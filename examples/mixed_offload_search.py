"""Mixed-destination offload search on the heterogeneous miniapp.

The paper's GA searches binary CPU/GPU placements; here one k-ary genome
places every loop on CPU, GPU or the FPGA profile in a single search
(arXiv:2011.12431's mixed offloading destination environment). With
``--cache``, re-running with a different ``--destinations`` subset reuses
every measurement whose placement falls inside the shared destinations —
the fingerprint covers the machine, not the subset.

  PYTHONPATH=src python examples/mixed_offload_search.py
  PYTHONPATH=src python examples/mixed_offload_search.py \
      --destinations cpu,gpu --cache /tmp/hetero.jsonl
  PYTHONPATH=src python examples/mixed_offload_search.py \
      --destinations cpu,gpu,fpga --cache /tmp/hetero.jsonl  # warm start
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="hetero",
                    help="miniapp name (see repro.core.miniapps.MINIAPPS)")
    ap.add_argument("--destinations", default="cpu,gpu,fpga",
                    help="comma-separated destination subset; first must "
                         "be the host")
    ap.add_argument("--population", type=int, default=24)
    ap.add_argument("--generations", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent fitness cache (JSONL), shared across "
                         "destination subsets")
    args = ap.parse_args()

    from repro.core import ga, miniapps
    from repro.core.evalpool import EvalPool, FitnessCache
    from repro.destinations import MixedEvaluator

    prog = miniapps.MINIAPPS[args.app]()
    subset = tuple(args.destinations.split(","))
    e = MixedEvaluator(prog, subset)
    print(f"{prog.name}: {prog.gene_length} genes x {e.k} destinations "
          f"({', '.join(d.name for d in e.dests)})")

    cache = FitnessCache(args.cache, fingerprint=e.fingerprint()) \
        if args.cache else None
    if cache is not None and len(cache):
        print(f"resumed fitness cache: {len(cache)} placements ({args.cache})")
    params = ga.GAParams(
        population=args.population, generations=args.generations,
        seed=args.seed, timeout_s=1e6, alleles=e.k,
    )
    with EvalPool(e, workers=args.workers, cache=cache) as pool:
        res = ga.run_ga(
            None, prog.gene_length, params, pool=pool,
            on_generation=lambda s: print(
                f"  gen {s.generation:2d}: best {s.best_time_s:.4f}s "
                f"(hit-rate {s.hit_rate:.0%})"
            ),
        )
        tot = pool.totals()
    if cache is not None:
        cache.close()  # pools don't close caller-owned caches

    host_only = e.host_only_time()
    print(f"\nbest plan: {res.best_time_s:.4f}s "
          f"= {host_only / res.best_time_s:.1f}x over all-CPU "
          f"({tot.evaluated} measurements, {tot.cache_hits} cache hits)")
    print(e.breakdown(res.best_genes).describe())
    for loop, g in zip(prog.offloadable_loops, e.admissible(res.best_genes)):
        print(f"  {loop.name:16s} -> {e.dests[g].name}")


if __name__ == "__main__":
    main()
