"""Mixed-destination offload search on the heterogeneous miniapp.

The paper's GA searches binary CPU/GPU placements; here one k-ary genome
places every loop on CPU, GPU or the FPGA profile in a single search
(arXiv:2011.12431's mixed offloading destination environment), driven
end-to-end through the ``repro.offload`` facade. With ``--cache``,
re-running with a different ``--destinations`` subset reuses every
measurement whose placement falls inside the shared destinations — the
fingerprint covers the machine, not the subset. ``--warm-start`` seeds
the k-ary population with each single-destination best.

  PYTHONPATH=src python examples/mixed_offload_search.py
  PYTHONPATH=src python examples/mixed_offload_search.py \
      --destinations cpu,gpu --cache /tmp/hetero.jsonl
  PYTHONPATH=src python examples/mixed_offload_search.py \
      --destinations cpu,gpu,fpga --cache /tmp/hetero.jsonl  # warm start
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="hetero",
                    help="miniapp name (see repro.core.miniapps.MINIAPPS)")
    ap.add_argument("--destinations", default="cpu,gpu,fpga",
                    help="comma-separated destination subset; first must "
                         "be the host")
    ap.add_argument("--population", type=int, default=24)
    ap.add_argument("--generations", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent fitness cache (JSONL), shared across "
                         "destination subsets")
    ap.add_argument("--warm-start", action="store_true",
                    help="seed the population with single-destination "
                         "bests (genome-aware seeding)")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="save the staged OffloadResult artifact here")
    args = ap.parse_args()

    from repro.offload import Offloader, OffloadSpec

    spec = OffloadSpec(
        program=args.app,
        mode="mixed",
        destinations=tuple(args.destinations.split(",")),
        population=args.population,
        generations=args.generations,
        seed=args.seed,
        workers=args.workers,
        cache=args.cache,
        warm_start=args.warm_start,
    )
    off = Offloader(
        spec, artifact_path=args.artifact,
        on_generation=lambda s: print(
            f"  gen {s.generation:2d}: best {s.best_time_s:.4f}s "
            f"(hit-rate {s.hit_rate:.0%})"
        ),
    )
    a = off.run(until="analyze").stage("analyze").payload
    print(f"{a['program']}: {a['gene_length']} genes x "
          f"{len(a['destinations'])} destinations "
          f"({', '.join(a['destinations'])})")
    res = off.run()
    print()
    print(res.stage("report").payload["text"])


if __name__ == "__main__":
    main()
