"""End-to-end training driver: ~100M-param model, a few hundred steps.

Builds a mid-size dense config (~100M params), runs the GA offload search
over its stage-group plan with the ANALYTIC evaluator, then trains under
the found plan with the full substrate: synthetic pipeline, AdamW, async
checkpoints, monitor. Loss decreases on the planted-bigram stream.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.configs.base import TRAIN_4K
from repro.core import analysis
from repro.data.pipeline import DataConfig
from repro.train.trainer import TrainConfig, Trainer


def build_100m():
    """~100M params: stablelm-3b family scaled down (same structure)."""
    cfg = get_arch("stablelm-3b")
    cfg = dataclasses.replace(
        cfg, n_layers=8, d_model=512, n_heads=8, kv_heads=8, head_dim=64,
        d_ff=2048, vocab=32768,
    )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = build_100m()
    print(f"model: {cfg.n_params()/1e6:.0f}M params "
          f"({cfg.n_layers}L d{cfg.d_model} ff{cfg.d_ff} v{cfg.vocab})")

    plan = analysis.build_plan(cfg, None, n_groups=4)
    print("plan:\n" + plan.describe())

    shape = dataclasses.replace(
        TRAIN_4K, seq_len=args.seq, global_batch=args.batch
    )
    tcfg = TrainConfig(
        steps=args.steps, log_every=20, ckpt_dir=args.ckpt_dir,
        save_every=100, peak_lr=1e-3, warmup=30,
    )
    trainer = Trainer(cfg, shape, plan, tcfg=tcfg, data=DataConfig(seed=7))
    summary = trainer.run()
    print(f"final: {summary}")
    if trainer.monitor.records:
        first = trainer.monitor.records[0].loss
        assert summary["loss_ewma"] < first, "loss must decrease"
        print(f"loss: {first:.3f} -> ewma {summary['loss_ewma']:.3f}  OK")
    else:
        print(f"resumed checkpoint already at step {trainer.step}; "
              f"nothing left to train (pass a fresh --ckpt-dir)")


if __name__ == "__main__":
    main()
