"""Quickstart: the paper's pipeline end to end on one miniapp.

1. "Code analysis"  — load the Himeno LoopProgram (13 offloadable loops)
2. GA offload search — fitness t^-1/2, roulette+elitism, Pc=.9 Pm=.05
3. Transfer reduction — bulk / present / temp-area scheduling
4. PCAST result check — offloaded vs CPU outputs on a sample run

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import evaluator as ev
from repro.core import ga, miniapps, pcast
from repro.core import transfer as tr


def main():
    # -- 1. code analysis -------------------------------------------------
    prog = miniapps.himeno_program()
    print(prog.describe())

    # -- 2. GA search (proposed method: bulk+present+temp-area) -----------
    evaluator = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)
    params = ga.GAParams.for_gene_length(prog.gene_length, seed=0)
    print(f"\nGA: M={params.population} T={params.generations} "
          f"Pc={params.crossover_rate} Pm={params.mutation_rate}")
    result = ga.run_ga(
        evaluator, prog.gene_length, params,
        on_generation=lambda s: print(
            f"  gen {s.generation:2d}: best {s.best_time_s*1e3:8.1f} ms "
            f"(mean {s.mean_time_s*1e3:8.1f} ms)"
        ),
    )
    cpu_time = evaluator.cpu_only_time()
    print(f"\nbest genes: {result.best_genes}")
    print(f"CPU-only {cpu_time:.2f}s -> offloaded {result.best_time_s:.3f}s "
          f"= {cpu_time/result.best_time_s:.1f}x speedup "
          f"(paper: 15.4x; previous method 4.8x)")

    # -- 3. transfer schedule for the found plan ---------------------------
    sched = tr.build_schedule(prog, result.best_genes, tr.TransferMode.BULK)
    print(f"transfer schedule: {sched.describe()}")

    # -- 4. PCAST result-difference check ----------------------------------
    print("\nPCAST check (offloaded jit stencil vs CPU numpy):")
    p_acc, gosa_acc = miniapps.himeno_run(grid=(17, 17, 33), nn=4,
                                          jit_stencil=True)
    p_cpu, gosa_cpu = miniapps.himeno_run(grid=(17, 17, 33), nn=4,
                                          jit_stencil=False)
    report = pcast.compare(
        {"p": p_cpu, "gosa": np.float32(gosa_cpu)},
        {"p": p_acc, "gosa": np.float32(gosa_acc)},
    )
    print(report.describe())
    assert report.ok


if __name__ == "__main__":
    main()
