"""Quickstart: the paper's pipeline end to end on one miniapp.

One :class:`OffloadSpec` drives every step through the staged
``repro.offload`` facade:

1. analyze — "code analysis": the Himeno LoopProgram (13 offloadable
   loops) with its pgcc-style directive per loop
2. seed + search — GA offload search (fitness t^-1/2, roulette+elitism,
   Pc=.9 Pm=.05, the paper's M/T rule) over the evaluation pool
3. verify — re-measure the winner + PCAST result-difference check of the
   offloaded JAX implementation vs the CPU numpy reference
4. report — the end-to-end summary (also saved in the artifact)

  PYTHONPATH=src python examples/quickstart.py

The same flow from the command line:

  PYTHONPATH=src python -m repro.offload run --program himeno
"""
from repro.offload import Offloader, OffloadSpec


def main():
    spec = OffloadSpec(program="himeno", mode="binary", method="proposed")
    off = Offloader(
        spec,
        on_generation=lambda s: print(
            f"  gen {s.generation:2d}: best {s.best_time_s*1e3:8.1f} ms "
            f"(mean {s.mean_time_s*1e3:8.1f} ms)"
        ),
    )

    # -- 1. code analysis -------------------------------------------------
    a = off.run(until="analyze").stage("analyze").payload
    print(f"{a['description']}: {a['n_loops']} loops, "
          f"{a['gene_length']} offloadable (= gene length)")
    for l in a["loops"]:
        print(f"  {l['name']:24s} {l['class']:16s} {l['directive']}")

    # -- 2-4. search + verify + report ------------------------------------
    print(f"\nGA search ({spec.method} method):")
    res = off.run()
    print()
    print(res.stage("report").payload["text"])

    # a PCAST failure would have raised StageFailure out of run() above;
    # reaching here means the offloaded results matched the CPU reference
    print(f"\n(paper: 15.4x; previous method 4.8x — got "
          f"{res.speedup:.1f}x)")


if __name__ == "__main__":
    main()
