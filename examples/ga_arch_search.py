"""Beyond-paper: the GA offload search applied to a MODEL ARCHITECTURE.

The paper searches which C loops go to the GPU. At the framework level the
same genome decides which stage groups of a transformer get their
accelerated treatment (TP/EP sharding + fused kernels) vs the replicated
baseline. The verification environment here is the AOT-compiled roofline
evaluator on the production mesh — expensive per individual (XLA compile),
exactly like the paper's per-individual deploy+measure, so gene lengths
stay small (units, not layers).

This example uses the ANALYTIC plan evaluator (instant) by default so it
runs everywhere; pass --compiled to score individuals by actually
lowering+compiling each plan on the 16x16 mesh (minutes; run via
  PYTHONPATH=src python examples/ga_arch_search.py --compiled
inside a fresh process — it sets the 512-device flag itself).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--compiled", action="store_true")
    ap.add_argument("--generations", type=int, default=0,
                    help="override GA generations")
    ap.add_argument("--workers", type=int, default=1,
                    help="concurrent fitness measurements per generation")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent fitness cache (JSONL); lets a killed "
                         "search resume without re-measuring")
    args = ap.parse_args()

    if args.compiled and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

    from repro.configs import get_arch
    from repro.core import analysis, ga
    from repro.core.evalpool import EvalPool, FitnessCache, \
        evaluator_fingerprint
    from repro.core.evaluator import CompiledEvaluator

    cfg = get_arch(args.arch)
    units = analysis.build_units(cfg, None)
    n = len(units)
    print(f"{args.arch}: {n} offload units (gene length {n})")
    for u in units:
        print(f"  {u.name:14s} {u.directive.value}")

    if args.compiled:
        from repro.launch import dryrun
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=False)

        def build_and_score(genes):
            plan = analysis.build_plan(cfg, mesh, genes=genes)
            rec = dryrun.run_cell(
                args.arch, "train_4k", multi_pod=False, mesh=mesh,
                plan=plan, verbose=False,
            )
            return rec["roofline"]["t_step_s"]

        evaluator = CompiledEvaluator(
            build_and_score, verbose=True, compile_workers=args.workers,
            tag=f"{args.arch}:train_4k:16x16",
        )
        gens = args.generations or 4
        params = ga.GAParams(population=min(n, 6), generations=gens,
                             seed=0, timeout_s=1e6)
    else:
        # analytic: per-unit roofline terms without compiling
        from repro.configs.base import TRAIN_4K
        from repro.launch.roofline import model_flops

        def analytic_time(genes):
            plan = analysis.build_plan(cfg, None, genes=genes)
            # napkin model: offloaded units run TP-sharded (model axis 16),
            # baseline units replicated (x16 compute); collectives charged
            # per offloaded unit boundary.
            t = 0.0
            flops = model_flops(cfg, TRAIN_4K) / 256
            per_unit = flops / max(len(plan.units), 1)
            for u in plan.units:
                rate = 197e12
                t += per_unit / rate / (1.0 if u.offload else 16.0) * 16.0 \
                    if not u.offload else per_unit / rate
                if u.offload:
                    t += 2 * cfg.d_model * 4096 * 2 / 50e9 / 1e3  # reshard
            return t

        # cache key: the closure's qualname alone would collide across
        # --arch values, silently sharing measurements between models
        analytic_time.fingerprint = lambda: f"analytic-plan:{args.arch}"
        evaluator = analytic_time
        params = ga.GAParams(
            population=min(n, 10),
            generations=args.generations or min(n, 10),
            seed=0, timeout_s=1e6,
        )

    cache = FitnessCache(args.cache,
                         fingerprint=evaluator_fingerprint(evaluator)) \
        if args.cache else None
    if cache is not None and len(cache):
        print(f"resumed fitness cache: {len(cache)} measurements "
              f"({args.cache})")
    pool = EvalPool(evaluator, workers=args.workers, cache=cache)
    result = ga.run_ga(
        None, n, params, pool=pool,
        on_generation=lambda s: print(
            f"  gen {s.generation}: best {s.best_time_s*1e3:.2f} ms "
            f"(wall {s.gen_wall_s:.2f}s, dedup {s.dedup_ratio:.0%}, "
            f"hit-rate {s.hit_rate:.0%})"
        ),
    )
    tot = pool.totals()
    pool.close()
    if cache is not None:
        cache.close()  # pools don't close caller-owned caches
    print(f"\nsearch: {tot.evaluated} measurements for "
          f"{tot.submitted} individuals "
          f"({tot.cache_hits} cache hits, {tot.timeouts} timeouts)")
    print(f"best genes: {result.best_genes}")
    best_plan = analysis.build_plan(cfg, None, genes=result.best_genes)
    print(best_plan.describe())


if __name__ == "__main__":
    main()
