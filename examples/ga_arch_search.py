"""Beyond-paper: the GA offload search applied to a MODEL ARCHITECTURE.

The paper searches which C loops go to the GPU. At the framework level the
same genome decides which stage groups of a transformer get their
accelerated treatment (TP/EP sharding + fused kernels) vs the replicated
baseline, driven through the ``repro.offload`` facade with
``program="arch:<name>"``. The verification environment here is the
AOT-compiled roofline evaluator on the production mesh — expensive per
individual (XLA compile), exactly like the paper's per-individual
deploy+measure, so gene lengths stay small (units, not layers).

This example uses the ANALYTIC plan evaluator (instant) by default so it
runs everywhere; pass --compiled to score individuals by actually
lowering+compiling each plan on the 16x16 mesh (minutes; run via
  PYTHONPATH=src python examples/ga_arch_search.py --compiled
inside a fresh process — it sets the 512-device flag itself). The
compiled evaluator is injected into the facade; such artifacts resume
only with the same injection.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--compiled", action="store_true")
    ap.add_argument("--generations", type=int, default=0,
                    help="override GA generations")
    ap.add_argument("--workers", type=int, default=1,
                    help="concurrent fitness measurements per generation")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent fitness cache (JSONL); lets a killed "
                         "search resume without re-measuring")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="save the staged OffloadResult artifact here")
    args = ap.parse_args()

    if args.compiled and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

    from repro.offload import Offloader, OffloadSpec

    spec = OffloadSpec(
        program=f"arch:{args.arch}",
        generations=args.generations or (4 if args.compiled else None),
        population=6 if args.compiled else None,
        workers=args.workers,
        cache=args.cache,
    )

    evaluator = None
    if args.compiled:
        from repro.core.evaluator import CompiledEvaluator
        from repro.core import analysis
        from repro.configs import get_arch
        from repro.launch import dryrun
        from repro.launch.mesh import make_production_mesh

        cfg = get_arch(args.arch)
        mesh = make_production_mesh(multi_pod=False)

        def build_and_score(genes):
            plan = analysis.build_plan(cfg, mesh, genes=genes)
            rec = dryrun.run_cell(
                args.arch, "train_4k", multi_pod=False, mesh=mesh,
                plan=plan, verbose=False,
            )
            return rec["roofline"]["t_step_s"]

        evaluator = CompiledEvaluator(
            build_and_score, verbose=True, compile_workers=args.workers,
            tag=f"{args.arch}:train_4k:16x16",
        )

    off = Offloader(
        spec, artifact_path=args.artifact, evaluator=evaluator,
        on_generation=lambda s: print(
            f"  gen {s.generation}: best {s.best_time_s*1e3:.2f} ms "
            f"(wall {s.gen_wall_s:.2f}s, dedup {s.dedup_ratio:.0%}, "
            f"hit-rate {s.hit_rate:.0%})"
        ),
    )
    a = off.run(until="analyze").stage("analyze").payload
    print(f"{args.arch}: {a['gene_length']} offload units "
          f"(gene length {a['gene_length']})")
    for u in a["units"]:
        print(f"  {u['name']:14s} {u['directive']}")

    res = off.run()
    search = res.stage("search").payload
    print(f"\nsearch: {search['evaluations']} measurements "
          f"({search['cache_hits']} cache hits)")
    print(f"best genes: {res.best_genes}")
    print(off.adapter.describe_plan(res.best_genes))


if __name__ == "__main__":
    main()
