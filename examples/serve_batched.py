"""Serving example: batched requests through the continuous-batching engine.

  PYTHONPATH=src python examples/serve_batched.py --arch glm4-9b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.core import analysis
from repro.models.model import Model
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="glm4-9b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    plan = analysis.build_plan(cfg, None, n_groups=2)
    model = Model(cfg, plan)
    params = jax.jit(model.init)(jax.random.key(0))
    engine = Engine(cfg, plan, params, ServeConfig(slots=args.slots,
                                                   ctx_len=128))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            request_id=i,
            prompt=rng.integers(0, cfg.vocab, 8 + int(rng.integers(0, 24)))
                      .astype(np.int32),
            max_new_tokens=8 + int(rng.integers(0, 8)),
        ))
    t0 = time.perf_counter()
    ticks = 0
    while engine.queue or any(engine.slot_req):
        served = engine.step()
        ticks += 1
        if ticks % 8 == 0:
            print(f"  tick {ticks}: {served} active slots, "
                  f"{len(engine.queue)} queued, "
                  f"{len(engine.finished)} finished")
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in engine.finished)
    print(f"\n{len(engine.finished)} requests, {toks} tokens, {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU, reduced {args.arch})")


if __name__ == "__main__":
    main()
