"""Plan-aware train/serve step builders.

``make_train_step`` returns the jit-able update function with:
- microbatch gradient accumulation (lax.scan over batch splits),
- optional int8 gradient compression with error feedback (plan-gated),
- the model's remat policy already baked into its forward.

``make_prefill_step`` / ``make_decode_step`` build the serving entry points.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import Optimizer
from repro.optim import compression


def pick_microbatches(global_batch: int, seq: int, dp: int,
                      tokens_budget: int = 8192) -> int:
    """Largest power-of-2 microbatch count keeping per-shard microbatch >= 1
    and per-shard tokens under budget."""
    per_shard = max(global_batch // max(dp, 1), 1)
    mb = 1
    while (
        mb * 2 <= per_shard
        and (per_shard // mb) * seq > tokens_budget
    ):
        mb *= 2
    return mb


def make_train_step(model: Model, opt: Optimizer, compress: bool = False):
    mb = model.plan.microbatches
    mctx = model.mctx
    pspecs = model.param_specs()

    def shard_like_params(tree):
        """Keep gradients sharded exactly like params (ZeRO reduce-scatter
        instead of replicated all-reduce — the staged-transfer analogue)."""
        if mctx.mesh is None:
            return tree
        return jax.tree.map(
            lambda g, s: mctx.wsc(g, *tuple(s)), tree, pspecs
        )

    def total_loss(params, batch):
        # §Perf: the weight gather happens HERE — inside the grad, outside
        # the microbatch scan. The scan transpose accumulates the gathered
        # weights' cotangents locally across microbatches, so the gather's
        # transpose (the gradient reduce-scatter) fires ONCE per step —
        # the paper's transfer hoisting applied at the framework level.
        gathered = model.gather_params(params)
        if mb <= 1:
            return model.loss(gathered, batch)
        split = jax.tree.map(
            lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch
        )

        def body(lacc, microbatch):
            l, m = model.loss(gathered, microbatch)
            return lacc + l, m["aux"]

        lsum, auxs = jax.lax.scan(body, jnp.zeros((), jnp.float32), split)
        loss = lsum / mb
        return loss, {"nll": loss, "aux": auxs.mean()}

    grad_fn = jax.value_and_grad(total_loss, has_aux=True)

    def step(params, opt_state, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        grads = shard_like_params(grads)

        if compress:
            grads, new_ef = compression.ef_compress_tree(
                grads, opt_state["ef"]
            )
            inner = opt_state["opt"]
        else:
            inner = opt_state

        new_params, new_inner = opt.update(grads, inner, params)
        if compress:
            new_state = {"opt": new_inner, "ef": new_ef}
        else:
            new_state = new_inner
        return new_params, new_state, {"loss": loss, **metrics}

    return step


def init_opt_state(model: Model, opt: Optimizer, params, compress: bool = False):
    state = opt.init(params)
    if compress:
        return {"opt": state, "ef": compression.ef_init(params)}
    return state


def opt_state_specs(model: Model, opt: Optimizer, compress: bool = False):
    specs = opt.state_specs(model.param_specs())
    if compress:
        return {"opt": specs, "ef": model.param_specs()}
    return specs


def make_prefill_step(model: Model, ctx_len: Optional[int] = None):
    def prefill(params, batch):
        return model.prefill(params, batch, ctx_len=ctx_len)

    return prefill


def make_decode_step(model: Model):
    def decode(params, cache, tokens, positions):
        return model.decode_step(params, cache, tokens, positions)

    return decode
