"""Trainer: the end-to-end training loop over all substrate layers.

Wires together: Model (plan-aware), optimizer, data pipeline, checkpoint
manager (async, restartable), monitor, and the fault coordinator
(heartbeat/straggler simulation hooks). Used by ``launch.train`` and the
end-to-end example; small enough to read top to bottom.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.plan import ExecutionPlan
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.model import Model
from repro.models.sharding import MeshCtx, spec_tree_to_shardings
from repro.optim.adamw import Optimizer, adamw, cosine_schedule
from repro.runtime.fault import FaultCoordinator
from repro.runtime.monitor import Monitor
from repro.train import train_step as ts


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    save_every: int = 50
    compress_grads: bool = False
    seed: int = 0
    peak_lr: float = 3e-4
    warmup: int = 10


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        plan: ExecutionPlan,
        mesh=None,
        opt: Optional[Optimizer] = None,
        tcfg: TrainConfig = TrainConfig(),
        data: DataConfig = DataConfig(),
        interpret: bool = False,
    ):
        self.cfg = cfg
        self.shape = shape
        self.tcfg = tcfg
        self.mesh = mesh
        self.mctx = MeshCtx(mesh)
        self.model = Model(cfg, plan, mesh=mesh, interpret=interpret)
        self.opt = opt or adamw(
            cosine_schedule(tcfg.peak_lr, tcfg.warmup, tcfg.steps)
        )
        self.monitor = Monitor()
        self.pipeline = Pipeline(cfg, shape, data)
        self.manager = (
            CheckpointManager(tcfg.ckpt_dir, save_every=tcfg.save_every)
            if tcfg.ckpt_dir
            else None
        )
        self.fault: Optional[FaultCoordinator] = None
        self._step_fn = None
        self.params = None
        self.opt_state = None
        self.step = 0

    # ------------------------------------------------------------------
    def initialize(self):
        rng = jax.random.key(self.tcfg.seed)
        if self.mesh is not None:
            pspecs = self.model.param_specs()
            shardings = spec_tree_to_shardings(self.mctx, pspecs)
            init = jax.jit(self.model.init, out_shardings=shardings)
            with self.mesh:
                self.params = init(rng)
        else:
            self.params = jax.jit(self.model.init)(rng)
        self.opt_state = ts.init_opt_state(
            self.model, self.opt, self.params, self.tcfg.compress_grads
        )
        step_fn = ts.make_train_step(
            self.model, self.opt, self.tcfg.compress_grads
        )
        self._step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        # restart path: restore latest checkpoint if one exists
        if self.manager is not None:
            state = {"params": self.params, "opt": self.opt_state}
            restored_step, restored = self.manager.restore_latest(state)
            if restored_step is not None:
                self.params = restored["params"]
                self.opt_state = restored["opt"]
                self.step = restored_step
                self.pipeline.step = restored_step
        return self

    # ------------------------------------------------------------------
    def _device_batch(self, batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in batch.items():
            arr = jnp.asarray(v)
            if self.mesh is not None:
                b = self.mctx.batch_entry(arr.shape[0])
                from jax.sharding import NamedSharding, PartitionSpec as P

                spec = P(b, *([None] * (arr.ndim - 1)))
                arr = jax.device_put(arr, NamedSharding(self.mesh, spec))
            out[k] = arr
        return out

    def run(self, data_iter: Optional[Iterable] = None) -> Dict[str, float]:
        if self.params is None:
            self.initialize()
        it = iter(data_iter) if data_iter is not None else iter(self.pipeline)
        ctx = self.mesh if self.mesh is not None else _NullCtx()
        with ctx:
            while self.step < self.tcfg.steps:
                batch = self._device_batch(next(it))
                self.monitor.start_step()
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch
                )
                loss = float(metrics["loss"])
                rec = self.monitor.end_step(
                    self.step, loss,
                    tokens=self.shape.global_batch * self.shape.seq_len,
                )
                self.step += 1
                if self.fault is not None:
                    self.fault.on_step(self.step, {0: rec.seconds})
                if self.manager is not None and self.manager.should_save(
                    self.step
                ):
                    self.manager.save(
                        self.step,
                        {"params": self.params, "opt": self.opt_state},
                        metadata={"loss": loss},
                    )
                if self.step % self.tcfg.log_every == 0:
                    s = self.monitor.summary()
                    print(
                        f"[train] step {self.step} loss {loss:.4f} "
                        f"({s['tokens_per_s']:.0f} tok/s, "
                        f"{s['mean_step_s']*1e3:.0f} ms/step)"
                    )
        if self.manager is not None:
            self.manager.finalize()
        return self.monitor.summary()


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
