"""Model calibration against real measurements (docs/fidelity.md).

The analytic ``HardwareModel`` constants were frozen once against the
paper's published end-points; nothing on THIS container ever checked
them against a wall clock. This module closes that loop, the way the
FPGA companion work (arXiv:2004.08548) insists modeled rates must be:

- **measure** a small designed probe set — the runnable miniapps
  (himeno, nasft) at several grid/iteration configs, each on both the
  host (numpy) and the accelerator (jitted JAX) path, wall-clocked by
  :class:`~repro.core.evaluator.MeasuredEvaluator`;
- **fit** per-destination constants by linear least squares:
  ``t ≈ flops/rate + bytes/link_bw + calls*setup`` (host probes have no
  transfer column). Two apps with different flops/bytes ratios keep the
  columns independent; non-positive coefficients are dropped and their
  constants *pinned* to the base model (recorded, never silent);
- **emit** a named registry entry (e.g. ``quadro-p4000-calibrated``)
  selectable via ``OffloadSpec.hw`` in every mode — the fitted
  :class:`HardwareModel` for binary/arch searches plus a
  :func:`~repro.destinations.profiles.calibrated_registry` for mixed
  searches — and record every probe's fit residual in the artifact.

Cache identity: the emitted ``HardwareModel.name`` is
``<entry>-<8-hex digest of the fitted constants>``, and the calibrated
registry fingerprints every constant, so a re-calibration deliberately
invalidates fitness caches while the modeled machines' fingerprints
stay untouched.

With ``kernels=True`` (``--kernels`` on the CLI, automatic when
``OffloadSpec.blocks`` is set) the probe set extends to the block
kernel library: each entry's implementation is wall-clocked against its
``ref.py`` oracle and the measured speedup lands in
``kernel_constants``, which :func:`install` registers as per-kernel
gains so block-substitution pricing (docs/blocks.md) is fitted, not
assumed.

Single constants cannot be split into a compute/bandwidth pair by one
wall clock, so the fit keeps the base machine's compute:bandwidth
*balance*: ``cpu_membw``/``accel_membw`` scale with the fitted rates
(recorded under ``pinned``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import evaluator as ev
from repro.core import miniapps
from repro.core.evaluator import loop_bytes
from repro.core.loopir import LoopClass, LoopProgram
from repro.destinations import (
    Registry,
    calibrated_registry,
    get_registry,
    register_registry,
)
from repro.offload import programs

_CAL_VERSION = 1


# ---------------------------------------------------------------------------
# the designed probe set
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Probe:
    """One designed measurement: app config x destination path."""

    app: str  # "himeno" | "nasft"
    grid: Tuple[int, int, int]
    steps: int  # nn (himeno) / niter (nasft)
    dest: str  # "host" | "accel"


def _configs() -> List[Tuple[str, Tuple[int, int, int], int]]:
    # grids big enough that per-call compute rises above dispatch noise
    # (at toy grids the jit path is dispatch-dominated and the rate
    # column of the fit is unidentifiable), small enough that the whole
    # sweep stays a few seconds
    return [
        ("himeno", (17, 17, 33), 2),
        ("himeno", (17, 17, 33), 4),
        ("himeno", (33, 33, 65), 2),
        ("himeno", (33, 33, 65), 4),
        ("nasft", (16, 16, 16), 2),
        ("nasft", (16, 16, 16), 4),
        ("nasft", (32, 32, 32), 2),
        ("nasft", (32, 32, 32), 4),
    ]


# both apps at several scales, each on both paths: himeno and nasft have
# different flops/bytes ratios, which is what keeps the rate and
# transfer columns of the least-squares system independent
DEFAULT_PROBES: Tuple[Probe, ...] = tuple(
    Probe(app, grid, steps, dest)
    for app, grid, steps in _configs()
    for dest in ("host", "accel")
)


def _probe_run_fn(p: Probe):
    if p.app == "himeno":
        return miniapps.HimenoRunFn(grid=p.grid, nn=p.steps)
    return miniapps.NasftRunFn(grid=p.grid, niter=p.steps)


def _probe_program(p: Probe) -> LoopProgram:
    if p.app == "himeno":
        return miniapps.himeno_program(grid=p.grid, nn=p.steps)
    return miniapps.nasft_program(grid=p.grid, niter=p.steps)


def _region_quantities(prog: LoopProgram) -> Tuple[float, float, float]:
    """(flops, bytes, calls) of the program's sequential-region loops —
    the work the runnable implementations actually execute per run."""
    flops = byts = 0.0
    for loop in prog.loops:
        if loop.parent_seq is None:
            continue
        execs = prog.region_trip(loop.parent_seq)
        flops += loop.total_flops * execs
        byts += loop_bytes(prog, loop) * execs
    calls = float(max((r.trip for r in prog.seq_regions), default=1))
    return flops, byts, calls


def _measure_probe(p: Probe, repeats: int) -> float:
    """Wall-clock one probe in-process (the calibrate flow measures a
    handful of designed points, not a GA population — subprocess
    isolation buys nothing here). One untimed warm-up run precedes the
    timed repeats: calibration fits steady-state rates by definition,
    so a one-time jit compile must never land in a probe even at
    repeats=1."""
    fn = _probe_run_fn(p)
    m = ev.MeasuredEvaluator(fn, repeats=repeats, tag=fn.tag)
    n = miniapps.MINIAPPS[p.app]().gene_length
    genes = [0] * n
    if p.dest == "accel":
        genes[programs.hot_gene_index(p.app)] = 1
    fn(genes)  # warm-up (compile cache), not timed
    return float(m(genes))


# ---------------------------------------------------------------------------
# the least-squares fit
# ---------------------------------------------------------------------------


def _nonneg_lstsq(
    A: np.ndarray, b: np.ndarray
) -> Tuple[Optional[np.ndarray], List[int]]:
    """Least squares with non-positive coefficients dropped (their
    columns zeroed and refit), column 0 (the rate term) mandatory.
    Returns (coefficients | None when even the rate fit fails, dropped
    column indices). Columns are norm-scaled before the solve."""
    active = list(range(A.shape[1]))
    dropped: List[int] = []
    while True:
        sub = A[:, active]
        scale = np.linalg.norm(sub, axis=0)
        scale[scale == 0.0] = 1.0
        coef_s, *_ = np.linalg.lstsq(sub / scale, b, rcond=None)
        coef = coef_s / scale
        bad = [i for i, c in zip(active, coef) if c <= 0.0 and i != 0]
        if not bad:
            if coef[0] <= 0.0:
                return None, dropped  # unusable: pin to the base model
            out = np.zeros(A.shape[1])
            for i, c in zip(active, coef):
                out[i] = c
            return out, dropped
        # drop the worst offender and refit the rest
        worst = min(bad, key=lambda i: coef[active.index(i)])
        active.remove(worst)
        dropped.append(worst)


def _base_hw_from_registry(reg: Registry) -> ev.HardwareModel:
    """Derive the base HardwareModel constants from a registry's host
    and first GPU/TPU-kind destination (works for any registry, named
    calibrations included)."""
    host = reg.host
    accel = next(
        (d for d in reg.destinations if d.kind in ("gpu", "tpu")), None
    )
    if accel is None:
        raise ValueError(
            f"registry {reg.name!r} has no GPU/TPU-kind destination to "
            "calibrate against"
        )
    link = reg.link(host.name, accel.name)
    assert link is not None, (host.name, accel.name)
    rates = dict(accel.rates)
    return ev.HardwareModel(
        name=f"base-of-{reg.name}",
        cpu_flops=dict(host.rates)[LoopClass.TIGHT],
        cpu_membw=host.membw,
        accel_flops_kernels=rates[LoopClass.TIGHT],
        accel_flops_parallel=rates[LoopClass.NON_TIGHT],
        accel_flops_vector=rates[LoopClass.VECTOR_ONLY],
        accel_membw=accel.membw,
        link_bw=link.bw,
        link_latency=link.latency,
        launch_latency=accel.launch_latency,
    )


# ---------------------------------------------------------------------------
# the calibration artifact
# ---------------------------------------------------------------------------


_CONSTANT_FIELDS = (
    "cpu_flops", "cpu_membw", "accel_flops_kernels", "accel_flops_parallel",
    "accel_flops_vector", "accel_membw", "link_bw", "link_latency",
    "launch_latency",
)


@dataclasses.dataclass
class CalibrationResult:
    """Fitted constants + per-probe residuals for one machine.

    Saved as ``<name>.calib.json`` by the CLI (git-ignored: calibrations
    are machine-local facts, like fitness caches) and embedded verbatim
    in the pipeline's ``calibrate`` stage payload, so resuming a
    calibrated artifact reconstructs the identical machine without
    re-measuring anything.
    """

    name: str  # spec-facing registry/hw entry name
    base: str  # the base registry that was calibrated
    host: str  # where the clocks ran
    repeats: int
    constants: Dict[str, float]  # the _CONSTANT_FIELDS values
    pinned: Tuple[str, ...]  # constants NOT determined by the fit
    probes: Tuple[Dict[str, Any], ...]  # measured/fitted/residual rows
    # per-kernel speedup of each block-library implementation over its
    # ref.py oracle on THIS host (``run_calibration(kernels=True)``,
    # docs/blocks.md); empty unless kernel probes ran
    kernel_constants: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )

    @property
    def digest(self) -> str:
        blob = json.dumps(self.constants, sort_keys=True)
        if self.kernel_constants:
            # appended only when present: kernel-free calibrations keep
            # their pre-blocks digests (and cache identities) unchanged
            blob += json.dumps(self.kernel_constants, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:8]

    @property
    def hw_name(self) -> str:
        """The HardwareModel name: entry name + constants digest, so a
        re-calibration can never silently share fitness-cache entries
        with its predecessor (binary-mode fingerprints key on it)."""
        return f"{self.name}-{self.digest}"

    def hardware_model(self) -> ev.HardwareModel:
        return ev.HardwareModel(name=self.hw_name, **self.constants)

    def residuals(self) -> Dict[str, float]:
        errs = [abs(float(p["rel_err"])) for p in self.probes]
        return {
            "n": len(errs),
            "max_abs_rel": max(errs) if errs else 0.0,
            "mean_abs_rel": float(np.mean(errs)) if errs else 0.0,
        }

    # -- (de)serialization --------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "v": _CAL_VERSION,
            "name": self.name,
            "base": self.base,
            "host": self.host,
            "repeats": self.repeats,
            "constants": dict(self.constants),
            "pinned": list(self.pinned),
            "probes": [dict(p) for p in self.probes],
            **({"kernel_constants": dict(self.kernel_constants)}
               if self.kernel_constants else {}),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CalibrationResult":
        v = d.get("v", _CAL_VERSION)
        if v != _CAL_VERSION:
            raise ValueError(f"unsupported calibration version {v!r}")
        return cls(
            name=str(d["name"]),
            base=str(d["base"]),
            host=str(d.get("host", "")),
            repeats=int(d.get("repeats", 1)),
            constants={k: float(v) for k, v in d["constants"].items()},
            pinned=tuple(d.get("pinned", ())),
            probes=tuple(dict(p) for p in d.get("probes", ())),
            kernel_constants={
                k: float(v)
                for k, v in d.get("kernel_constants", {}).items()
            },
        )

    def save(self, path: str) -> str:
        from repro.offload.result import atomic_json_save

        return atomic_json_save(path, self.to_dict())

    @classmethod
    def load(cls, path: str) -> "CalibrationResult":
        with open(path, "r", encoding="utf-8") as fh:
            return cls.from_dict(json.load(fh))


# ---------------------------------------------------------------------------
# the flow: measure -> fit -> emit
# ---------------------------------------------------------------------------


def run_calibration(
    base: str = "quadro-p4000",
    repeats: int = 3,
    name: Optional[str] = None,
    probes: Optional[Sequence[Probe]] = None,
    measure: Optional[Callable[[Probe, int], float]] = None,
    kernels: bool = False,
    kernel_measure: Optional[
        Callable[[Any], Tuple[float, float]]
    ] = None,
) -> CalibrationResult:
    """Measure the probe set and fit the calibrated constants.

    ``measure`` is injectable for tests (a synthetic clock makes the fit
    deterministic); the default wall-clocks in-process.

    ``kernels=True`` additionally times every block-library kernel
    (docs/blocks.md) against its ``ref.py`` oracle and records the
    measured speedup as a per-kernel gain in ``kernel_constants``, so a
    ``fidelity="calibrated"`` blocks run prices substitutions from this
    host's clocks instead of the modeled defaults. ``kernel_measure``
    is the injectable probe: ``entry -> (oracle_s, impl_s)``.
    """
    base_reg = get_registry(base)
    base_hw = _base_hw_from_registry(base_reg)
    name = name or f"{base}-calibrated"
    probes = tuple(probes if probes is not None else DEFAULT_PROBES)
    measure = measure or _measure_probe
    if not any(p.dest == "host" for p in probes) or \
            not any(p.dest == "accel" for p in probes):
        raise ValueError("probe set needs both host and accel probes")

    rows: List[Dict[str, Any]] = []
    for p in probes:
        flops, byts, calls = _region_quantities(_probe_program(p))
        rows.append({
            "app": p.app,
            "dest": p.dest,
            "grid": list(p.grid),
            "steps": p.steps,
            "flops": flops,
            "bytes": byts,
            "calls": calls,
            "measured_s": float(measure(p, repeats)),
        })

    pinned: List[str] = ["link_latency"]  # one wall clock can't see it

    # host fit: t ~ flops/rate + calls*overhead (no transfer column; the
    # per-call overhead is interpreter dispatch, recorded but unused)
    hrows = [r for r in rows if r["dest"] == "host"]
    A = np.array([[r["flops"], r["calls"]] for r in hrows])
    b = np.array([r["measured_s"] for r in hrows])
    coef, _ = _nonneg_lstsq(A, b)
    if coef is None:
        cpu_flops = base_hw.cpu_flops
        pinned.append("cpu_flops")
        coef = np.array([1.0 / cpu_flops, 0.0])
    else:
        cpu_flops = 1.0 / coef[0]
    for r in hrows:
        r["fitted_s"] = float(coef[0] * r["flops"] + coef[1] * r["calls"])

    # accel fit: t ~ flops/rate + bytes/link_bw + calls*launch
    arows = [r for r in rows if r["dest"] == "accel"]
    A = np.array([[r["flops"], r["bytes"], r["calls"]] for r in arows])
    b = np.array([r["measured_s"] for r in arows])
    coef, dropped = _nonneg_lstsq(A, b)
    if coef is None:
        accel_flops = base_hw.accel_flops_kernels
        link_bw = base_hw.link_bw
        launch = base_hw.launch_latency
        pinned += ["accel_flops_kernels", "link_bw", "launch_latency"]
        coef = np.array([1.0 / accel_flops, 1.0 / link_bw, launch])
    else:
        accel_flops = 1.0 / coef[0]
        link_bw = 1.0 / coef[1] if 1 not in dropped else base_hw.link_bw
        launch = float(coef[2]) if 2 not in dropped \
            else base_hw.launch_latency
        if 1 in dropped:
            pinned.append("link_bw")
        if 2 in dropped:
            pinned.append("launch_latency")
    for r in arows:
        r["fitted_s"] = float(
            coef[0] * r["flops"] + coef[1] * r["bytes"]
            + coef[2] * r["calls"]
        )

    for r in rows:
        r["rel_err"] = float(
            (r["fitted_s"] - r["measured_s"]) / max(r["measured_s"], 1e-12)
        )

    # a single rate per destination cannot split compute from bandwidth:
    # keep the base machine's balance (membw scales with the rate) and
    # its directive-rate ratios
    pinned += ["cpu_membw", "accel_membw", "accel_flops_parallel",
               "accel_flops_vector"]
    constants = {
        "cpu_flops": float(cpu_flops),
        "cpu_membw": float(
            base_hw.cpu_membw * cpu_flops / base_hw.cpu_flops
        ),
        "accel_flops_kernels": float(accel_flops),
        "accel_flops_parallel": float(
            accel_flops * base_hw.accel_flops_parallel
            / base_hw.accel_flops_kernels
        ),
        "accel_flops_vector": float(
            accel_flops * base_hw.accel_flops_vector
            / base_hw.accel_flops_kernels
        ),
        "accel_membw": float(
            base_hw.accel_membw * accel_flops
            / base_hw.accel_flops_kernels
        ),
        "link_bw": float(link_bw),
        "link_latency": float(base_hw.link_latency),
        "launch_latency": float(launch),
    }
    assert set(constants) == set(_CONSTANT_FIELDS)

    kernel_constants: Dict[str, float] = {}
    if kernels:
        from repro.blocks import library as blk

        kmeasure = kernel_measure or (
            lambda entry: blk.time_kernel(entry, repeats=repeats)
        )
        for entry in blk.default_library().entries:
            oracle_s, impl_s = kmeasure(entry)
            gain = float(oracle_s) / max(float(impl_s), 1e-12)
            # a kernel that measures slower than its oracle keeps a
            # sub-1 gain: the search then prices substitution as a loss
            # and the genome learns to leave the block alone
            kernel_constants[entry.name] = max(gain, 1e-6)

    return CalibrationResult(
        name=name,
        base=base,
        host=ev._local_host(),
        repeats=repeats,
        constants=constants,
        pinned=tuple(pinned),
        probes=tuple(rows),
        kernel_constants=kernel_constants,
    )


def install(cal: CalibrationResult,
            replace: bool = True) -> ev.HardwareModel:
    """Register the calibration as a named machine in THIS process:
    ``OffloadSpec.hw = cal.name`` then selects the fitted HardwareModel
    (binary/arch) or the calibrated registry (mixed). Registration is
    process-local — other processes re-install from the saved
    ``.calib.json`` or from the artifact's calibrate-stage payload."""
    hw = cal.hardware_model()
    programs.register_hw_model(hw, name=cal.name, replace=replace)

    def factory(base: str = cal.base, hw: ev.HardwareModel = hw,
                name: str = cal.name) -> Registry:
        return calibrated_registry(get_registry(base), hw, name)

    register_registry(cal.name, factory, replace=replace)
    if cal.kernel_constants:
        from repro.blocks import library as blk

        blk.register_kernel_gains(cal.name, dict(cal.kernel_constants))
    return hw


def load_and_install(path: str, replace: bool = True) -> CalibrationResult:
    """``install(CalibrationResult.load(path))`` — the CLI's
    ``--calibration`` flag."""
    cal = CalibrationResult.load(path)
    install(cal, replace=replace)
    return cal
