"""Search-quality metrics: does the search *converge* and does the model
*discriminate*?

The paper evaluates its GA offload search only by the final speedup it
finds. That single scalar hides two failure modes this module measures
(docs/observability.md):

- **winner instability** — the GA is a stochastic search; a different
  seed may land on a different (worse) placement. :func:`winner_stability`
  re-runs the *modeled* search across ``k`` seeds (reusing the recorded
  search for the spec's own seed and sharing the persistent fitness
  cache, so the extra searches are mostly cache hits) and summarizes
  them as ``pass@k`` within a relative window, the worst/best spread,
  and the number of distinct winning genomes. An optional variance gate
  turns excessive spread into a report-stage failure.
- **rank infidelity** — PR 5's fidelity section reduced model honesty to
  one predicted/measured ratio per destination; a model can average out
  perfectly and still *order* candidates wrongly, which is what the GA
  actually consumes. :func:`spearman` / :func:`kendall` (tau-b, with tie
  correction) correlate modeled vs measured fitness over the search's
  final population.

Population-shape metrics (:func:`allele_entropy`, :func:`median`) feed
the per-generation trace events in :mod:`repro.offload.trace`.

Everything here is pure math except :func:`winner_stability`, which
drives :func:`repro.core.ga.run_ga` — it never touches the pipeline, so
the pipeline can call it for any evaluator it chooses (the modeled one;
re-running a *measured* search would re-pay real wall-clock).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import ga
from repro.core.evalpool import EvalPool, FitnessCache, evaluator_fingerprint

Genes = Tuple[int, ...]


# ---------------------------------------------------------------------------
# rank statistics (pure; hypothesis-tested in tests/test_quality_properties)
# ---------------------------------------------------------------------------


def ranks(xs: Sequence[float]) -> List[float]:
    """Fractional (average) ranks, 1-based; ties share their mean rank."""
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    out = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        r = (i + j) / 2.0 + 1.0
        for k in range(i, j + 1):
            out[order[k]] = r
        i = j + 1
    return out


def spearman(xs: Sequence[float], ys: Sequence[float]) -> Optional[float]:
    """Spearman rank correlation (Pearson on fractional ranks, the
    standard tie handling). ``None`` when undefined: fewer than two
    pairs, or either side constant (zero rank variance)."""
    if len(xs) != len(ys):
        raise ValueError(f"length mismatch: {len(xs)} vs {len(ys)}")
    n = len(xs)
    if n < 2:
        return None
    rx, ry = ranks(xs), ranks(ys)
    mx, my = sum(rx) / n, sum(ry) / n
    sxx = sum((a - mx) ** 2 for a in rx)
    syy = sum((b - my) ** 2 for b in ry)
    if sxx == 0.0 or syy == 0.0:
        return None
    sxy = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    return sxy / math.sqrt(sxx * syy)


def kendall(xs: Sequence[float], ys: Sequence[float]) -> Optional[float]:
    """Kendall rank correlation, tau-b (tie-corrected). ``None`` when
    undefined (n < 2 or either side constant). O(n^2) — final GA
    populations are tens of individuals, not millions."""
    if len(xs) != len(ys):
        raise ValueError(f"length mismatch: {len(xs)} vs {len(ys)}")
    n = len(xs)
    if n < 2:
        return None
    concordant = discordant = 0
    ties_x = ties_y = 0  # pairs tied in x (resp. y), tied-in-both counted in each
    for i in range(n):
        for j in range(i + 1, n):
            dx = xs[i] - xs[j]
            dy = ys[i] - ys[j]
            if dx == 0:
                ties_x += 1
            if dy == 0:
                ties_y += 1
            if dx == 0 or dy == 0:
                continue
            if (dx > 0) == (dy > 0):
                concordant += 1
            else:
                discordant += 1
    n0 = n * (n - 1) // 2
    denom = math.sqrt((n0 - ties_x) * (n0 - ties_y))
    if denom == 0.0:
        return None
    return (concordant - discordant) / denom


def rank_section(
    modeled: Sequence[float],
    measured: Sequence[float],
    *,
    scale: Optional[str] = None,
    reference: Optional[str] = None,
) -> Dict[str, Any]:
    """The modeled-vs-measured discrimination record the report stage and
    sweep cells carry: both correlations over one candidate set, plus the
    distinct-value counts that explain a ``None`` (a side with a single
    distinct value cannot be ranked)."""
    out: Dict[str, Any] = {
        "n": len(modeled),
        "spearman": spearman(modeled, measured),
        "kendall": kendall(modeled, measured),
        "distinct_modeled": len(set(modeled)),
        "distinct_measured": len(set(measured)),
    }
    if scale is not None:
        out["scale"] = scale
    if reference is not None:
        out["reference"] = reference
    if out["spearman"] is None:
        out["note"] = (
            "undefined: fewer than two candidates or a constant side "
            "(no ranking to correlate)"
        )
    return out


# ---------------------------------------------------------------------------
# population-shape metrics (feed the per-generation trace events)
# ---------------------------------------------------------------------------


def median(xs: Sequence[float]) -> float:
    if not xs:
        raise ValueError("median of an empty sequence")
    s = sorted(float(x) for x in xs)
    mid = len(s) // 2
    if len(s) % 2:
        return s[mid]
    return (s[mid - 1] + s[mid]) / 2.0


def allele_entropy(population: Sequence[Sequence[int]], alleles: int) -> float:
    """Mean per-gene Shannon entropy of the population's allele
    distribution, normalized by log2(alleles) into [0, 1]: 0 = every
    gene fixed (converged population), 1 = uniform over all alleles at
    every gene. Empty populations, empty genomes and single-allele
    alphabets score 0 (nothing left to vary)."""
    if not population or alleles < 2:
        return 0.0
    n = len(population[0])
    if n == 0:
        return 0.0
    m = len(population)
    total = 0.0
    for g in range(n):
        counts: Dict[int, int] = {}
        for ind in population:
            a = int(ind[g])
            counts[a] = counts.get(a, 0) + 1
        total -= sum(
            (c / m) * math.log2(c / m) for c in counts.values() if c
        )
    return total / (n * math.log2(alleles))


# ---------------------------------------------------------------------------
# pass@k winner stability
# ---------------------------------------------------------------------------


def stability_metrics(
    winners: Sequence[Dict[str, Any]], window: float
) -> Dict[str, Any]:
    """Summarize per-seed winners as pass@k + spread (pure, testable).

    ``winners`` rows carry at least ``seed``, ``best_time_s`` and
    ``best_genes``. A seed *passes* when its best time lands within the
    relative ``window`` of the best seed's best time.
    """
    if not winners:
        raise ValueError("stability_metrics needs at least one winner")
    if window < 0:
        raise ValueError(f"window must be >= 0: {window}")
    times = [float(w["best_time_s"]) for w in winners]
    best, worst = min(times), max(times)
    passed = sum(1 for t in times if t <= best * (1.0 + window))
    return {
        "k": len(winners),
        "window": window,
        "pass_at_k": passed / len(winners),
        "best_time_s": best,
        "worst_time_s": worst,
        "rel_spread": (worst / best - 1.0) if best > 0 else 0.0,
        "distinct_winners": len(
            {tuple(int(g) for g in w["best_genes"]) for w in winners}
        ),
        "winners": [dict(w) for w in winners],
    }


def winner_stability(
    evaluator: Callable[[Genes], float],
    gene_length: int,
    params: ga.GAParams,
    *,
    k: int,
    window: float,
    seeds: Optional[Sequence[Genes]] = None,
    workers: int = 1,
    cache_path: Optional[str] = None,
    recorded: Optional[Tuple[Sequence[int], float]] = None,
    on_search: Optional[Callable[[Dict[str, Any]], None]] = None,
) -> Dict[str, Any]:
    """pass@k winner stability: the search at GA seeds ``params.seed ..
    params.seed + k - 1``, summarized by :func:`stability_metrics`.

    ``recorded`` is the already-run search's ``(best_genes, best_time_s)``
    for ``params.seed`` itself — reused instead of re-run (pass it only
    when that search used THIS evaluator). Each re-search opens the
    persistent ``cache_path`` under the evaluator's fingerprint, so
    genomes the main search already measured are cache hits. Evaluation
    runs on a thread pool: the whole point is that the evaluator is the
    cheap modeled one.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1: {k}")
    winners: List[Dict[str, Any]] = []
    for i in range(k):
        seed = params.seed + i
        if i == 0 and recorded is not None:
            genes, t = recorded
            winners.append({
                "seed": seed,
                "best_time_s": float(t),
                "best_genes": [int(g) for g in genes],
                "reused": True,
                "evaluations": 0,
                "cache_hits": 0,
            })
            continue
        p = dataclasses.replace(params, seed=seed)
        cache = None
        if cache_path:
            cache = FitnessCache(
                cache_path, fingerprint=evaluator_fingerprint(evaluator)
            )
        try:
            with EvalPool(evaluator, workers=workers, cache=cache) as pool:
                res = ga.run_ga(
                    None, gene_length, p, pool=pool, seeds=seeds or None
                )
                tot = pool.totals()
        finally:
            if cache is not None:
                cache.close()
        row = {
            "seed": seed,
            "best_time_s": float(res.best_time_s),
            "best_genes": [int(g) for g in res.best_genes],
            "reused": False,
            "evaluations": int(tot.evaluated),
            "cache_hits": int(tot.cache_hits),
        }
        winners.append(row)
        if on_search is not None:
            on_search(row)
    return stability_metrics(winners, window)
