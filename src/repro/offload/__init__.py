"""repro.offload — the paper's whole flow as one staged pipeline API.

The source paper's contribution is a single automated sequence: extract
loop statements, assign directives, GA-search placements against a
verification environment, reduce transfers, and check results (PCAST).
This package is that sequence as a reusable surface:

- :class:`OffloadSpec` — one frozen, JSON-round-trippable description of
  a search (program, binary/mixed mode, method, GA budget, pool/cache
  settings, verify tolerances);
- :class:`Offloader` — the facade running the named stages ``analyze ->
  seed -> search -> verify -> report``;
- :class:`OffloadResult` — the per-stage artifact that saves, reloads,
  and resumes (completed stages skip; interrupted searches resume warm
  through the persistent JSONL fitness cache);
- ``python -m repro.offload`` — the CLI (``run`` / ``resume`` /
  ``report`` / ``trace`` / ``calibrate`` / ``sweep``, ``--smoke`` for
  CI; every verb's ``--help`` epilog documents its exit codes);
- :mod:`repro.offload.sweep` — the model-zoo sweep driver: the
  programs x machines x modes matrix run resumably cell-by-cell, the
  append-only ``BENCH_sweep.json`` trajectory, the leaderboard and the
  regression flagger (docs/benchmarks.md);
- :mod:`repro.offload.trace` / :mod:`repro.offload.quality` — the
  observability layer (docs/observability.md): a deterministic JSONL
  trace of span/event records written next to every artifact and
  embedded in it by digest, plus the pass@k winner-stability and
  modeled-vs-measured rank-correlation metrics the report stage and
  every sweep cell surface;
- :mod:`repro.offload.calibrate` — measured model calibration behind
  ``OffloadSpec.fidelity`` (imported lazily: modeled pipelines never
  touch it).

Every example, benchmark and calibration script drives this facade; with
spec defaults its searches are byte-identical to the pre-redesign
hand-wired paths (parity-tested).
"""
from repro.offload.pipeline import Offloader, render_report
from repro.offload.result import (
    STAGES,
    OffloadResult,
    StageFailure,
    StageRecord,
)
from repro.offload.spec import (
    FIDELITIES,
    GAControls,
    METHODS,
    MODES,
    OffloadSpec,
)

__all__ = [
    "FIDELITIES",
    "GAControls",
    "METHODS",
    "MODES",
    "Offloader",
    "OffloadResult",
    "OffloadSpec",
    "STAGES",
    "StageFailure",
    "StageRecord",
    "render_report",
]
