"""Model-zoo sweep: the cross-product evaluation matrix + BENCH trajectory.

The paper's core claim is breadth — the improved offload method works
"in multiple applications" — so the repo needs a driver that runs *all*
of them, on *every* modeled machine, in one resumable invocation:

    {miniapps + arch:<name> programs} x {machine registries} x {modes}

Each feasible cell runs through the ordinary :class:`Offloader` pipeline
into its own ``OffloadResult`` artifact under a sweep directory, with
one shared persistent JSONL fitness cache (evaluator fingerprints keep
foreign entries apart, so sharing one file is safe and is the point: a
re-sweep is mostly cache hits, and a killed sweep resumes cell-by-cell
— completed artifacts are skipped outright with zero fresh
measurements).

Every sweep appends exactly one schema-versioned **trajectory point** to
a ``BENCH_sweep.json`` file (default: repo root): git hash, timestamp,
the matrix, one summary record per cell (winner fitness, speedup vs
all-host, search cost, cache-hit rate, residency pressure, block
substitutions) and
aggregate totals. The trajectory is append-only — points are never
rewritten — which makes it the PR-over-PR perf record the ROADMAP's
re-anchor process reads.

On top of the trajectory, :func:`render_leaderboard` renders the
best placement per program per machine with deltas against the previous
point, and :func:`flag_regressions` compares consecutive points
cell-by-cell: a cell whose winner fitness worsened by strictly more
than ``rel_tolerance`` (default 5%) is flagged, and the CLI
(``python -m repro.offload sweep``) turns flags into a nonzero exit
code so nightly CI fails loudly. See docs/benchmarks.md for the full
schema table and the cookbook.

Feasibility rules (recorded per skipped cell, never silent):

- ``arch:<name>`` programs are binary-only (``OffloadSpec`` rejects
  mixed mode for them) and their analytic plan evaluator is
  machine-independent, so each arch runs once, pinned to the default
  machine — the other (machine, arch) cells are recorded as skipped
  duplicates rather than tripling the budget for identical searches.
- Binary miniapp cells price against a :class:`HardwareModel`, so they
  only exist on machines whose registry name is also a hardware-model
  name (``p4000-constrained`` shares the P4000's rate constants and is
  skipped in binary mode).
- Mixed cells search the machine's full destination set (host first),
  taken from its registry.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import subprocess
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.offload.pipeline import Offloader
from repro.offload.result import (
    OffloadResult,
    StageFailure,
    atomic_json_save,
)
from repro.offload.spec import (
    GAControls,
    MEASURED_PROGRAMS,
    MIXED_SMOKE_BUDGET,
    MODES,
    OffloadSpec,
)

SWEEP_SCHEMA = "repro.offload.sweep"
SWEEP_SCHEMA_VERSION = 1

# per-POINT schema version (the FILE schema above stays 1 so existing
# trajectories keep loading). v2 points additionally carry a per-cell
# "quality" key — the report stage's pass@k winner stability and
# modeled-vs-measured rank correlation (docs/observability.md) — and
# append cleanly after v1 points: readers treat a missing "v" as 1.
# v3 points additionally carry a per-cell "blocks" key — the
# function-block substitution summary (matched blocks, substituted
# count, kernel@destination rows; docs/blocks.md), None for cells the
# feature does not apply to.
# v4 points additionally carry "throughput" inside each ok cell's
# "search" summary — modeled-search genomes/sec (population x
# generations / search wall), the number the fast-search knobs
# (OffloadSpec.ga.batch / .steady_state) exist to raise.
SWEEP_POINT_VERSION = 4

# default trajectory file (repo root when invoked from there) and the
# default per-cell artifact directories; smoke and full matrices get
# separate directories so a smoke artifact can never satisfy (and
# silently shrink) a full-budget cell on resume
DEFAULT_TRAJECTORY = "BENCH_sweep.json"
DEFAULT_SWEEP_DIR = ".sweep"
DEFAULT_SMOKE_DIR = ".sweep-smoke"

# a cell regresses when its winner fitness worsens by STRICTLY more
# than this relative tolerance vs the previous point (exactly at the
# edge is not a regression — modeled searches are deterministic, so the
# tolerance only absorbs intentional small model/constant changes)
DEFAULT_REL_TOLERANCE = 0.05

# the machine every machine-independent arch search is pinned to, and
# the default machine of the smoke matrix
DEFAULT_MACHINE = "quadro-p4000"

# CI fast-tier smoke matrix: one binary miniapp, one mixed (k-ary,
# warm-started) miniapp, one arch program — the three adapter families
# through the whole pipeline in seconds
SMOKE_CELLS: Tuple[Tuple[str, str, str], ...] = (
    ("himeno", DEFAULT_MACHINE, "binary"),
    ("hetero", DEFAULT_MACHINE, "mixed"),
    ("arch:stablelm-3b", DEFAULT_MACHINE, "binary"),
)


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One matrix cell: a program searched on a machine in a mode."""

    program: str
    hw: str
    mode: str

    @property
    def id(self) -> str:
        return f"{self.program}:{self.hw}:{self.mode}"

    @property
    def slug(self) -> str:
        """Filesystem-safe artifact stem for this cell."""
        return self.id.replace(":", "-").replace("/", "-")


# ---------------------------------------------------------------------------
# matrix enumeration
# ---------------------------------------------------------------------------


def default_programs() -> List[str]:
    """Every sweepable program: the paper miniapps plus the whole
    model zoo as ``arch:<name>`` plan searches."""
    from repro.configs import ARCH_IDS
    from repro.core import miniapps

    return sorted(miniapps.MINIAPPS) + [f"arch:{a}" for a in ARCH_IDS]


def default_machines() -> List[str]:
    from repro.destinations import REGISTRIES

    return sorted(REGISTRIES)


def enumerate_matrix(
    programs: Optional[Sequence[str]] = None,
    machines: Optional[Sequence[str]] = None,
    modes: Sequence[str] = MODES,
) -> Tuple[List[SweepCell], List[Dict[str, str]]]:
    """The cross product as (feasible cells, skipped cells with reasons).

    Every (program, machine, mode) combination appears in exactly one of
    the two lists — infeasible cells are recorded, never dropped
    silently.
    """
    from repro.configs import ARCH_IDS
    from repro.core import miniapps
    from repro.destinations import REGISTRIES
    from repro.offload.programs import HW_MODELS

    programs = list(programs) if programs is not None else default_programs()
    machines = list(machines) if machines is not None else default_machines()
    for m in modes:
        if m not in MODES:
            raise ValueError(f"unknown mode {m!r}; have {MODES}")
    known_progs = set(miniapps.MINIAPPS) | {f"arch:{a}" for a in ARCH_IDS}
    unknown = [p for p in programs if p not in known_progs]
    if unknown:
        raise ValueError(
            f"unknown programs {unknown}; have {sorted(known_progs)}"
        )
    unknown = [m for m in machines if m not in REGISTRIES
               and m not in HW_MODELS]
    if unknown:
        raise ValueError(
            f"unknown machines {unknown}; have registries "
            f"{sorted(REGISTRIES)} and hardware models {sorted(HW_MODELS)}"
        )
    cells: List[SweepCell] = []
    skipped: List[Dict[str, str]] = []
    for prog in programs:
        for hw in machines:
            for mode in modes:
                cell = SweepCell(prog, hw, mode)
                reason = None
                if prog.startswith("arch:"):
                    if mode == "mixed":
                        reason = "arch programs are binary-only"
                    elif hw != DEFAULT_MACHINE and DEFAULT_MACHINE in machines:
                        reason = (
                            "arch plan evaluator is machine-independent; "
                            f"scored once on {DEFAULT_MACHINE}"
                        )
                elif mode == "binary" and hw not in HW_MODELS:
                    reason = (
                        "binary mode prices against a HardwareModel; "
                        f"registry {hw!r} has no rate-constant entry"
                    )
                if reason is None:
                    cells.append(cell)
                else:
                    skipped.append({"id": cell.id, "reason": reason})
    return cells, skipped


def smoke_matrix() -> Tuple[List[SweepCell], List[Dict[str, str]]]:
    """The fixed 3-cell CI fast-tier matrix (one per adapter family)."""
    return [SweepCell(*c) for c in SMOKE_CELLS], []


def cell_spec(
    cell: SweepCell,
    *,
    smoke: bool = False,
    cache: Optional[str] = None,
    workers: int = 1,
    seed: int = 0,
) -> OffloadSpec:
    """The :class:`OffloadSpec` a cell runs under. Mixed cells search
    the machine's full destination set (host first) warm-started, with
    the smoke budget trim under ``smoke``; binary/arch budgets are
    already seconds-scale on the analytic evaluators."""
    kw: Dict[str, Any] = dict(
        program=cell.program,
        mode=cell.mode,
        hw=cell.hw,
        cache=cache,
        workers=workers,
        seed=seed,
    )
    if cell.mode == "mixed":
        from repro.destinations import get_registry

        reg = get_registry(cell.hw)
        kw["destinations"] = tuple(d.name for d in reg.destinations)
        kw["warm_start"] = True
        # mixed cells search with the block-substitution dimension on:
        # the sweep's job is the best placement the toolchain can find,
        # and v3 points record what substitution bought per cell
        kw["blocks"] = True
        if smoke:
            kw["population"], kw["generations"] = MIXED_SMOKE_BUDGET
    if cell.program in MEASURED_PROGRAMS:
        # runnable programs: wall-clock the two winner projections so
        # every sweep point records modeled-vs-measured rank fidelity
        kw["ga"] = GAControls(rank_probe=True)
    return OffloadSpec(**kw)


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------


def _git_hash() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    return out.stdout.strip() or None if out.returncode == 0 else None


def _quality_summary(art: Optional[OffloadResult]) -> Optional[Dict]:
    """Compact per-cell copy of the report stage's quality section
    (pass@k stability + rank correlation), the v2 trajectory field. A
    gate-failed report stage still recorded its payload, so its quality
    numbers surface here too."""
    if art is None or "report" not in art.stages:
        return None
    q = art.stages["report"].payload.get("quality")
    if not q:
        return None
    out: Dict[str, Any] = {}
    st = q.get("stability") or {}
    out["stability"] = {"skipped": st["skipped"]} if "skipped" in st else {
        k: st[k] for k in ("k", "pass_at_k", "rel_spread",
                           "distinct_winners") if k in st
    }
    rk = q.get("rank") or {}
    out["rank"] = {"skipped": rk["skipped"]} if "skipped" in rk else {
        k: rk.get(k) for k in ("n", "spearman", "kendall")
    }
    return out


def _blocks_summary(art: Optional[OffloadResult]) -> Optional[Dict]:
    """Compact per-cell block-substitution record (docs/blocks.md), the
    v3 trajectory field: how many library blocks matched, how many the
    winner substituted, and which kernel landed where. None when the
    cell ran without the feature (binary/arch cells, zero-match mixed
    programs)."""
    if art is None or "analyze" not in art.stages:
        return None
    blocks = art.stages["analyze"].payload.get("blocks")
    if not blocks:
        return None
    out: Dict[str, Any] = {
        "matches": len(blocks.get("matches", ())),
        "substituted": 0,
        "kernels": [],
    }
    if "search" in art.stages:
        subs = art.stages["search"].payload.get("substitutions") or ()
        act = [s for s in subs if s.get("active")]
        out["substituted"] = len(act)
        out["kernels"] = [f"{s['entry']}@{s['destination']}" for s in act]
    return out


def _cell_record(
    cell: SweepCell,
    art: Optional[OffloadResult],
    *,
    status: str,
    fresh: int,
    resumed: bool,
    wall_s: float,
    error: Optional[str] = None,
) -> Dict[str, Any]:
    rec: Dict[str, Any] = {
        "id": cell.id,
        "program": cell.program,
        "hw": cell.hw,
        "mode": cell.mode,
        "status": status,  # "ok" | "failed"
        "resumed": resumed,  # artifact was already complete: cell skipped
        "fresh_measurements": int(fresh),  # paid in THIS invocation
        "wall_s": float(wall_s),
        "error": error,
        "best_time_s": None,
        "baseline_s": None,
        "speedup": None,
        "search": None,
        "residency": None,
        "quality": _quality_summary(art),
        "blocks": _blocks_summary(art),
    }
    if art is None:
        return rec
    rec["best_time_s"] = art.best_time_s
    rec["baseline_s"] = art.baseline_time_s
    rec["speedup"] = art.speedup
    if art.completed("search"):
        s = art.stage("search").payload
        looked_up = int(s["evaluations"]) + int(s["cache_hits"])
        rec["search"] = {
            "evaluations": int(s["evaluations"]),
            "cache_hits": int(s["cache_hits"]),
            "hit_rate": float(s["cache_hits"]) / looked_up
            if looked_up else 0.0,
            "wall_s": float(s["wall_s"]),
            "generations": int(s["ga"]["generations"]),
            "population": int(s["ga"]["population"]),
            # genomes/sec the search sustained (submissions, not fresh
            # measurements: cache hits are part of the sustained rate)
            "throughput": (
                int(s["ga"]["generations"]) * int(s["ga"]["population"])
                / float(s["wall_s"])
            ) if float(s["wall_s"]) > 0 else None,
        }
        r = s.get("residency")
        if r is not None:
            rec["residency"] = {
                "evicted_bytes": float(r["evicted_bytes"]),
                "spilled_bytes": float(r["spilled_bytes"]),
                "oversubscribed": list(r.get("oversubscribed", ())),
            }
    return rec


def _totals(cells: List[Dict[str, Any]], wall_s: float) -> Dict[str, Any]:
    ok = [c for c in cells if c["status"] == "ok"]
    speedups = [c["speedup"] for c in ok if c["speedup"]]
    fresh = sum(c["fresh_measurements"] for c in cells)
    hits = sum(c["search"]["cache_hits"] for c in ok if c["search"])
    looked_up = fresh + hits
    return {
        "n_cells": len(cells),
        "n_ok": len(ok),
        "n_failed": sum(1 for c in cells if c["status"] == "failed"),
        "n_resumed": sum(1 for c in cells if c["resumed"]),
        "fresh_measurements": int(fresh),
        "cache_hits": int(hits),
        "hit_rate": float(hits) / looked_up if looked_up else 0.0,
        "geomean_speedup": float(
            math.exp(sum(math.log(s) for s in speedups) / len(speedups))
        ) if speedups else None,
        "wall_s": float(wall_s),
    }


def run_sweep(
    cells: Sequence[SweepCell],
    skipped: Sequence[Dict[str, str]] = (),
    *,
    out_dir: str = DEFAULT_SWEEP_DIR,
    cache: Optional[str] = None,
    workers: int = 1,
    smoke: bool = False,
    seed: int = 0,
    label: Optional[str] = None,
    progress: Optional[Callable[[str], None]] = None,
) -> Dict[str, Any]:
    """Run every cell (resumably) and return one trajectory point.

    Per cell, in order:

    - an existing COMPLETE artifact under ``out_dir`` short-circuits the
      cell entirely (``resumed=True``, zero fresh measurements);
    - an existing partial artifact is continued via
      :meth:`Offloader.resume` (its embedded spec is authoritative);
    - otherwise a fresh pipeline runs under :func:`cell_spec`.

    All cells share one JSONL fitness cache (default
    ``<out_dir>/fitness.jsonl``); evaluator fingerprints keep entries
    from crossing between cells that must not share. A cell's
    :class:`StageFailure` is recorded (status="failed") and the sweep
    continues — one bad cell must not lose the rest of the matrix.
    """
    say = progress or (lambda _line: None)
    os.makedirs(out_dir, exist_ok=True)
    cache = cache or os.path.join(out_dir, "fitness.jsonl")
    t0 = time.perf_counter()
    records: List[Dict[str, Any]] = []
    for i, cell in enumerate(cells):
        c0 = time.perf_counter()
        art_path = os.path.join(out_dir, f"{cell.slug}.offload.json")
        art: Optional[OffloadResult] = None
        if os.path.exists(art_path):
            art = OffloadResult.load(art_path)
        if art is not None and art.completed("report"):
            rec = _cell_record(cell, art, status="ok", fresh=0,
                               resumed=True,
                               wall_s=time.perf_counter() - c0)
            records.append(rec)
            say(f"[{i + 1}/{len(cells)}] {cell.id}: already complete "
                f"(best {rec['best_time_s']:.4g}s) — skipped")
            continue
        if art is not None:
            off = Offloader.resume(art_path)
        else:
            spec = cell_spec(cell, smoke=smoke, cache=cache,
                             workers=workers, seed=seed)
            off = Offloader(spec, artifact_path=art_path)
        status, error = "ok", None
        try:
            off.run()
        except StageFailure as e:
            status, error = "failed", str(e)
        except Exception as e:  # noqa: BLE001 — sweep must finish
            status, error = "failed", repr(e)
        fresh = 0
        if off.result.completed("search"):
            fresh = int(off.result.stage("search").payload["evaluations"])
        rec = _cell_record(cell, off.result, status=status, fresh=fresh,
                           resumed=False, error=error,
                           wall_s=time.perf_counter() - c0)
        records.append(rec)
        if status == "ok":
            say(f"[{i + 1}/{len(cells)}] {cell.id}: best "
                f"{rec['best_time_s']:.4g}s "
                f"({rec['speedup']:.1f}x over all-host, "
                f"{fresh} fresh measurements)")
        else:
            say(f"[{i + 1}/{len(cells)}] {cell.id}: FAILED — {error}")
    return {
        "v": SWEEP_POINT_VERSION,
        "git": _git_hash(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "label": label,
        "smoke": bool(smoke),
        "matrix": {
            "cells": [c.id for c in cells],
            "skipped": list(skipped),
        },
        "cells": records,
        "totals": _totals(records, time.perf_counter() - t0),
    }


# ---------------------------------------------------------------------------
# trajectory persistence (BENCH_sweep.json)
# ---------------------------------------------------------------------------

_POINT_KEYS = ("git", "timestamp", "label", "smoke", "matrix", "cells",
               "totals")
_CELL_KEYS = ("id", "program", "hw", "mode", "status", "resumed",
              "fresh_measurements", "wall_s", "best_time_s", "baseline_s",
              "speedup")


def validate_point(point: Dict[str, Any]) -> None:
    """Raise ``ValueError`` naming every missing field — the writer-side
    schema gate (``Trajectory.append`` runs it on every point)."""
    problems = [f"point missing key {k!r}" for k in _POINT_KEYS
                if k not in point]
    cells = point.get("cells")
    if not isinstance(cells, list):
        problems.append("point 'cells' must be a list")
        cells = []
    v = point.get("v", 1)  # v1 points predate the "v" key
    for i, c in enumerate(cells):
        problems += [f"cell[{i}] missing key {k!r}" for k in _CELL_KEYS
                     if k not in c]
        if c.get("status") not in ("ok", "failed"):
            problems.append(f"cell[{i}] status must be ok|failed: "
                            f"{c.get('status')!r}")
        if v >= 2 and "quality" not in c:
            problems.append(f"cell[{i}] missing key 'quality' "
                            f"(required for v{v} points)")
        if v >= 3 and "blocks" not in c:
            problems.append(f"cell[{i}] missing key 'blocks' "
                            f"(required for v{v} points)")
        if (v >= 4 and c.get("status") == "ok"
                and isinstance(c.get("search"), dict)
                and "throughput" not in c["search"]):
            problems.append(f"cell[{i}] search missing key 'throughput' "
                            f"(required for v{v} points)")
    if problems:
        raise ValueError("invalid trajectory point: " + "; ".join(problems))


@dataclasses.dataclass
class Trajectory:
    """The append-only BENCH trajectory: an ordered list of points."""

    points: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    path: Optional[str] = None

    @classmethod
    def load(cls, path: str) -> "Trajectory":
        """Load a trajectory file; a missing file is an empty trajectory
        (the first sweep creates it), anything else must carry the
        schema tag + version."""
        if not os.path.exists(path):
            return cls(points=[], path=path)
        with open(path, "r", encoding="utf-8") as fh:
            d = json.load(fh)
        if d.get("schema") != SWEEP_SCHEMA or \
                d.get("v") != SWEEP_SCHEMA_VERSION:
            raise ValueError(
                f"{path} is not a {SWEEP_SCHEMA}/v{SWEEP_SCHEMA_VERSION} "
                f"trajectory (schema={d.get('schema')!r}, v={d.get('v')!r})"
            )
        return cls(points=list(d.get("points", [])), path=path)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": SWEEP_SCHEMA,
            "v": SWEEP_SCHEMA_VERSION,
            "points": self.points,
        }

    def save(self, path: Optional[str] = None) -> Optional[str]:
        path = path or self.path
        if path is None:
            return None
        self.path = path
        return atomic_json_save(path, self.to_dict())

    @property
    def last(self) -> Optional[Dict[str, Any]]:
        return self.points[-1] if self.points else None

    @property
    def previous(self) -> Optional[Dict[str, Any]]:
        return self.points[-2] if len(self.points) >= 2 else None


def append_point(path: str, point: Dict[str, Any]) -> Trajectory:
    """Validate ``point``, merge it onto whatever is on disk at ``path``
    right now (append-only: existing points are never rewritten or
    dropped), save atomically, and return the merged trajectory."""
    validate_point(point)
    traj = Trajectory.load(path)
    traj.points.append(point)
    traj.save()
    return traj


# ---------------------------------------------------------------------------
# regression flagging + leaderboard
# ---------------------------------------------------------------------------


def flag_regressions(
    prev: Optional[Dict[str, Any]],
    new: Dict[str, Any],
    rel_tolerance: float = DEFAULT_REL_TOLERANCE,
) -> List[Dict[str, Any]]:
    """Cells of ``new`` whose winner fitness worsened by strictly more
    than ``rel_tolerance`` relative to the same cell id in ``prev``.

    Semantics (documented in docs/benchmarks.md, tested at the edges):

    - only cells with status="ok" and a recorded winner in BOTH points
      compare — a failed or new cell is never a *regression* (failures
      carry their own exit code);
    - ``new_s > prev_s * (1 + tol)`` flags; equality at the boundary
      does not;
    - improvements are never flagged, whatever their size.
    """
    if prev is None:
        return []
    if rel_tolerance < 0:
        raise ValueError(f"rel_tolerance must be >= 0: {rel_tolerance}")
    prev_by_id = {
        c["id"]: c for c in prev.get("cells", ())
        if c.get("status") == "ok" and c.get("best_time_s")
    }
    flags = []
    for c in new.get("cells", ()):
        if c.get("status") != "ok" or not c.get("best_time_s"):
            continue
        p = prev_by_id.get(c["id"])
        if p is None:
            continue
        prev_s, new_s = float(p["best_time_s"]), float(c["best_time_s"])
        if new_s > prev_s * (1.0 + rel_tolerance):
            flags.append({
                "id": c["id"],
                "prev_best_s": prev_s,
                "new_best_s": new_s,
                "ratio": new_s / prev_s,
                "rel_tolerance": rel_tolerance,
            })
    return flags


def _delta_text(prev_cell: Optional[Dict[str, Any]],
                cell: Dict[str, Any]) -> str:
    if prev_cell is None or not prev_cell.get("best_time_s") \
            or not cell.get("best_time_s"):
        return "new"
    rel = cell["best_time_s"] / prev_cell["best_time_s"] - 1.0
    return f"{rel:+.1%}"


def render_leaderboard(
    traj: Trajectory,
    rel_tolerance: float = DEFAULT_REL_TOLERANCE,
) -> str:
    """The best placement per program per machine from the trajectory's
    last point, with per-cell deltas against the previous point and the
    regression verdict (the same comparison the exit code reflects)."""
    point = traj.last
    if point is None:
        return "BENCH trajectory is empty — run a sweep first."
    prev = traj.previous
    prev_by_id = {c["id"]: c for c in (prev or {}).get("cells", ())}
    ok = [c for c in point["cells"] if c["status"] == "ok"]
    rows = [
        f"== BENCH leaderboard @ {point.get('git') or 'unknown'} "
        f"({point['timestamp']}, point {len(traj.points)}"
        + (f", label {point['label']!r}" if point.get("label") else "")
        + (", smoke matrix" if point.get("smoke") else "")
        + ") =="
    ]
    for hw in sorted({c["hw"] for c in ok}):
        rows.append(f"machine {hw}:")
        rows.append(f"  {'program':28s} {'mode':7s} {'best_s':>10s} "
                    f"{'speedup':>8s} {'vs prev':>8s}")
        by_prog: Dict[str, Dict[str, Any]] = {}
        for c in ok:
            if c["hw"] != hw:
                continue
            cur = by_prog.get(c["program"])
            if cur is None or (c["best_time_s"] or float("inf")) < \
                    (cur["best_time_s"] or float("inf")):
                by_prog[c["program"]] = c
        for prog in sorted(
            by_prog, key=lambda p: -(by_prog[p]["speedup"] or 0.0)
        ):
            c = by_prog[prog]
            rows.append(
                f"  {prog:28s} {c['mode']:7s} {c['best_time_s']:10.4g} "
                f"{(c['speedup'] or 0.0):7.1f}x "
                f"{_delta_text(prev_by_id.get(c['id']), c):>8s}"
            )
    quality_lines = []
    for c in ok:
        q = c.get("quality") or {}
        st = q.get("stability") or {}
        rk = q.get("rank") or {}
        parts = []
        if "pass_at_k" in st:
            parts.append(f"pass@{st['k']} {st['pass_at_k']:.0%} "
                         f"(spread +{st['rel_spread']:.1%}, "
                         f"{st['distinct_winners']} winners)")
        if rk.get("spearman") is not None:
            parts.append(f"spearman {rk['spearman']:+.2f} "
                         f"over {rk['n']}")
        if parts:
            quality_lines.append(f"  {c['id']}: " + ", ".join(parts))
    if quality_lines:
        rows.append("search quality (v2 points; docs/observability.md):")
        rows.extend(quality_lines)
    block_lines = []
    for c in ok:
        b = c.get("blocks")
        if not b or not b.get("matches"):
            continue
        kern = ", ".join(b.get("kernels", ())) or "none"
        block_lines.append(
            f"  {c['id']}: {b.get('substituted', 0)}/{b['matches']} "
            f"blocks substituted ({kern})"
        )
    if block_lines:
        rows.append("block substitutions (v3 points; docs/blocks.md):")
        rows.extend(block_lines)
    failed = [c for c in point["cells"] if c["status"] == "failed"]
    for c in failed:
        rows.append(f"FAILED {c['id']}: {c.get('error')}")
    tot = point["totals"]
    rows.append(
        f"totals: {tot['n_ok']}/{tot['n_cells']} cells ok"
        + (f", {tot['n_resumed']} resumed" if tot["n_resumed"] else "")
        + f", {tot['fresh_measurements']} fresh measurements, "
        f"hit-rate {tot['hit_rate']:.0%}"
        + (f", geomean speedup {tot['geomean_speedup']:.2f}x"
           if tot.get("geomean_speedup") else "")
        + f", wall {tot['wall_s']:.1f}s"
    )
    flags = flag_regressions(prev, point, rel_tolerance)
    if flags:
        rows.append(f"REGRESSIONS (tolerance {rel_tolerance:.0%}):")
        for f in flags:
            rows.append(
                f"  {f['id']}: {f['prev_best_s']:.4g}s -> "
                f"{f['new_best_s']:.4g}s ({f['ratio']:.3f}x)"
            )
    elif prev is not None:
        rows.append(f"regressions (tolerance {rel_tolerance:.0%}): none")
    else:
        rows.append("regressions: no previous point to compare against")
    return "\n".join(rows)
