"""OffloadResult: the pipeline's JSON-serializable, resumable artifact.

One artifact per end-to-end run: the :class:`OffloadSpec` plus one
:class:`StageRecord` per completed (or failed) stage, in pipeline order.
``save``/``load`` round-trip the whole thing through JSON, and the
:class:`~repro.offload.pipeline.Offloader` skips stages already recorded
as done — so a killed run resumed from its artifact re-enters the
pipeline exactly where it stopped, and a *search* interrupted mid-GA
resumes warm through the spec's persistent JSONL fitness cache (the
stage re-runs, but every already-measured genome is a cache hit).

Stage payloads are plain JSON values (genes as lists of ints) so the
artifact is greppable/diffable and survives module refactors.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Optional, Tuple

from repro.offload.spec import OffloadSpec

_ARTIFACT_VERSION = 1

# pipeline order; Offloader runs exactly these, in this order. The
# calibrate stage comes FIRST: with spec.fidelity="calibrated" it
# measures + fits the machine the analyze baseline and the search both
# price against; for every other fidelity it records itself as not
# applicable (so artifacts stay uniform and resume stays positional).
STAGES: Tuple[str, ...] = (
    "calibrate", "analyze", "seed", "search", "verify", "report"
)


class StageFailure(RuntimeError):
    """A pipeline stage failed (recorded in the artifact before raising)."""

    def __init__(self, stage: str, message: str):
        super().__init__(f"stage {stage!r} failed: {message}")
        self.stage = stage


@dataclasses.dataclass
class StageRecord:
    name: str
    status: str  # "done" | "failed"
    wall_s: float
    payload: Dict[str, Any]
    error: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.status == "done"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StageRecord":
        return cls(
            name=str(d["name"]),
            status=str(d["status"]),
            wall_s=float(d.get("wall_s", 0.0)),
            payload=dict(d.get("payload", {})),
            error=d.get("error"),
        )


@dataclasses.dataclass
class OffloadResult:
    """Spec + per-stage records; the unit of save/reload/resume."""

    spec: OffloadSpec
    stages: Dict[str, StageRecord] = dataclasses.field(default_factory=dict)
    path: Optional[str] = None  # where save() writes (None = in-memory)
    # trace summary ({"path", "digest", "records"}) maintained by the
    # Offloader when tracing is on: the digest is the trace file's
    # content digest (timing-stripped; repro.offload.trace), so the
    # `trace` CLI verb can prove a trace file belongs to this artifact
    trace: Optional[Dict[str, Any]] = None
    # serving-layer job record (repro.serve.jobs, docs/serving.md): when
    # the artifact is owned by the offload service, its lifecycle state
    # (queued/running/done/failed/cancelled), restarts, admission clamps
    # etc. live HERE — the resumable artifact IS the job-state record,
    # which is what makes crash recovery "resume every artifact whose
    # job is non-terminal". Additive: None for every non-service run,
    # keeping those artifact bytes identical to pre-serving ones.
    job: Optional[Dict[str, Any]] = None

    # -- stage bookkeeping --------------------------------------------------

    def completed(self, stage: str) -> bool:
        rec = self.stages.get(stage)
        return rec is not None and rec.done

    def stage(self, name: str) -> StageRecord:
        if name not in self.stages:
            raise KeyError(
                f"stage {name!r} not in artifact (have "
                f"{[s for s in STAGES if s in self.stages]})"
            )
        return self.stages[name]

    def record(self, name: str, payload: Dict[str, Any], wall_s: float,
               status: str = "done", error: Optional[str] = None
               ) -> StageRecord:
        assert name in STAGES, name
        rec = StageRecord(name=name, status=status, wall_s=wall_s,
                          payload=payload, error=error)
        self.stages[name] = rec
        return rec

    # -- convenience accessors ---------------------------------------------

    @property
    def best_genes(self) -> Optional[Tuple[int, ...]]:
        if not self.completed("search"):
            return None
        return tuple(int(g) for g in self.stage("search").payload["best_genes"])

    @property
    def best_time_s(self) -> Optional[float]:
        if not self.completed("search"):
            return None
        t = self.stage("search").payload["best_time_s"]
        # a zero-generation search records no winner (best_time_s=None)
        return float(t) if t is not None else None

    @property
    def baseline_time_s(self) -> Optional[float]:
        if not self.completed("analyze"):
            return None
        return float(self.stage("analyze").payload["baseline_s"])

    @property
    def speedup(self) -> Optional[float]:
        if self.best_time_s and self.baseline_time_s:
            return self.baseline_time_s / self.best_time_s
        return None

    @property
    def calibration(self) -> Optional[Dict[str, Any]]:
        """The embedded calibration dict (constants, probes, residuals)
        when this artifact ran at fidelity='calibrated'; None otherwise."""
        if not self.completed("calibrate"):
            return None
        return self.stage("calibrate").payload.get("calibration")

    # -- persistence --------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "v": _ARTIFACT_VERSION,
            "spec": self.spec.to_dict(),
            "stages": [self.stages[s].to_dict()
                       for s in STAGES if s in self.stages],
        }
        if self.trace is not None:  # additive: v1 artifacts stay loadable
            out["trace"] = self.trace
        if self.job is not None:  # additive: service-owned artifacts only
            out["job"] = self.job
        return out

    def save(self, path: Optional[str] = None) -> Optional[str]:
        """Atomically write the artifact JSON; returns the path written
        (None when the artifact is in-memory only)."""
        path = path or self.path
        if path is None:
            return None
        self.path = path
        return atomic_json_save(path, self.to_dict())

    @classmethod
    def load(cls, path: str) -> "OffloadResult":
        with open(path, "r", encoding="utf-8") as fh:
            d = json.load(fh)
        if d.get("v") != _ARTIFACT_VERSION:
            raise ValueError(
                f"unsupported artifact version {d.get('v')!r} in {path}"
            )
        out = cls(spec=OffloadSpec.from_dict(d["spec"]), path=path,
                  trace=d.get("trace"), job=d.get("job"))
        for rec in d.get("stages", []):
            sr = StageRecord.from_dict(rec)
            if sr.name in STAGES:
                out.stages[sr.name] = sr
        return out

    # -- display ------------------------------------------------------------

    def summary(self) -> str:
        rows = [f"OffloadResult[{self.spec.program}/{self.spec.mode}"
                + (f"/{self.spec.method}" if self.spec.mode == "binary"
                   else f"/{'+'.join(self.spec.destinations)}") + "]"]
        for s in STAGES:
            if s in self.stages:
                r = self.stages[s]
                flag = "done" if r.done else f"FAILED ({r.error})"
                rows.append(f"  {s:8s} {flag} ({r.wall_s:.2f}s)")
            else:
                rows.append(f"  {s:8s} -")
        return "\n".join(rows)


def atomic_json_save(path: str, obj: Dict[str, Any]) -> str:
    """Write ``obj`` as pretty JSON via tmp-file + rename, so readers
    never observe a torn file (shared by OffloadResult and
    CalibrationResult saves)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(obj, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return path


def timed(fn, *args, **kw):
    """(result, wall seconds) of ``fn(*args, **kw)``."""
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0
