"""OffloadSpec: the one declarative input of the staged offload pipeline.

Everything the paper's flow needs to run end to end — which program,
which search mode (the paper's binary CPU/GPU placements or the
mixed-destination k-ary follow-up), which method configuration, GA
budget, evaluation-pool settings and verification tolerances — lives in
one frozen, JSON-round-trippable dataclass. The spec is embedded in the
:class:`~repro.offload.result.OffloadResult` artifact, so a saved
artifact is self-describing and ``python -m repro.offload resume`` needs
nothing but the artifact path.

Programs are named: a miniapp from :data:`repro.core.miniapps.MINIAPPS`
(``"himeno"``, ``"nasft"``, ``"hetero"``) or a model architecture as
``"arch:<name>"`` (the beyond-paper framework-level search, scored by the
analytic plan evaluator). Method configurations are the fig-5 columns,
centralized here so benchmarks stop re-declaring them.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from repro.core import ga

# The fig-5 method configurations (paper §3.3): transfer mode, temp-area
# staging, and whether only `kernels`-class loops may be offloaded.
# Previously duplicated by benchmarks/fig5_speedup.py; now the single
# source of truth for every binary-mode search.
METHODS: Dict[str, Dict[str, Any]] = {
    # [33]: nest-level transfers, kernels directive only, no temp-area
    "previous": dict(transfer="nest", staged=False, kernels_only=True),
    # ablation: add the directive expansion, keep [33] transfers
    "dir-expansion-only": dict(transfer="nest", staged=False,
                               kernels_only=False),
    # ablation: add bulk/present/temp-area transfers, keep kernels-only
    "transfer-only": dict(transfer="bulk", staged=True, kernels_only=True),
    # this paper: both improvements
    "proposed": dict(transfer="bulk", staged=True, kernels_only=False),
    # extra reference: [32]-era naive per-kernel sync
    "naive-2018": dict(transfer="naive", staged=False, kernels_only=True),
}

MODES = ("binary", "mixed")

# How candidates are priced, end to end (docs/fidelity.md):
# - "modeled"    — the analytic HardwareModel/MixedEvaluator (default;
#                  byte-identical to every pre-fidelity search);
# - "measured"   — real wall-clocked subprocess runs of the runnable
#                  miniapps through MeasuredEvaluator + a process EvalPool;
# - "calibrated" — a calibrate stage measures a designed probe set, fits
#                  per-destination constants by least squares, and the
#                  search runs the analytic model under the fitted machine.
FIDELITIES = ("modeled", "measured", "calibrated")

# programs with a runnable implementation the measured/calibrated levels
# can wall-clock; programs.RUNNABLE must stay in sync (asserted there)
MEASURED_PROGRAMS = ("himeno", "nasft")

# mixed-mode GA budgets (population, generations): the k=3 space needs
# ~24x24 to find the mixed optimum on every seed; the smoke budget is
# the CI-sized trim that still shows the win on the default seed. The
# CLI's --smoke and benchmarks/fig_mixed_destinations.py both consume
# these so the budgets can't drift apart.
MIXED_BUDGET = (24, 24)
MIXED_SMOKE_BUDGET = (10, 8)

_SPEC_VERSION = 1


@dataclasses.dataclass(frozen=True)
class GAControls:
    """Search-quality knobs (docs/observability.md), nested under
    ``OffloadSpec.ga``. Every default keeps the search byte-identical to
    the pre-observability pipeline: ``diversity=0.0`` never enters the
    fitness-sharing block, and the stability/rank metrics run *after*
    the search, in the report stage, against the same fitness cache.
    """

    # fitness-sharing strength (GAParams.diversity): an individual's
    # roulette fitness is divided by (copies of its genome in the
    # generation) ** diversity. 0.0 = off, the historical selection.
    diversity: float = 0.0
    # pass@k winner stability in the report stage: the modeled search is
    # re-run at GA seeds seed+1 .. seed+k-1 (the recorded search covers
    # the spec's own seed), sharing the persistent fitness cache.
    # <= 1 disables the re-searches.
    stability_seeds: int = 3
    # a seed "passes" when its best time lands within this relative
    # window of the best seed's best
    stability_window: float = 0.02
    # when set, the report stage FAILS if the relative spread
    # (worst/best - 1) across seeds exceeds this gate
    stability_gate: Optional[float] = None
    # wall-clock the (at most two) realizable projections of the final
    # population so modeled/calibrated searches get a modeled-vs-measured
    # rank correlation too; measured fidelity computes it for free from
    # the search's own clocks
    rank_probe: bool = False
    # asynchronous steady-state GA (GAParams.steady_state): offspring are
    # bred per free worker lane instead of waiting at the generation
    # barrier. False = the historical generational loop, byte-identical.
    steady_state: bool = False
    # vectorized population pricing (BatchMixedEvaluator): mixed-mode
    # searches price whole populations in one numpy pass; the scalar
    # evaluator stays the verify-stage oracle and shares the same
    # fingerprint/cache keys. False = scalar pricing, byte-identical.
    batch: bool = False

    def __post_init__(self):
        if self.diversity < 0:
            raise ValueError(f"ga.diversity must be >= 0: {self.diversity}")
        if self.stability_seeds < 0:
            raise ValueError(
                f"ga.stability_seeds must be >= 0: {self.stability_seeds}"
            )
        if self.stability_window < 0:
            raise ValueError(
                f"ga.stability_window must be >= 0: {self.stability_window}"
            )
        if self.stability_gate is not None and self.stability_gate < 0:
            raise ValueError(
                f"ga.stability_gate must be >= 0: {self.stability_gate}"
            )

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "GAControls":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown GAControls fields {sorted(unknown)}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class OffloadSpec:
    """Declarative input of one end-to-end offload search.

    ``population`` / ``generations`` / ``timeout_s`` default to ``None``
    = "the budget the pre-redesign entry point used": the paper rule
    (:meth:`GAParams.for_gene_length`) for binary searches, 24x24 with a
    no-op timeout for mixed searches, and min(n, 10) for arch searches —
    so a default spec reproduces the historical paths byte-identically.
    """

    program: str  # miniapp name, or "arch:<name>"
    mode: str = "binary"  # "binary" | "mixed"
    method: str = "proposed"  # binary only: METHODS key
    destinations: Tuple[str, ...] = ("cpu", "gpu", "fpga")  # mixed only
    # the modeled machine. Binary/arch: a HardwareModel name (rate
    # constants). Mixed: a machine Registry name from
    # ``repro.destinations.REGISTRIES`` — profiles, links AND
    # per-destination memory capacities, so a capacity-constrained
    # machine (e.g. "p4000-constrained", "tpu-v5e-host") is frozen into
    # the spec and its artifact/cache identity.
    hw: str = "quadro-p4000"
    # -- fidelity: how candidates are priced (FIDELITIES) ------------------
    # "measured" requires a runnable program (MEASURED_PROGRAMS), binary
    # mode, and executor="process" (real subprocess measurements);
    # "calibrated" requires ``hw`` to name a known base registry — both
    # validated here at spec time, never mid-search.
    fidelity: str = "modeled"
    # measurement repeats per individual/probe (measured + calibrated).
    # The minimum over repeats is kept, so with the default of 2 the
    # first repeat absorbs any one-time jit compile (a fresh spawn
    # worker re-jits) and the clock bills the COMPILED kernel; set 1
    # only if you explicitly want cold-start costs in the fitness.
    repeats: int = 2
    # -- GA budget ---------------------------------------------------------
    population: Optional[int] = None
    generations: Optional[int] = None
    seed: int = 0
    timeout_s: Optional[float] = None
    penalty_time_s: float = 1000.0
    # -- genome-aware seeding (mixed only): warm the k-ary initial
    # population with each single-destination best re-expressed in the
    # k-ary alphabet (ROADMAP follow-on)
    warm_start: bool = False
    # -- function-block substitution (mixed only, docs/blocks.md): match
    # loop chains against the kernel library (repro.blocks) and extend
    # the genome with one gene per matched block choosing between
    # loop-level placement and library substitution per destination.
    # Off = byte-identical to the loop-level search.
    blocks: bool = False
    # -- evaluation pool ---------------------------------------------------
    workers: int = 1
    executor: str = "thread"
    cache: Optional[str] = None  # persistent JSONL fitness-cache path
    # -- verify tolerances (None = repro.core.pcast dtype defaults) --------
    rel_tol: Optional[float] = None
    abs_tol: Optional[float] = None
    # -- search-quality knobs (docs/observability.md) ----------------------
    ga: GAControls = dataclasses.field(default_factory=GAControls)

    def __post_init__(self):
        if isinstance(self.ga, dict):  # from_dict round-trip
            object.__setattr__(self, "ga", GAControls.from_dict(self.ga))
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}: {self.mode!r}")
        if self.mode == "binary" and self.method not in METHODS:
            raise ValueError(
                f"unknown method {self.method!r}; have {sorted(METHODS)}"
            )
        if self.mode == "mixed":
            if self.is_arch:
                raise ValueError("mixed mode applies to loop programs, "
                                 "not arch:<name> searches")
            if len(self.destinations) < 2:
                raise ValueError("mixed mode needs >= 2 destinations "
                                 "(host first)")
        if self.executor not in ("thread", "process"):
            raise ValueError(f"executor must be thread|process: "
                             f"{self.executor!r}")
        if self.warm_start and self.mode != "mixed":
            raise ValueError("warm_start is a mixed-mode (k-ary) feature")
        if self.blocks and self.mode != "mixed":
            raise ValueError("blocks (function-block substitution) is a "
                             "mixed-mode feature")
        if self.fidelity not in FIDELITIES:
            raise ValueError(
                f"fidelity must be one of {FIDELITIES}: {self.fidelity!r}"
            )
        if self.repeats < 1:
            raise ValueError(f"repeats must be >= 1: {self.repeats}")
        if self.population is not None and self.population < 1:
            raise ValueError(f"population must be >= 1: {self.population}")
        if self.generations is not None and self.generations < 0:
            # 0 is allowed: an analyze-only run records an empty search
            # ("no generations"), which report/verify handle explicitly
            raise ValueError(f"generations must be >= 0: {self.generations}")
        if self.fidelity == "measured":
            if self.program not in MEASURED_PROGRAMS:
                raise ValueError(
                    f"fidelity='measured' needs a program with a runnable "
                    f"implementation {MEASURED_PROGRAMS}; {self.program!r} "
                    "has none to wall-clock"
                )
            if self.mode != "binary":
                raise ValueError(
                    "fidelity='measured' is a binary-mode feature (the "
                    "runnable implementations switch one CPU/accelerator "
                    "path); use mode='binary'"
                )
            if self.executor != "process":
                raise ValueError(
                    "fidelity='measured' wall-clocks real subprocess runs; "
                    "set executor='process' (the CLI --fidelity measured "
                    "does this for you)"
                )
        if self.fidelity == "calibrated":
            if self.is_arch:
                raise ValueError(
                    "fidelity='calibrated' calibrates a machine registry; "
                    "arch:<name> searches use the analytic plan evaluator "
                    "and have no machine to calibrate"
                )
            # lazy import: destinations never imports repro.offload, so
            # this cannot cycle — and it keeps spec importable without
            # dragging the destinations subsystem in for modeled specs
            from repro.destinations import REGISTRIES

            if self.hw not in REGISTRIES:
                raise ValueError(
                    f"fidelity='calibrated' needs a known base registry "
                    f"to calibrate; unknown hw {self.hw!r} (have "
                    f"{sorted(REGISTRIES)})"
                )
        # normalize list -> tuple for from_dict round-trips
        object.__setattr__(self, "destinations", tuple(self.destinations))

    # -- program identity ---------------------------------------------------

    @property
    def is_arch(self) -> bool:
        return self.program.startswith("arch:")

    @property
    def arch_name(self) -> str:
        assert self.is_arch, self.program
        return self.program.split(":", 1)[1]

    # -- GA parameter resolution (parity with the pre-redesign paths) ------

    def ga_params(self, gene_length: int, alleles: int = 2) -> ga.GAParams:
        """Concrete :class:`GAParams` for this spec at a gene length.

        Unset (``None``) fields resolve to the budget the pre-redesign
        entry points used, so the facade's searches stay byte-identical
        to them; explicit values — including ``generations=0`` — are
        taken as-is.
        """
        if self.mode == "mixed":
            return ga.GAParams(
                population=self.population
                if self.population is not None else MIXED_BUDGET[0],
                generations=self.generations
                if self.generations is not None else MIXED_BUDGET[1],
                seed=self.seed,
                timeout_s=self.timeout_s if self.timeout_s is not None
                else 1e6,
                penalty_time_s=self.penalty_time_s,
                alleles=alleles,
                diversity=self.ga.diversity,
                steady_state=self.ga.steady_state,
            )
        if self.is_arch:
            return ga.GAParams(
                population=self.population
                if self.population is not None else min(gene_length, 10),
                generations=self.generations
                if self.generations is not None else min(gene_length, 10),
                seed=self.seed,
                timeout_s=self.timeout_s if self.timeout_s is not None
                else 1e6,
                penalty_time_s=self.penalty_time_s,
                diversity=self.ga.diversity,
                steady_state=self.ga.steady_state,
            )
        # binary miniapp: the paper rule (fig4/fig5)
        kw: Dict[str, Any] = dict(seed=self.seed,
                                  penalty_time_s=self.penalty_time_s,
                                  diversity=self.ga.diversity,
                                  steady_state=self.ga.steady_state)
        if self.timeout_s is not None:
            kw["timeout_s"] = self.timeout_s
        params = ga.GAParams.for_gene_length(gene_length, **kw)
        if self.population is not None or self.generations is not None:
            params = dataclasses.replace(
                params,
                population=self.population
                if self.population is not None else params.population,
                generations=self.generations
                if self.generations is not None else params.generations,
            )
        return params

    # -- (de)serialization --------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["destinations"] = list(self.destinations)
        if not self.blocks:
            # serialized only when set: a blocks-off spec round-trips
            # byte-identically to pre-blocks artifacts (same digest)
            del d["blocks"]
        # same rule for the fast-search knobs: asdict recursed into the
        # nested GAControls, so dropping the off-state keys keeps every
        # knobs-off spec digest identical to pre-fast-search artifacts
        if not self.ga.steady_state:
            del d["ga"]["steady_state"]
        if not self.ga.batch:
            del d["ga"]["batch"]
        d["v"] = _SPEC_VERSION
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "OffloadSpec":
        d = dict(d)
        v = d.pop("v", _SPEC_VERSION)
        if v != _SPEC_VERSION:
            raise ValueError(f"unsupported OffloadSpec version {v}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown OffloadSpec fields {sorted(unknown)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "OffloadSpec":
        return cls.from_dict(json.loads(s))
