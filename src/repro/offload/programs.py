"""Program adapters: one interface from an OffloadSpec to the pieces the
pipeline stages need.

A *program* is whatever the offload genome indexes into:

- a **miniapp** ``LoopProgram`` (the paper's applications — Himeno,
  NAS.FT, and the heterogeneous pipeline), searched either in the
  paper's binary CPU/GPU mode (``MiniappEvaluator`` under a METHODS
  configuration) or in the mixed-destination k-ary mode
  (``MixedEvaluator`` over a destination subset);
- a **model architecture** (``"arch:<name>"``), the beyond-paper
  framework-level search where genes toggle stage-group offload in an
  ExecutionPlan, scored by the analytic plan evaluator (or an injected
  ``CompiledEvaluator`` for real AOT-compile scoring).

Each adapter knows its gene length and allele count, builds its
evaluator, computes the all-host baseline, renders a genome as a
{unit: destination} placement, and (for miniapps with runnable JAX
implementations) produces the PCAST result-difference check of the
offloaded path against the CPU reference.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import evaluator as ev
from repro.core import miniapps
from repro.core import pcast
from repro.core import transfer as tr
from repro.core.loopir import LoopClass, LoopProgram
from repro.offload.spec import MEASURED_PROGRAMS, METHODS, OffloadSpec

# HardwareModel registry (spec.hw); Offloader may inject an unregistered
# candidate model (calibration sweeps) via its ``hw=`` override.
HW_MODELS: Dict[str, ev.HardwareModel] = {
    ev.QUADRO_P4000.name: ev.QUADRO_P4000,
    ev.TPU_V5E_HOST.name: ev.TPU_V5E_HOST,
}

_BUILTIN_HW_MODELS = frozenset(HW_MODELS)


def register_hw_model(hw: ev.HardwareModel, name: Optional[str] = None,
                      replace: bool = False) -> None:
    """Make a :class:`HardwareModel` selectable as ``OffloadSpec.hw`` in
    binary/arch mode (calibrated machines register here under their
    entry name; the model's OWN name carries the constants digest that
    keys fitness-cache fingerprints). Built-ins cannot be replaced."""
    name = name or hw.name
    if name in _BUILTIN_HW_MODELS:
        raise ValueError(f"cannot replace built-in hardware model {name!r}")
    if name in HW_MODELS and not replace:
        raise ValueError(
            f"hardware model {name!r} already registered; pass "
            "replace=True to re-register"
        )
    HW_MODELS[name] = hw

# paper directive per pgcc-style loop class (§3.3)
DIRECTIVES: Dict[LoopClass, str] = {
    LoopClass.TIGHT: "acc kernels",
    LoopClass.NON_TIGHT: "acc parallel loop",
    LoopClass.VECTOR_ONLY: "acc parallel loop vector",
    LoopClass.NOT_OFFLOADABLE: "(excluded: not offloadable)",
}


def resolve_hw(spec: OffloadSpec,
               override: Optional[ev.HardwareModel] = None
               ) -> ev.HardwareModel:
    if override is not None:
        return override
    if spec.hw not in HW_MODELS:
        raise ValueError(
            f"unknown hardware model {spec.hw!r}; have {sorted(HW_MODELS)}"
        )
    return HW_MODELS[spec.hw]


# ---------------------------------------------------------------------------
# PCAST runnables: genome -> (reference pytree, offloaded pytree)
# ---------------------------------------------------------------------------


def _himeno_pair(offloaded: bool):
    p_ref, g_ref = miniapps.himeno_run(grid=(17, 17, 33), nn=4,
                                       jit_stencil=False)
    p_off, g_off = miniapps.himeno_run(grid=(17, 17, 33), nn=4,
                                       jit_stencil=offloaded)
    return (
        {"p": p_ref, "gosa": np.float32(g_ref)},
        {"p": p_off, "gosa": np.float32(g_off)},
    )


def _nasft_pair(offloaded: bool):
    ref = miniapps.nasft_run(grid=(16, 16, 16), niter=2, jit_fft=False)
    off = miniapps.nasft_run(grid=(16, 16, 16), niter=2, jit_fft=offloaded)
    return {"checksums": ref}, {"checksums": off}


# miniapp name -> (hot loop whose gene selects the accelerator path,
#                  pair builder). Apps absent here have no runnable
# implementation; their verify stage records the PCAST check as skipped.
RUNNABLE: Dict[str, Tuple[str, Callable[[bool], Tuple[Any, Any]]]] = {
    "himeno": ("jacobi_stencil", _himeno_pair),
    "nasft": ("evolve", _nasft_pair),
}

# measured-fidelity plumbing: the picklable run_fn class per runnable
# program, and the LoopProgram at the RUN FN's (scaled-down) config — the
# scale real measurements and their model predictions must both use, so
# predicted-vs-measured ratios compare like with like (docs/fidelity.md).
MEASURED_RUN_FNS: Dict[str, Callable[[], Any]] = {
    "himeno": miniapps.HimenoRunFn,
    "nasft": miniapps.NasftRunFn,
}

assert set(MEASURED_RUN_FNS) == set(RUNNABLE) == set(MEASURED_PROGRAMS), \
    "spec.MEASURED_PROGRAMS must list exactly the runnable miniapps"


def measured_scale_program(name: str) -> LoopProgram:
    """The program's LoopProgram at its runnable (measured) scale."""
    fn = MEASURED_RUN_FNS[name]()
    if name == "himeno":
        return miniapps.himeno_program(grid=fn.grid, nn=fn.nn)
    return miniapps.nasft_program(grid=fn.grid, niter=fn.niter)


def hot_gene_index(name: str) -> int:
    """Gene index of the runnable implementation's hot loop — the one
    gene the measured path actually realizes (docs/fidelity.md)."""
    prog = miniapps.MINIAPPS[name]()
    return miniapps._gene_index(prog, RUNNABLE[name][0])


# ---------------------------------------------------------------------------
# adapters
# ---------------------------------------------------------------------------


class MiniappBinaryAdapter:
    """The paper's binary CPU/GPU search under a METHODS configuration."""

    kind = "miniapp-binary"
    deterministic = True  # analytic evaluator: re-measuring is exact

    def __init__(self, spec: OffloadSpec,
                 hw: Optional[ev.HardwareModel] = None):
        if spec.program not in miniapps.MINIAPPS:
            raise ValueError(
                f"unknown miniapp {spec.program!r}; have "
                f"{sorted(miniapps.MINIAPPS)}"
            )
        self.spec = spec
        self.hw = resolve_hw(spec, hw)
        self.prog: LoopProgram = miniapps.MINIAPPS[spec.program]()
        self.method = METHODS[spec.method]

    @property
    def gene_length(self) -> int:
        return self.prog.gene_length

    @property
    def alleles(self) -> int:
        return 2

    @property
    def allele_names(self) -> Tuple[str, ...]:
        return ("cpu", "gpu")

    def build_evaluator(self) -> ev.MiniappEvaluator:
        return ev.MiniappEvaluator(
            self.prog,
            tr.TransferMode(self.method["transfer"]),
            staged=self.method["staged"],
            hw=self.hw,
            kernels_only=self.method["kernels_only"],
        )

    def baseline_time(self) -> float:
        # all loops on the CPU, priced exactly as the fig4/fig5 scripts
        # did (default BULK/staged args are transfer-free at zero genes)
        return ev.predict_time(
            self.prog, (0,) * self.gene_length, hw=self.hw
        ).total_s

    def analyze_payload(self) -> Dict[str, Any]:
        return {
            "program": self.prog.name,
            "description": self.prog.description,
            "gene_length": self.gene_length,
            "n_loops": len(self.prog.loops),
            "kernels_only": bool(self.method["kernels_only"]),
            "loops": [
                {
                    "name": l.name,
                    "class": l.klass.value,
                    "directive": DIRECTIVES[l.klass],
                    "offloadable": l.offloadable,
                }
                for l in self.prog.loops
            ],
        }

    def placement(self, genes: Sequence[int]) -> Dict[str, str]:
        adm = self.build_evaluator().admissible(genes)
        out = {l.name: "cpu" for l in self.prog.loops}
        for g, l in zip(adm, self.prog.offloadable_loops):
            out[l.name] = "gpu" if g else "cpu"
        return out

    def pcast_check(self, genes: Sequence[int]
                    ) -> Optional[pcast.PcastReport]:
        hot = RUNNABLE.get(self.prog.name)
        if hot is None:
            return None
        loop_name, pair = hot
        offloaded = self.placement(genes)[loop_name] != "cpu"
        ref, off = pair(offloaded)
        return pcast.compare(ref, off, rel_tol=self.spec.rel_tol,
                             abs_tol=self.spec.abs_tol)


class MiniappMeasuredAdapter:
    """Measured fidelity: the paper's REAL measurement loop — every
    candidate wall-clocked by running the miniapp's implementation, not
    priced by the analytic model.

    The genome still indexes the paper-scale LoopProgram (gene length
    13/65), but fitness comes from ``MeasuredEvaluator`` wall-clocking
    the picklable run_fn at its scaled-down config inside the spec's
    ``executor="process"`` EvalPool (spawn context — subprocess
    isolation is what makes the clock honest). The run_fn's
    ``cache_key`` collapses genomes to the genes the implementation
    actually distinguishes (the hot loop), so equivalent placements
    share one real measurement exactly as the paper's §5.2 cache
    intends. ``model_evaluator()`` exposes the analytic model AT THE
    MEASURED SCALE for the verify stage's predicted-vs-measured
    fidelity section.
    """

    kind = "miniapp-measured"
    deterministic = False  # wall clocks jitter; re-measure can't be exact

    def __init__(self, spec: OffloadSpec,
                 hw: Optional[ev.HardwareModel] = None):
        assert spec.fidelity == "measured", spec.fidelity
        self.spec = spec
        self.hw = resolve_hw(spec, hw)  # the MODEL the fidelity section
        # compares against; never used to price candidates
        self.prog: LoopProgram = miniapps.MINIAPPS[spec.program]()
        self.run_fn = MEASURED_RUN_FNS[spec.program]()
        self.method = METHODS[spec.method]

    @property
    def gene_length(self) -> int:
        return self.prog.gene_length

    @property
    def alleles(self) -> int:
        return 2

    @property
    def allele_names(self) -> Tuple[str, ...]:
        return ("cpu", "gpu")

    def build_evaluator(self) -> ev.MeasuredEvaluator:
        return ev.MeasuredEvaluator(
            self.run_fn, repeats=self.spec.repeats, tag=self.run_fn.tag
        )

    def model_evaluator(self) -> ev.MiniappEvaluator:
        """The analytic model at the measured scale, under the spec's
        method configuration and modeled machine."""
        return ev.MiniappEvaluator(
            measured_scale_program(self.spec.program),
            tr.TransferMode(self.method["transfer"]),
            staged=self.method["staged"],
            hw=self.hw,
            kernels_only=self.method["kernels_only"],
        )

    def baseline_time(self) -> float:
        # a REAL all-host measurement (in-process: the analyze stage is
        # not pooled, and the number is compared against other wall
        # clocks, not against model output)
        return float(self.build_evaluator()((0,) * self.gene_length))

    def analyze_payload(self) -> Dict[str, Any]:
        e = self.build_evaluator()
        return {
            "program": self.prog.name,
            "description": self.prog.description,
            "gene_length": self.gene_length,
            "n_loops": len(self.prog.loops),
            "fidelity": "measured",
            "measured_scale": self.run_fn.tag,
            "host": e.host,
            "repeats": self.spec.repeats,
            "loops": [
                {
                    "name": l.name,
                    "class": l.klass.value,
                    "directive": DIRECTIVES[l.klass],
                    "offloadable": l.offloadable,
                }
                for l in self.prog.loops
            ],
        }

    def placement(self, genes: Sequence[int]) -> Dict[str, str]:
        # raw gene -> path mapping: measured fidelity has no admissibility
        # model to mask through — the implementation either jits the loop
        # or it doesn't
        out = {l.name: "cpu" for l in self.prog.loops}
        for g, l in zip(genes, self.prog.offloadable_loops):
            out[l.name] = "gpu" if int(g) else "cpu"
        return out

    def pcast_check(self, genes: Sequence[int]
                    ) -> Optional[pcast.PcastReport]:
        loop_name, pair = RUNNABLE[self.prog.name]
        offloaded = self.placement(genes)[loop_name] != "cpu"
        ref, off = pair(offloaded)
        return pcast.compare(ref, off, rel_tol=self.spec.rel_tol,
                             abs_tol=self.spec.abs_tol)


class MiniappMixedAdapter:
    """Mixed-destination k-ary search (arXiv:2011.12431 direction)."""

    kind = "miniapp-mixed"
    deterministic = True

    def __init__(self, spec: OffloadSpec,
                 hw: Optional[ev.HardwareModel] = None):
        from repro.destinations import (
            REGISTRIES,
            MixedEvaluator,
            default_registry,
            get_registry,
        )

        if spec.program not in miniapps.MINIAPPS:
            raise ValueError(
                f"unknown miniapp {spec.program!r}; have "
                f"{sorted(miniapps.MINIAPPS)}"
            )
        self.spec = spec
        # ``spec.hw`` selects the modeled MACHINE here, not just rate
        # constants: a named Registry carries per-destination memory
        # capacities, so freezing the name in the spec freezes them too.
        # ``self.machine`` is the spec-facing name: for spec-resolved
        # machines it can be fed straight back into ``OffloadSpec.hw``
        # (the registry's INTERNAL name may differ, e.g. "p4000-fpga" —
        # renaming it would move every unbounded cache fingerprint); an
        # injected HardwareModel (calibration sweeps) is process-local
        # and not name-addressable, so its artifact says so explicitly
        # instead of claiming a name the spec would reject.
        if hw is not None:
            self.registry = default_registry(hw)
            self.machine = f"injected:{hw.name}"
        elif spec.hw in REGISTRIES:
            self.registry = get_registry(spec.hw)
            self.machine = spec.hw
        elif spec.hw in HW_MODELS:
            self.registry = default_registry(HW_MODELS[spec.hw])
            self.machine = spec.hw
        else:
            raise ValueError(
                f"unknown machine {spec.hw!r} for mixed mode; have "
                f"registries {sorted(REGISTRIES)} and hardware models "
                f"{sorted(HW_MODELS)}"
            )
        known = {d.name for d in self.registry.destinations}
        missing = [n for n in spec.destinations if n not in known]
        if missing:
            raise ValueError(
                f"destinations {missing} do not exist on machine "
                f"{self.machine!r} (its destinations: {sorted(known)}); "
                "set OffloadSpec.destinations (CLI: --destinations) to "
                "match the registry"
            )
        self.prog: LoopProgram = miniapps.MINIAPPS[spec.program]()
        self._mixed_cls = MixedEvaluator
        # function-block substitution (docs/blocks.md): with spec.blocks
        # and at least one library match, the evaluator grows one gene
        # per matched block. Zero matches fall back to the plain
        # evaluator so the search (and its cache fingerprint) stays
        # byte-identical to a blocks-off run.
        self.library = None
        self.matches: Tuple[Any, ...] = ()
        if spec.blocks:
            from repro.blocks import default_library, match_blocks

            self.library = default_library(hw=self.machine)
            self.matches = match_blocks(self.prog, self.library)
        # spec.ga.batch swaps in the vectorized-population subclasses
        # for the MAIN search evaluator. Scalar __call__, fingerprint
        # and cache keys are inherited, so the verify-stage re-measure
        # stays the oracle and batch/scalar searches share one cache;
        # the warm-start sub_evaluators stay scalar (tiny populations,
        # not worth the table builds).
        if self.matches:
            from repro.blocks import (
                BatchBlockMixedEvaluator,
                BlockMixedEvaluator,
            )

            block_cls = (
                BatchBlockMixedEvaluator if spec.ga.batch
                else BlockMixedEvaluator
            )
            self._evaluator = block_cls(
                self.prog, spec.destinations, registry=self.registry,
                library=self.library, matches=self.matches,
            )
        else:
            from repro.destinations import BatchMixedEvaluator

            mixed_cls = BatchMixedEvaluator if spec.ga.batch \
                else MixedEvaluator
            self._evaluator = mixed_cls(
                self.prog, spec.destinations, registry=self.registry
            )

    @property
    def gene_length(self) -> int:
        return self.prog.gene_length + len(self.matches)

    @property
    def alleles(self) -> int:
        return self._evaluator.k

    @property
    def allele_names(self) -> Tuple[str, ...]:
        return self._evaluator.allele_names()

    def build_evaluator(self):
        return self._evaluator

    def sub_evaluator(self, subset: Sequence[str]):
        """A single-destination (host + one device) evaluator sharing
        this machine's registry — the warm-start pre-searches. Its
        fingerprint equals the mixed one (subset-independent), so the
        pre-searches and the main search share one fitness-cache file.
        Under ``spec.blocks`` the sub-evaluator is block-aware over the
        SAME matches, so pre-search genomes keep the full ``n + m``
        length and ``reexpress`` maps block genes like loop genes."""
        if self.matches:
            from repro.blocks import BlockMixedEvaluator

            return BlockMixedEvaluator(
                self.prog, tuple(subset), registry=self.registry,
                library=self.library, matches=self.matches,
            )
        return self._mixed_cls(self.prog, tuple(subset),
                               registry=self.registry)

    def substitutions(self, genes: Sequence[int]) -> Optional[list]:
        """Per-block decision rows for a genome (None when the run has
        no block genome — keeps blocks-off payloads byte-identical)."""
        fn = getattr(self._evaluator, "substitutions", None)
        return fn(genes) if fn is not None else None

    def reexpress(self, genes: Sequence[int], device: str) -> Tuple[int, ...]:
        """A binary (host, device) genome re-expressed in the full k-ary
        alphabet of ``spec.destinations``."""
        idx = self.spec.destinations.index(device)
        return tuple(idx if int(g) else 0 for g in genes)

    def baseline_time(self) -> float:
        return self._evaluator.host_only_time()

    def _capacities(self) -> Dict[str, float]:
        """Bounded device memories of the searched subset (empty when
        the whole machine is unbounded)."""
        return {
            d.name: float(d.memory_bytes)
            for d in self._evaluator.dests if d.bounded
        }

    def analyze_payload(self) -> Dict[str, Any]:
        dests = {d.name: d for d in self._evaluator.dests}
        out: Dict[str, Any] = {
            "program": self.prog.name,
            "description": self.prog.description,
            "gene_length": self.gene_length,
            "n_loops": len(self.prog.loops),
            "machine": self.machine,
            "destinations": [d.name for d in self._evaluator.dests],
            "capacities": self._capacities(),
        }
        if self.spec.blocks:
            out["blocks"] = {
                "library": [e.name for e in self.library.entries],
                "library_fingerprint": self.library.fingerprint(),
                "matches": [
                    {
                        "entry": m.entry,
                        "loops": list(m.loops),
                        "parent_seq": m.parent_seq,
                        "atom": m.atom,
                    }
                    for m in self.matches
                ],
            }
        out["loops"] = [
            {
                "name": l.name,
                "class": l.klass.value,
                "directive": DIRECTIVES[l.klass],
                "offloadable": l.offloadable,
                "admissible": [
                    n for n, d in dests.items() if d.accepts(l.klass)
                ] if l.offloadable else [],
            }
            for l in self.prog.loops
        ]
        return out

    def placement(self, genes: Sequence[int]) -> Dict[str, str]:
        return self._evaluator.placement(genes)

    def schedule_stats(self, genes: Sequence[int]) -> Dict[str, Any]:
        """Residency pressure of a genome's transfer schedule — recorded
        in the search payload so the report stage can state eviction and
        streaming traffic without re-running anything."""
        bd = self._evaluator.breakdown(genes)
        s = bd.schedule
        return {
            "transfer_s": float(bd.transfer_s),
            "transfer_bytes": float(s.total_bytes),
            "evicted_bytes": float(s.total_evicted_bytes),
            "evict_bytes_by_dest": {
                k: float(v) for k, v in sorted(s.evict_bytes_by_dest.items())
            },
            "spilled_bytes": float(s.total_spilled_bytes),
            "spill_bytes_by_dest": {
                k: float(v) for k, v in sorted(s.spill_bytes_by_dest.items())
            },
            "oversubscribed": list(s.oversubscribed),
            "capacities": self._capacities(),
        }

    def pcast_check(self, genes: Sequence[int]
                    ) -> Optional[pcast.PcastReport]:
        hot = RUNNABLE.get(self.prog.name)
        if hot is None:
            return None
        loop_name, pair = hot
        host = self._evaluator.dests[0].name
        offloaded = self.placement(genes)[loop_name] != host
        ref, off = pair(offloaded)
        return pcast.compare(ref, off, rel_tol=self.spec.rel_tol,
                             abs_tol=self.spec.abs_tol)


class ArchPlanEvaluator:
    """Analytic per-unit roofline for the framework-level search
    (moved verbatim from examples/ga_arch_search.py): offloaded units
    run TP-sharded, baseline units replicated (x16 compute), collectives
    charged per offloaded unit boundary."""

    def __init__(self, arch: str):
        from repro.configs import get_arch

        self.arch = arch
        self.cfg = get_arch(arch)

    def __call__(self, genes: Sequence[int]) -> float:
        from repro.configs.base import TRAIN_4K
        from repro.core import analysis
        from repro.launch.roofline import model_flops

        plan = analysis.build_plan(self.cfg, None, genes=tuple(genes))
        t = 0.0
        flops = model_flops(self.cfg, TRAIN_4K) / 256
        per_unit = flops / max(len(plan.units), 1)
        for u in plan.units:
            rate = 197e12
            t += per_unit / rate / (1.0 if u.offload else 16.0) * 16.0 \
                if not u.offload else per_unit / rate
            if u.offload:
                t += 2 * self.cfg.d_model * 4096 * 2 / 50e9 / 1e3  # reshard
        return t

    def fingerprint(self) -> str:
        # kept identical to the pre-redesign closure's fingerprint so
        # existing persistent caches keep hitting
        return f"analytic-plan:{self.arch}"


class ArchAdapter:
    """Beyond-paper: genes toggle stage-group offload in an ExecutionPlan.

    The default evaluator is the instant analytic one; the Offloader's
    ``evaluator=`` injection swaps in a ``CompiledEvaluator`` for real
    AOT-compile scoring (examples/ga_arch_search.py --compiled).
    """

    kind = "arch"
    deterministic = True

    def __init__(self, spec: OffloadSpec,
                 hw: Optional[ev.HardwareModel] = None):
        from repro.configs import get_arch
        from repro.core import analysis

        self.spec = spec
        self.cfg = get_arch(spec.arch_name)
        self.units = analysis.build_units(self.cfg, None)

    @property
    def gene_length(self) -> int:
        return len(self.units)

    @property
    def alleles(self) -> int:
        return 2

    @property
    def allele_names(self) -> Tuple[str, ...]:
        return ("cpu", "accel")

    def build_evaluator(self) -> ArchPlanEvaluator:
        return ArchPlanEvaluator(self.spec.arch_name)

    def baseline_time(self) -> float:
        return self.build_evaluator()((0,) * self.gene_length)

    def analyze_payload(self) -> Dict[str, Any]:
        from repro.core import analysis

        return {
            "program": self.spec.program,
            "description": f"{self.spec.arch_name} execution plan",
            "gene_length": self.gene_length,
            "units": [
                {"name": u.name, "directive": u.directive.value}
                for u in self.units
            ],
            "applicability": analysis.applicability_notes(self.cfg, None),
        }

    def placement(self, genes: Sequence[int]) -> Dict[str, str]:
        return {
            u.name: "accel" if g else "cpu"
            for g, u in zip(genes, self.units)
        }

    def describe_plan(self, genes: Sequence[int]) -> str:
        from repro.core import analysis

        return analysis.build_plan(
            self.cfg, None, genes=tuple(genes)
        ).describe()

    def pcast_check(self, genes: Sequence[int]) -> None:
        return None  # no runnable reference pair at the plan level


def resolve_adapter(spec: OffloadSpec,
                    hw: Optional[ev.HardwareModel] = None):
    if spec.is_arch:
        return ArchAdapter(spec, hw)
    if spec.fidelity == "measured":
        return MiniappMeasuredAdapter(spec, hw)
    if spec.mode == "mixed":
        return MiniappMixedAdapter(spec, hw)
    return MiniappBinaryAdapter(spec, hw)
