"""CLI for the staged offload pipeline.

  python -m repro.offload run --program himeno --mode binary
  python -m repro.offload run --program hetero --mode mixed \\
      --destinations cpu,gpu,fpga --warm-start --cache /tmp/hetero.jsonl
  python -m repro.offload run --program himeno --smoke   # CI gate
  python -m repro.offload resume --artifact himeno-binary.offload.json
  python -m repro.offload report --artifact himeno-binary.offload.json

``run`` executes every stage (analyze -> seed -> search -> verify ->
report) and saves the artifact after each one; a failed stage (e.g. the
PCAST result-difference check) exits non-zero with the failure recorded
in the artifact. ``resume`` continues a saved artifact, skipping its
completed stages — an interrupted *search* additionally resumes warm
through the spec's persistent fitness cache. ``report`` pretty-prints an
artifact (partial ones included) without running anything.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.offload.pipeline import Offloader, render_report
from repro.offload.result import STAGES, OffloadResult, StageFailure
from repro.offload.spec import (
    METHODS,
    MIXED_SMOKE_BUDGET,
    MODES,
    OffloadSpec,
)


def _default_artifact(spec: OffloadSpec) -> str:
    tag = spec.program.replace(":", "-")
    return f"{tag}-{spec.mode}.offload.json"


def _spec_from_args(args: argparse.Namespace) -> OffloadSpec:
    kw = dict(
        program=args.program,
        mode=args.mode,
        method=args.method,
        destinations=tuple(args.destinations.split(",")),
        hw=args.hw,
        population=args.population,
        generations=args.generations,
        seed=args.seed,
        timeout_s=args.timeout_s,
        warm_start=args.warm_start,
        workers=args.workers,
        executor=args.executor,
        cache=args.cache,
        rel_tol=args.rel_tol,
        abs_tol=args.abs_tol,
    )
    if args.smoke and args.mode == "mixed":
        # binary paper-rule budgets are already seconds-scale on the
        # analytic evaluator; only the mixed budget needs trimming
        kw["population"] = kw["population"] or MIXED_SMOKE_BUDGET[0]
        kw["generations"] = kw["generations"] or MIXED_SMOKE_BUDGET[1]
    return OffloadSpec(**kw)


def _progress(stats) -> None:
    print(f"  gen {stats.generation:2d}: best {stats.best_time_s:.4g}s "
          f"(hit-rate {stats.hit_rate:.0%})")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.offload",
        description="staged offload pipeline: analyze -> seed -> search "
                    "-> verify -> report",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="run the pipeline for a new spec")
    run.add_argument("--program", required=True,
                     help="miniapp name (himeno/nasft/hetero) or "
                          "arch:<name>")
    run.add_argument("--mode", choices=list(MODES), default="binary")
    run.add_argument("--method", choices=sorted(METHODS),
                     default="proposed", help="binary-mode configuration")
    run.add_argument("--destinations", default="cpu,gpu,fpga",
                     help="mixed-mode destination subset (host first)")
    run.add_argument("--hw", default="quadro-p4000")
    run.add_argument("--population", type=int, default=None)
    run.add_argument("--generations", type=int, default=None)
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--timeout-s", type=float, default=None)
    run.add_argument("--warm-start", action="store_true",
                     help="mixed mode: seed the k-ary population with "
                          "single-destination bests")
    run.add_argument("--workers", type=int, default=1)
    run.add_argument("--executor", choices=("thread", "process"),
                     default="thread")
    run.add_argument("--cache", default=None, metavar="PATH",
                     help="persistent JSONL fitness cache (resume rides "
                          "on it)")
    run.add_argument("--rel-tol", type=float, default=None,
                     help="PCAST relative tolerance override")
    run.add_argument("--abs-tol", type=float, default=None,
                     help="PCAST absolute tolerance override")
    run.add_argument("--artifact", default=None, metavar="PATH",
                     help="artifact path (default <program>-<mode>"
                          ".offload.json)")
    run.add_argument("--until", choices=STAGES, default="report")
    run.add_argument("--smoke", action="store_true",
                     help="CI-sized budget (small GA)")
    run.add_argument("--quiet", action="store_true")

    res = sub.add_parser("resume", help="continue a saved artifact")
    res.add_argument("--artifact", required=True, metavar="PATH")
    res.add_argument("--until", choices=STAGES, default="report")
    res.add_argument("--quiet", action="store_true")

    rep = sub.add_parser("report", help="pretty-print a saved artifact")
    rep.add_argument("--artifact", required=True, metavar="PATH")

    args = ap.parse_args(argv)

    if args.cmd == "report":
        art = OffloadResult.load(args.artifact)
        print(art.summary())
        print()
        if art.completed("report"):
            print(art.stage("report").payload["text"])
        else:
            print(render_report(art))
        return 0

    on_gen = None if args.quiet else _progress
    if args.cmd == "run":
        try:
            spec = _spec_from_args(args)
        except ValueError as e:
            ap.error(str(e))
        off = Offloader(spec, artifact_path=args.artifact
                        or _default_artifact(spec), on_generation=on_gen)
    else:  # resume
        off = Offloader.resume(args.artifact, on_generation=on_gen)

    try:
        result = off.run(until=args.until)
    except StageFailure as e:
        print(f"error: {e}", file=sys.stderr)
        print(f"artifact: {off.result.path}", file=sys.stderr)
        return 1
    if result.completed("report"):
        print(result.stage("report").payload["text"])
    else:
        print(render_report(result))
    print(f"artifact: {result.path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
