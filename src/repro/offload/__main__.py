"""CLI for the staged offload pipeline.

  python -m repro.offload run --program himeno --mode binary
  python -m repro.offload run --program hetero --mode mixed \\
      --destinations cpu,gpu,fpga --warm-start --cache /tmp/hetero.jsonl
  python -m repro.offload run --program himeno --fidelity measured \\
      --workers 2 --population 4 --generations 2
  python -m repro.offload run --program himeno --smoke   # CI gate
  python -m repro.offload calibrate --base quadro-p4000 \\
      --out p4000.calib.json
  python -m repro.offload run --program hetero --mode mixed \\
      --calibration p4000.calib.json --hw quadro-p4000-calibrated
  python -m repro.offload resume --artifact himeno-binary.offload.json
  python -m repro.offload report --artifact himeno-binary.offload.json

``run`` executes every stage (calibrate -> analyze -> seed -> search ->
verify -> report) and saves the artifact after each one; a failed stage
(e.g. the PCAST result-difference check) exits non-zero with the failure
recorded in the artifact. ``resume`` continues a saved artifact, skipping
its completed stages — an interrupted *search* additionally resumes warm
through the spec's persistent fitness cache. ``report`` pretty-prints an
artifact (partial ones included) without running anything. ``calibrate``
measures the probe set, fits the machine constants, and saves a
``.calib.json`` that ``--calibration`` installs in later invocations
(docs/fidelity.md).
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.offload.pipeline import Offloader, render_report
from repro.offload.result import STAGES, OffloadResult, StageFailure
from repro.offload.spec import (
    FIDELITIES,
    METHODS,
    MIXED_SMOKE_BUDGET,
    MODES,
    OffloadSpec,
)


def _default_artifact(spec: OffloadSpec) -> str:
    tag = spec.program.replace(":", "-")
    return f"{tag}-{spec.mode}.offload.json"


def _spec_from_args(args: argparse.Namespace) -> OffloadSpec:
    # --executor defaults per fidelity: measured wall-clocks in spawned
    # subprocesses (spec validation enforces it), everything else threads
    executor = args.executor or (
        "process" if args.fidelity == "measured" else "thread"
    )
    kw = dict(
        program=args.program,
        mode=args.mode,
        method=args.method,
        destinations=tuple(args.destinations.split(",")),
        hw=args.hw,
        fidelity=args.fidelity,
        repeats=args.repeats,
        population=args.population,
        generations=args.generations,
        seed=args.seed,
        timeout_s=args.timeout_s,
        warm_start=args.warm_start,
        workers=args.workers,
        executor=executor,
        cache=args.cache,
        rel_tol=args.rel_tol,
        abs_tol=args.abs_tol,
    )
    if args.smoke and args.mode == "mixed":
        # binary paper-rule budgets are already seconds-scale on the
        # analytic evaluator; only the mixed budget needs trimming
        kw["population"] = kw["population"] or MIXED_SMOKE_BUDGET[0]
        kw["generations"] = kw["generations"] or MIXED_SMOKE_BUDGET[1]
    return OffloadSpec(**kw)


def _progress(stats) -> None:
    print(f"  gen {stats.generation:2d}: best {stats.best_time_s:.4g}s "
          f"(hit-rate {stats.hit_rate:.0%})")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.offload",
        description="staged offload pipeline: analyze -> seed -> search "
                    "-> verify -> report",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="run the pipeline for a new spec")
    run.add_argument("--program", required=True,
                     help="miniapp name (himeno/nasft/hetero) or "
                          "arch:<name>")
    run.add_argument("--mode", choices=list(MODES), default="binary")
    run.add_argument("--method", choices=sorted(METHODS),
                     default="proposed", help="binary-mode configuration")
    run.add_argument("--destinations", default="cpu,gpu,fpga",
                     help="mixed-mode destination subset (host first)")
    run.add_argument("--hw", default="quadro-p4000")
    run.add_argument("--fidelity", choices=list(FIDELITIES),
                     default="modeled",
                     help="how candidates are priced: the analytic model "
                          "(modeled), real subprocess wall clocks "
                          "(measured), or the model under constants "
                          "fitted to this machine (calibrated)")
    run.add_argument("--repeats", type=int, default=1,
                     help="measurement repeats per individual/probe "
                          "(measured/calibrated fidelity)")
    run.add_argument("--calibration", default=None, metavar="PATH",
                     help="install a saved .calib.json before building "
                          "the spec, so --hw can name its entry")
    run.add_argument("--population", type=int, default=None)
    run.add_argument("--generations", type=int, default=None)
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--timeout-s", type=float, default=None)
    run.add_argument("--warm-start", action="store_true",
                     help="mixed mode: seed the k-ary population with "
                          "single-destination bests")
    run.add_argument("--workers", type=int, default=1)
    run.add_argument("--executor", choices=("thread", "process"),
                     default=None,
                     help="measurement executor (default: thread; "
                          "process under --fidelity measured)")
    run.add_argument("--cache", default=None, metavar="PATH",
                     help="persistent JSONL fitness cache (resume rides "
                          "on it)")
    run.add_argument("--rel-tol", type=float, default=None,
                     help="PCAST relative tolerance override")
    run.add_argument("--abs-tol", type=float, default=None,
                     help="PCAST absolute tolerance override")
    run.add_argument("--artifact", default=None, metavar="PATH",
                     help="artifact path (default <program>-<mode>"
                          ".offload.json)")
    run.add_argument("--until", choices=STAGES, default="report")
    run.add_argument("--smoke", action="store_true",
                     help="CI-sized budget (small GA)")
    run.add_argument("--quiet", action="store_true")

    res = sub.add_parser("resume", help="continue a saved artifact")
    res.add_argument("--artifact", required=True, metavar="PATH")
    res.add_argument("--until", choices=STAGES, default="report")
    res.add_argument("--calibration", default=None, metavar="PATH",
                     help="install a saved .calib.json first (needed when "
                          "the artifact's spec names a calibrated machine "
                          "that is not embedded in the artifact itself)")
    res.add_argument("--quiet", action="store_true")

    rep = sub.add_parser("report", help="pretty-print a saved artifact")
    rep.add_argument("--artifact", required=True, metavar="PATH")

    cal = sub.add_parser(
        "calibrate",
        help="measure the probe set, fit machine constants, save a "
             ".calib.json entry usable via --calibration/--hw",
    )
    cal.add_argument("--base", default="quadro-p4000",
                     help="base machine registry to calibrate")
    cal.add_argument("--name", default=None,
                     help="entry name (default <base>-calibrated)")
    cal.add_argument("--repeats", type=int, default=3,
                     help="wall-clock repeats per probe (min kept; >1 "
                          "excludes one-time jit compiles)")
    cal.add_argument("--out", default=None, metavar="PATH",
                     help="where to save (default <name>.calib.json)")

    args = ap.parse_args(argv)

    if args.cmd == "calibrate":
        from repro.offload import calibrate as cal_mod

        name = args.name or f"{args.base}-calibrated"
        try:
            cal_res = cal_mod.run_calibration(
                base=args.base, repeats=args.repeats, name=name
            )
        except ValueError as e:
            ap.error(str(e))
        out = args.out or f"{name}.calib.json"
        cal_res.save(out)
        r = cal_res.residuals()
        print(f"calibrated {cal_res.base} -> {cal_res.name} "
              f"(hw {cal_res.hw_name}) on {cal_res.host}")
        for p in cal_res.probes:
            print(f"  {p['app']:7s} {p['dest']:5s} "
                  f"{'x'.join(map(str, p['grid'])):>10s} x{p['steps']}: "
                  f"measured {p['measured_s']:.4g}s fitted "
                  f"{p['fitted_s']:.4g}s ({p['rel_err']:+.1%})")
        print(f"residuals: max |{r['max_abs_rel']:.1%}| mean "
              f"|{r['mean_abs_rel']:.1%}| over {r['n']} probes; "
              f"pinned: {', '.join(cal_res.pinned)}")
        print(f"saved: {out}")
        print(f"use it:  python -m repro.offload run ... "
              f"--calibration {out} --hw {cal_res.name}")
        return 0

    if getattr(args, "calibration", None):
        from repro.offload import calibrate as cal_mod

        cal_mod.load_and_install(args.calibration)

    if args.cmd == "report":
        art = OffloadResult.load(args.artifact)
        print(art.summary())
        print()
        if art.completed("report"):
            print(art.stage("report").payload["text"])
        else:
            print(render_report(art))
        return 0

    on_gen = None if args.quiet else _progress
    if args.cmd == "run":
        try:
            spec = _spec_from_args(args)
        except ValueError as e:
            ap.error(str(e))
        off = Offloader(spec, artifact_path=args.artifact
                        or _default_artifact(spec), on_generation=on_gen)
    else:  # resume
        off = Offloader.resume(args.artifact, on_generation=on_gen)

    try:
        result = off.run(until=args.until)
    except StageFailure as e:
        print(f"error: {e}", file=sys.stderr)
        print(f"artifact: {off.result.path}", file=sys.stderr)
        return 1
    if result.completed("report"):
        print(result.stage("report").payload["text"])
    else:
        print(render_report(result))
    print(f"artifact: {result.path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
