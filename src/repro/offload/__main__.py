"""CLI for the staged offload pipeline.

  python -m repro.offload run --program himeno --mode binary
  python -m repro.offload run --program hetero --mode mixed \\
      --destinations cpu,gpu,fpga --warm-start --cache /tmp/hetero.jsonl
  python -m repro.offload run --program hetero --mode mixed --blocks
  python -m repro.offload run --program himeno --fidelity measured \\
      --workers 2 --population 4 --generations 2
  python -m repro.offload run --program himeno --smoke   # CI gate
  python -m repro.offload calibrate --base quadro-p4000 \\
      --out p4000.calib.json
  python -m repro.offload run --program hetero --mode mixed \\
      --calibration p4000.calib.json --hw quadro-p4000-calibrated
  python -m repro.offload resume --artifact himeno-binary.offload.json
  python -m repro.offload report --artifact himeno-binary.offload.json
  python -m repro.offload trace --artifact himeno-binary.offload.json
  python -m repro.offload sweep --smoke            # CI fast tier
  python -m repro.offload sweep --workers 4        # the full model zoo

``run`` executes every stage (calibrate -> analyze -> seed -> search ->
verify -> report) and saves the artifact after each one; a failed stage
(e.g. the PCAST result-difference check) exits non-zero with the failure
recorded in the artifact. ``resume`` continues a saved artifact, skipping
its completed stages — an interrupted *search* additionally resumes warm
through the spec's persistent fitness cache. ``report`` pretty-prints an
artifact (partial ones included) without running anything. ``trace``
loads the structured JSONL trace written next to the artifact
(docs/observability.md), verifies it against the digest embedded in the
artifact, and renders the span tree plus a per-stage budget-attribution
table. ``calibrate``
measures the probe set, fits the machine constants, and saves a
``.calib.json`` that ``--calibration`` installs in later invocations
(docs/fidelity.md). ``sweep`` runs the programs x machines x modes
matrix cell-by-cell (resumable), appends one trajectory point to
``BENCH_sweep.json``, renders the leaderboard, and flags regressions
against the previous point (docs/benchmarks.md).

Every verb documents its exit codes in its ``--help`` epilog; the table
itself lives in :data:`EXIT_CODES` (asserted in tests/test_docs.py).
Argparse usage errors exit 2 on every verb, as usual.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Tuple

from repro.offload.pipeline import Offloader, render_report
from repro.offload.result import STAGES, OffloadResult, StageFailure
from repro.offload.spec import (
    FIDELITIES,
    GAControls,
    METHODS,
    MIXED_SMOKE_BUDGET,
    MODES,
    OffloadSpec,
)


# exit codes per verb, rendered into each subparser's --help epilog and
# asserted verbatim in tests/test_docs.py. 2 is argparse's own usage-
# error code on every verb; the sweep's regression flag deliberately
# takes a code of its own (3) so nightly CI can tell "a cell's pipeline
# broke" (1) from "everything ran but got slower" (3).
EXIT_CODES: Dict[str, Tuple[Tuple[int, str], ...]] = {
    "run": (
        (0, "every stage up to --until completed"),
        (1, "a stage failed (PCAST mismatch, verify drift, ...); the "
            "failure is recorded in the artifact"),
        (2, "usage error"),
    ),
    "resume": (
        (0, "every remaining stage up to --until completed"),
        (1, "a stage failed; the failure is recorded in the artifact"),
        (2, "usage error"),
    ),
    "report": (
        (0, "artifact loaded and printed (partial artifacts included)"),
        (2, "usage error"),
    ),
    "trace": (
        (0, "trace loaded, validated, digest-checked against the "
            "artifact, and rendered"),
        (1, "trace file missing or malformed, or its digest does not "
            "match the one embedded in the artifact"),
        (2, "usage error"),
    ),
    "calibrate": (
        (0, "probe set measured, constants fitted, .calib.json saved"),
        (2, "usage error (incl. an unknown --base registry)"),
    ),
    "sweep": (
        (0, "every cell ran (or resumed complete); no regression vs the "
            "previous trajectory point"),
        (1, "at least one cell's pipeline failed (its error is recorded "
            "in the trajectory point; remaining cells still ran)"),
        (2, "usage error"),
        (3, "all cells ok, but at least one regressed beyond --tolerance "
            "vs the previous trajectory point"),
    ),
    "serve": (
        (0, "action completed: spec submitted (or coalesced onto an "
            "existing job), queue drained with every job DONE/CANCELLED, "
            "or status/result/cancel served"),
        (1, "unknown job id, at least one job FAILED during the drain, "
            "or the drain died on an injected crash (--fault crash-*)"),
        (2, "usage error"),
    ),
}


def _epilog(verb: str) -> str:
    rows = "\n".join(f"  {code}  {what}" for code, what in EXIT_CODES[verb])
    return f"exit codes:\n{rows}"


def _add_verb(sub, name: str, help_: str) -> argparse.ArgumentParser:
    """A subparser whose --help epilog is the verb's exit-code table."""
    return sub.add_parser(
        name, help=help_, epilog=_epilog(name),
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )


def _add_spec_args(p: argparse.ArgumentParser) -> None:
    """The OffloadSpec-building flags, shared verbatim by ``run`` and
    ``serve submit`` (consumed by :func:`_spec_from_args`)."""
    p.add_argument("--program", required=True,
                   help="miniapp name (himeno/nasft/hetero) or "
                        "arch:<name>")
    p.add_argument("--mode", choices=list(MODES), default="binary")
    p.add_argument("--method", choices=sorted(METHODS),
                   default="proposed", help="binary-mode configuration")
    p.add_argument("--destinations", default="cpu,gpu,fpga",
                   help="mixed-mode destination subset (host first)")
    p.add_argument("--hw", default="quadro-p4000")
    p.add_argument("--fidelity", choices=list(FIDELITIES),
                   default="modeled",
                   help="how candidates are priced: the analytic model "
                        "(modeled), real subprocess wall clocks "
                        "(measured), or the model under constants "
                        "fitted to this machine (calibrated)")
    p.add_argument("--repeats", type=int, default=1,
                   help="measurement repeats per individual/probe "
                        "(measured/calibrated fidelity)")
    p.add_argument("--population", type=int, default=None)
    p.add_argument("--generations", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--timeout-s", type=float, default=None)
    p.add_argument("--warm-start", action="store_true",
                   help="mixed mode: seed the k-ary population with "
                        "single-destination bests")
    p.add_argument("--blocks", action="store_true",
                   help="mixed mode: match loop chains against the "
                        "kernel library and let the genome substitute "
                        "tuned implementations (docs/blocks.md)")
    p.add_argument("--workers", type=int, default=1)
    p.add_argument("--executor", choices=("thread", "process"),
                   default=None,
                   help="measurement executor (default: thread; "
                        "process under --fidelity measured)")
    p.add_argument("--cache", default=None, metavar="PATH",
                   help="persistent JSONL fitness cache (resume rides "
                        "on it; `serve` overrides it with the queue "
                        "directory's shared store)")
    p.add_argument("--rel-tol", type=float, default=None,
                   help="PCAST relative tolerance override")
    p.add_argument("--abs-tol", type=float, default=None,
                   help="PCAST absolute tolerance override")
    p.add_argument("--diversity", type=float, default=None,
                   help="fitness-sharing strength for GA selection "
                        "(default 0 = off, byte-identical to the "
                        "historical selection)")
    p.add_argument("--stability-seeds", type=int, default=None,
                   metavar="K",
                   help="pass@k winner-stability seeds re-searched by "
                        "the report stage (default 3; <=1 disables)")
    p.add_argument("--stability-window", type=float, default=None,
                   help="relative window a seed's best must land in to "
                        "'pass' (default 0.02)")
    p.add_argument("--stability-gate", type=float, default=None,
                   help="fail the report stage when the winners' "
                        "relative spread exceeds this (default: no "
                        "gate)")
    p.add_argument("--rank-probe", action="store_true",
                   help="wall-clock the two winner projections so even "
                        "modeled/calibrated runs record modeled-vs-"
                        "measured rank correlation")
    p.add_argument("--steady-state", action="store_true",
                   help="asynchronous steady-state GA: breed offspring "
                        "per free worker lane instead of idling at the "
                        "generation barrier (docs/pipeline.md)")
    p.add_argument("--batch-eval", action="store_true",
                   help="mixed mode: price whole populations in one "
                        "vectorized pass (scalar evaluator stays the "
                        "verify oracle)")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized budget (small GA)")


def _default_artifact(spec: OffloadSpec) -> str:
    tag = spec.program.replace(":", "-")
    return f"{tag}-{spec.mode}.offload.json"


def _spec_from_args(args: argparse.Namespace) -> OffloadSpec:
    # --executor defaults per fidelity: measured wall-clocks in spawned
    # subprocesses (spec validation enforces it), everything else threads
    executor = args.executor or (
        "process" if args.fidelity == "measured" else "thread"
    )
    kw = dict(
        program=args.program,
        mode=args.mode,
        method=args.method,
        destinations=tuple(args.destinations.split(",")),
        hw=args.hw,
        fidelity=args.fidelity,
        repeats=args.repeats,
        population=args.population,
        generations=args.generations,
        seed=args.seed,
        timeout_s=args.timeout_s,
        warm_start=args.warm_start,
        blocks=args.blocks,
        workers=args.workers,
        executor=executor,
        cache=args.cache,
        rel_tol=args.rel_tol,
        abs_tol=args.abs_tol,
    )
    if args.smoke and args.mode == "mixed":
        # binary paper-rule budgets are already seconds-scale on the
        # analytic evaluator; only the mixed budget needs trimming
        kw["population"] = kw["population"] or MIXED_SMOKE_BUDGET[0]
        kw["generations"] = kw["generations"] or MIXED_SMOKE_BUDGET[1]
    ga_kw = {}
    if args.diversity is not None:
        ga_kw["diversity"] = args.diversity
    if args.stability_seeds is not None:
        ga_kw["stability_seeds"] = args.stability_seeds
    if args.stability_window is not None:
        ga_kw["stability_window"] = args.stability_window
    if args.stability_gate is not None:
        ga_kw["stability_gate"] = args.stability_gate
    if args.rank_probe:
        ga_kw["rank_probe"] = True
    if args.steady_state:
        ga_kw["steady_state"] = True
    if args.batch_eval:
        ga_kw["batch"] = True
    if ga_kw:
        kw["ga"] = GAControls(**ga_kw)
    return OffloadSpec(**kw)


def _progress(stats) -> None:
    print(f"  gen {stats.generation:2d}: best {stats.best_time_s:.4g}s "
          f"(hit-rate {stats.hit_rate:.0%})")


def _cmd_sweep(ap: argparse.ArgumentParser, args: argparse.Namespace) -> int:
    """The sweep verb: enumerate the matrix, run it resumably, append a
    trajectory point, print the leaderboard, exit by EXIT_CODES."""
    from repro.offload import sweep as sw

    out = args.out or sw.DEFAULT_TRAJECTORY
    tol = args.tolerance if args.tolerance is not None \
        else sw.DEFAULT_REL_TOLERANCE
    if args.report_only:
        try:
            traj = sw.Trajectory.load(out)
        except ValueError as e:
            ap.error(str(e))
        print(sw.render_leaderboard(traj, tol))
        if traj.last is None:
            return 0
        return 3 if sw.flag_regressions(traj.previous, traj.last, tol) \
            else 0

    if args.smoke:
        cells, skipped = sw.smoke_matrix()
    else:
        try:
            cells, skipped = sw.enumerate_matrix(
                args.programs.split(",") if args.programs else None,
                args.machines.split(",") if args.machines else None,
                tuple(args.modes.split(",")),
            )
        except ValueError as e:
            ap.error(str(e))
    if not cells:
        ap.error("matrix has no feasible cells (every combination was "
                 "skipped); widen --programs/--machines/--modes")
    sweep_dir = args.sweep_dir or (
        sw.DEFAULT_SMOKE_DIR if args.smoke else sw.DEFAULT_SWEEP_DIR
    )
    point = sw.run_sweep(
        cells, skipped, out_dir=sweep_dir, cache=args.cache,
        workers=args.workers, smoke=args.smoke, seed=args.seed,
        label=args.label, progress=None if args.quiet else print,
    )
    if args.no_append:
        traj = sw.Trajectory.load(out)
        prev = traj.last  # the point was not persisted; compare to last
        traj.points.append(point)  # in-memory, for the leaderboard only
    else:
        traj = sw.append_point(out, point)
        prev = traj.previous
    print(sw.render_leaderboard(traj, tol))
    if not args.no_append:
        print(f"trajectory: {out} ({len(traj.points)} points)")
    if point["totals"]["n_failed"]:
        return 1
    return 3 if sw.flag_regressions(prev, point, tol) else 0


def _cmd_serve(ap: argparse.ArgumentParser, args: argparse.Namespace) -> int:
    """The serve verb: drive an OffloadService over --dir. Exit codes
    per EXIT_CODES['serve']."""
    from repro.serve.admission import AdmissionPolicy
    from repro.serve.jobs import FAILED, JobError
    from repro.serve.offload_service import (
        FaultPlan,
        OffloadService,
        ServiceCrash,
    )

    policy_kw = {}
    for field in ("max_in_flight", "max_generations", "max_population",
                  "max_workers", "max_stability_seeds"):
        v = getattr(args, field, None)
        if v is not None:
            policy_kw[field] = v
    fault = None
    if getattr(args, "fault", None):
        try:
            fault = FaultPlan.parse(args.fault)
        except ValueError as e:
            ap.error(str(e))
    try:
        policy = AdmissionPolicy(**policy_kw)
    except ValueError as e:
        ap.error(str(e))
    svc = OffloadService(args.dir, policy=policy, fault=fault)

    if args.action == "submit":
        try:
            spec = _spec_from_args(args)
        except ValueError as e:
            ap.error(str(e))
        receipt = svc.submit(spec, force=args.force)
        if args.quiet:
            print(receipt.job_id)
        elif receipt.coalesced:
            print(f"coalesced onto existing job {receipt.job_id} "
                  f"(spec digest {receipt.digest})")
        else:
            line = f"queued {receipt.job_id} (spec digest {receipt.digest})"
            if receipt.clamped:
                clamps = ", ".join(
                    f"{k} {req}->{got}"
                    for k, (req, got) in sorted(receipt.clamped.items())
                )
                line += f"; admission clamped: {clamps}"
            print(line)
        return 0

    if args.action == "run":
        try:
            jobs = svc.run()
        except ServiceCrash as e:
            print(f"service crashed: {e}", file=sys.stderr)
            return 1
        failed = 0
        for j in jobs:
            extra = f"  !! {j.error}" if j.error else ""
            dup = svc.store.coalesced_count(j.id)
            dup_txt = f"  (+{dup} coalesced)" if dup else ""
            print(f"{j.id:24s} {j.state:9s} restarts={j.restarts}"
                  f"{dup_txt}{extra}")
            failed += j.state == FAILED
        return 1 if failed else 0

    try:
        if args.action == "status":
            if args.job:
                j = svc.status(args.job)
                print(f"{j.id}: {j.state} (seq {j.seq}, restarts "
                      f"{j.restarts}, digest {j.digest}, "
                      f"{svc.store.coalesced_count(j.id)} coalesced)")
                if j.clamped:
                    for k, (req, got) in sorted(j.clamped.items()):
                        print(f"  clamped {k}: {req} -> {got}")
                if j.error:
                    print(f"  error: {j.error}")
            else:
                for j in svc.jobs():
                    print(f"{j.id:24s} {j.state:9s} restarts={j.restarts}")
        elif args.action == "result":
            art = svc.result(args.job)
            print(art.summary())
            print(f"artifact: {svc.store.artifact_path(args.job)}")
            print(f"trace: {svc.store.trace_path(args.job)}")
        else:  # cancel
            svc.cancel(args.job)
            print(f"cancel requested: {args.job}")
    except JobError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.offload",
        description="staged offload pipeline: analyze -> seed -> search "
                    "-> verify -> report",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = _add_verb(sub, "run", "run the pipeline for a new spec")
    _add_spec_args(run)
    run.add_argument("--calibration", default=None, metavar="PATH",
                     help="install a saved .calib.json before building "
                          "the spec, so --hw can name its entry")
    run.add_argument("--artifact", default=None, metavar="PATH",
                     help="artifact path (default <program>-<mode>"
                          ".offload.json)")
    run.add_argument("--until", choices=STAGES, default="report")
    run.add_argument("--no-trace", action="store_true",
                     help="skip writing the JSONL trace next to the "
                          "artifact")
    run.add_argument("--quiet", action="store_true")

    res = _add_verb(sub, "resume", "continue a saved artifact")
    res.add_argument("--artifact", required=True, metavar="PATH")
    res.add_argument("--until", choices=STAGES, default="report")
    res.add_argument("--calibration", default=None, metavar="PATH",
                     help="install a saved .calib.json first (needed when "
                          "the artifact's spec names a calibrated machine "
                          "that is not embedded in the artifact itself)")
    res.add_argument("--no-trace", action="store_true",
                     help="skip continuing the JSONL trace next to the "
                          "artifact")
    res.add_argument("--quiet", action="store_true")

    rep = _add_verb(sub, "report", "pretty-print a saved artifact")
    rep.add_argument("--artifact", required=True, metavar="PATH")

    trc = _add_verb(
        sub, "trace",
        "validate and render an artifact's JSONL trace: span tree, "
        "per-generation telemetry, budget attribution",
    )
    trc.add_argument("--artifact", required=True, metavar="PATH")
    trc.add_argument("--trace", default=None, metavar="PATH",
                     help="trace file (default: the artifact path with "
                          ".json swapped for .trace.jsonl)")

    cal = _add_verb(
        sub, "calibrate",
        "measure the probe set, fit machine constants, save a "
        ".calib.json entry usable via --calibration/--hw",
    )
    cal.add_argument("--base", default="quadro-p4000",
                     help="base machine registry to calibrate")
    cal.add_argument("--name", default=None,
                     help="entry name (default <base>-calibrated)")
    cal.add_argument("--repeats", type=int, default=3,
                     help="wall-clock repeats per probe (min kept; >1 "
                          "excludes one-time jit compiles)")
    cal.add_argument("--out", default=None, metavar="PATH",
                     help="where to save (default <name>.calib.json)")
    cal.add_argument("--kernels", action="store_true",
                     help="also time the block-substitution kernel "
                          "library against its oracles and fit "
                          "per-kernel gains (docs/blocks.md)")

    swp = _add_verb(
        sub, "sweep",
        "run the model-zoo matrix (programs x machines x modes), append "
        "a BENCH trajectory point, render the leaderboard, flag "
        "regressions",
    )
    swp.add_argument("--programs", default=None,
                     help="comma-separated programs (default: every "
                          "miniapp + every arch:<name>)")
    swp.add_argument("--machines", default=None,
                     help="comma-separated machine registries (default: "
                          "all)")
    swp.add_argument("--modes", default=",".join(MODES),
                     help="comma-separated modes (default: binary,mixed)")
    swp.add_argument("--smoke", action="store_true",
                     help="the fixed 3-cell CI matrix at smoke budgets "
                          "(overrides --programs/--machines/--modes)")
    swp.add_argument("--dir", dest="sweep_dir", default=None, metavar="DIR",
                     help="per-cell artifact + fitness-cache directory "
                          "(default .sweep, .sweep-smoke under --smoke); "
                          "re-running against the same directory resumes: "
                          "complete cells are skipped outright")
    swp.add_argument("--cache", default=None, metavar="PATH",
                     help="shared JSONL fitness cache (default "
                          "<dir>/fitness.jsonl)")
    swp.add_argument("--out", default=None, metavar="PATH",
                     help="trajectory file to append to (default "
                          "BENCH_sweep.json)")
    swp.add_argument("--label", default=None,
                     help="free-form label recorded in the point")
    swp.add_argument("--tolerance", type=float, default=None,
                     help="relative regression tolerance vs the previous "
                          "point (default 0.05; strictly-beyond flags)")
    swp.add_argument("--workers", type=int, default=1)
    swp.add_argument("--seed", type=int, default=0)
    swp.add_argument("--no-append", action="store_true",
                     help="run + report but leave the trajectory file "
                          "untouched (regressions compare against its "
                          "LAST point instead of the previous one)")
    swp.add_argument("--report-only", action="store_true",
                     help="no searches: render the leaderboard of the "
                          "saved trajectory's last point (vs its "
                          "previous) and exit by the regression verdict")
    swp.add_argument("--quiet", action="store_true",
                     help="suppress per-cell progress lines")

    srv = _add_verb(
        sub, "serve",
        "offload-as-a-service against a filesystem queue directory: "
        "submit specs, drain the queue concurrently over one shared "
        "fitness cache, query/cancel jobs (docs/serving.md)",
    )
    srv_sub = srv.add_subparsers(dest="action", required=True)

    def _srv_action(name: str, help_: str) -> argparse.ArgumentParser:
        p = srv_sub.add_parser(name, help=help_)
        p.add_argument("--dir", required=True, metavar="DIR",
                       help="the service queue directory (jobs, traces "
                            "and the shared fitness cache live under it)")
        return p

    ssub = _srv_action("submit", "admit one spec into the queue "
                                 "(duplicates coalesce onto the "
                                 "existing job)")
    _add_spec_args(ssub)
    ssub.add_argument("--force", action="store_true",
                      help="run a fresh job even if an identical spec "
                           "is already queued/running/done (it still "
                           "shares the fitness cache)")
    ssub.add_argument("--max-generations", type=int, default=None,
                      help="admission clamp on the GA generation budget")
    ssub.add_argument("--max-population", type=int, default=None,
                      help="admission clamp on the GA population")
    ssub.add_argument("--max-workers", type=int, default=None,
                      help="admission clamp on per-job eval workers")
    ssub.add_argument("--max-stability-seeds", type=int, default=None,
                      help="admission clamp on report-stage stability "
                           "re-searches")
    ssub.add_argument("--quiet", action="store_true",
                      help="print only the job id (shell capture)")

    srun = _srv_action("run", "recover + drain the queue: resume every "
                              "non-terminal job, run QUEUED jobs "
                              "concurrently")
    srun.add_argument("--max-in-flight", type=int, default=None,
                      help="concurrent jobs bound (default 2)")
    srun.add_argument("--fault", default=None, metavar="SPEC",
                      help="fault-injection harness: <kind>:<arg>"
                           "[@<job-match>], kinds raise-in-stage, "
                           "raise-in-search, crash-after-stage, "
                           "crash-in-search, kill-after-stage, "
                           "kill-in-search (docs/serving.md)")

    sstat = _srv_action("status", "job table, or one job's record")
    sstat.add_argument("--job", default=None, metavar="ID")

    sres = _srv_action("result", "print a job's artifact summary + "
                                 "artifact/trace paths")
    sres.add_argument("--job", required=True, metavar="ID")

    scan = _srv_action("cancel", "request cancellation (honored before "
                                 "the job's next pipeline stage)")
    scan.add_argument("--job", required=True, metavar="ID")

    args = ap.parse_args(argv)

    if args.cmd == "sweep":
        return _cmd_sweep(ap, args)

    if args.cmd == "serve":
        return _cmd_serve(ap, args)

    if args.cmd == "calibrate":
        from repro.offload import calibrate as cal_mod

        name = args.name or f"{args.base}-calibrated"
        try:
            cal_res = cal_mod.run_calibration(
                base=args.base, repeats=args.repeats, name=name,
                kernels=args.kernels,
            )
        except ValueError as e:
            ap.error(str(e))
        out = args.out or f"{name}.calib.json"
        cal_res.save(out)
        r = cal_res.residuals()
        print(f"calibrated {cal_res.base} -> {cal_res.name} "
              f"(hw {cal_res.hw_name}) on {cal_res.host}")
        for p in cal_res.probes:
            print(f"  {p['app']:7s} {p['dest']:5s} "
                  f"{'x'.join(map(str, p['grid'])):>10s} x{p['steps']}: "
                  f"measured {p['measured_s']:.4g}s fitted "
                  f"{p['fitted_s']:.4g}s ({p['rel_err']:+.1%})")
        print(f"residuals: max |{r['max_abs_rel']:.1%}| mean "
              f"|{r['mean_abs_rel']:.1%}| over {r['n']} probes; "
              f"pinned: {', '.join(cal_res.pinned)}")
        for k, g in sorted(cal_res.kernel_constants.items()):
            print(f"  kernel {k}: gain {g:.3g}x vs oracle")
        print(f"saved: {out}")
        print(f"use it:  python -m repro.offload run ... "
              f"--calibration {out} --hw {cal_res.name}")
        return 0

    if getattr(args, "calibration", None):
        from repro.offload import calibrate as cal_mod

        cal_mod.load_and_install(args.calibration)

    if args.cmd == "report":
        art = OffloadResult.load(args.artifact)
        print(art.summary())
        print()
        if art.completed("report"):
            print(art.stage("report").payload["text"])
        else:
            print(render_report(art))
        return 0

    if args.cmd == "trace":
        from repro.offload import trace as trace_mod

        art = OffloadResult.load(args.artifact)
        path = args.trace or trace_mod.default_trace_path(args.artifact)
        try:
            tr = trace_mod.load_trace(path)
        except (trace_mod.TraceError, OSError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        print(trace_mod.render_trace(tr, artifact=art))
        if art.trace is not None and art.trace.get("digest") != tr.digest:
            print("error: trace digest does not match the artifact's "
                  "embedded digest (stale or foreign trace file)",
                  file=sys.stderr)
            return 1
        return 0

    on_gen = None if args.quiet else _progress
    if args.cmd == "run":
        try:
            spec = _spec_from_args(args)
        except ValueError as e:
            ap.error(str(e))
        off = Offloader(spec, artifact_path=args.artifact
                        or _default_artifact(spec), on_generation=on_gen,
                        trace=not args.no_trace)
    else:  # resume
        off = Offloader.resume(args.artifact, on_generation=on_gen,
                               trace=not args.no_trace)

    try:
        result = off.run(until=args.until)
    except StageFailure as e:
        print(f"error: {e}", file=sys.stderr)
        print(f"artifact: {off.result.path}", file=sys.stderr)
        return 1
    if result.completed("report"):
        print(result.stage("report").payload["text"])
    else:
        print(render_report(result))
    print(f"artifact: {result.path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
