"""Structured pipeline tracing: a schema-versioned JSONL trace per run.

Every :class:`~repro.offload.pipeline.Offloader` stage emits one **span**
record (name, status, injected-clock start/end, deterministic attrs) and
the search stage additionally emits one **event** per generation carrying
the :class:`~repro.core.evalpool.GenerationTelemetry` row (cache
hits/misses, dedup, timeouts, eval wall-clock) plus population stats
(best/median fitness, allele entropy). The report stage's quality work
(stability re-searches, rank-probe measurements) events its budget too,
so the trace attributes *every* measurement the pipeline paid for.
Block-substitution runs (``OffloadSpec.blocks``, docs/blocks.md) add
``block_match`` events under the analyze span and ``block_substitution``
oracle-verdict events under the verify span.

Design rules (docs/observability.md):

- **one JSONL file next to the artifact** (``<artifact>.trace.jsonl`` by
  default, :func:`default_trace_path`), append-only: a resumed pipeline
  appends a fresh ``run`` header and keeps going, so the trace is the
  full biography of the artifact, restarts included;
- **schema-versioned**: every ``run`` header carries
  ``schema=repro.offload.trace, v=1``; :func:`load_trace` validates
  structure and refuses foreign versions;
- **deterministic modulo the injected clock**: all timestamps come from
  the writer's ``clock`` callable (default ``time.perf_counter``) and
  live only under the keys :data:`TIMING_KEYS`; the **content digest**
  (sha256 over :func:`strip_timing`-stripped canonical JSON) therefore
  never depends on wall time — two identical modeled runs produce the
  same digest, which the artifact embeds (``OffloadResult.trace``) so
  ``python -m repro.offload trace`` can prove a trace file belongs to
  its artifact.

Record shapes (field tables in docs/observability.md)::

    {"seq": 0, "kind": "run",   "schema": ..., "v": 1, "ts": ...,
     "program": ..., "mode": ..., "fidelity": ..., "spec_digest": ...,
     "resumed": ...}
    {"seq": n, "kind": "span",  "name": "<stage>", "status": ...,
     "t0": ..., "t1": ..., "attrs": {...}, "error": ...?}
    {"seq": n, "kind": "event", "name": ..., "span": "<stage>",
     "ts": ..., "attrs": {...}, "timing": {...}?}

``attrs`` hold deterministic *data* (for measured-fidelity runs, real
wall clocks ARE data — they enter the digest like any other result);
``timing`` holds clock-derived bookkeeping that must not (generation
wall seconds, for example) and is stripped with the timestamps.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import time
from typing import Any, Callable, Dict, List, Optional

TRACE_SCHEMA = "repro.offload.trace"
TRACE_VERSION = 1

# keys excluded from the content digest: everything derived from the
# writer's clock. "ts"/"t0"/"t1" are timestamps; "timing" is a sub-dict
# for clock-derived payloads (e.g. a generation's eval wall seconds).
TIMING_KEYS = ("ts", "t0", "t1", "timing")

_KINDS = ("run", "span", "event")

# the share of a search's fresh measurements the budget-attribution
# renderer localizes to a leading generation prefix ("this search spent
# 71% of its measurements in generations 0-3")
_CONCENTRATION = 2.0 / 3.0


class TraceError(ValueError):
    """A trace file failed validation (corrupt line, bad seq, foreign
    schema/version)."""


def default_trace_path(artifact_path: str) -> str:
    """``<artifact minus .json>.trace.jsonl``, next to the artifact."""
    return re.sub(r"\.json$", "", artifact_path) + ".trace.jsonl"


def strip_timing(rec: Dict[str, Any]) -> Dict[str, Any]:
    """The record without its clock-derived keys (what the digest sees)."""
    return {k: v for k, v in rec.items() if k not in TIMING_KEYS}


def _canonical(rec: Dict[str, Any]) -> str:
    return json.dumps(rec, sort_keys=True, separators=(",", ":"))


def trace_digest(records: List[Dict[str, Any]]) -> str:
    """sha256 over the timing-stripped canonical JSON of every record —
    the digest the artifact embeds and the CLI re-checks."""
    h = hashlib.sha256()
    for rec in records:
        h.update((_canonical(strip_timing(rec)) + "\n").encode("utf-8"))
    return h.hexdigest()


class TraceWriter:
    """Append-only JSONL trace writer with an injected clock.

    Construction replays an existing file (a resumed pipeline continues
    the sequence numbers and the incremental digest); the file handle
    opens lazily on the first write and every record is flushed, so a
    killed run leaves at worst one truncated trailing line — which
    :func:`load_trace` rejects loudly rather than skipping.
    """

    def __init__(
        self,
        path: str,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.path = path
        self.clock: Callable[[], float] = clock or time.perf_counter
        self.records = 0
        self._hash = hashlib.sha256()
        self._fh = None
        if os.path.exists(path):
            for rec in _read_records(path):
                self._absorb(rec)

    def _absorb(self, rec: Dict[str, Any]) -> None:
        self._hash.update(
            (_canonical(strip_timing(rec)) + "\n").encode("utf-8")
        )
        self.records += 1

    def write(self, rec: Dict[str, Any]) -> None:
        rec = {"seq": self.records, **rec}
        if self._fh is None:
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")
        self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
        self._fh.flush()
        self._absorb(rec)

    def run_header(
        self,
        *,
        program: str,
        mode: str,
        fidelity: str,
        spec_digest: str,
        resumed: bool,
    ) -> None:
        self.write({
            "kind": "run",
            "schema": TRACE_SCHEMA,
            "v": TRACE_VERSION,
            "ts": self.clock(),
            "program": program,
            "mode": mode,
            "fidelity": fidelity,
            "spec_digest": spec_digest,
            "resumed": bool(resumed),
        })

    def span(
        self,
        name: str,
        t0: float,
        t1: float,
        status: str,
        attrs: Optional[Dict[str, Any]] = None,
        error: Optional[str] = None,
    ) -> None:
        rec: Dict[str, Any] = {
            "kind": "span",
            "name": name,
            "status": status,
            "t0": t0,
            "t1": t1,
            "attrs": attrs or {},
        }
        if error is not None:
            rec["error"] = error
        self.write(rec)

    def event(
        self,
        name: str,
        *,
        span: str,
        attrs: Optional[Dict[str, Any]] = None,
        timing: Optional[Dict[str, float]] = None,
    ) -> None:
        rec: Dict[str, Any] = {
            "kind": "event",
            "name": name,
            "span": span,
            "ts": self.clock(),
            "attrs": attrs or {},
        }
        if timing:
            rec["timing"] = {k: float(v) for k, v in timing.items()}
        self.write(rec)

    def digest(self) -> str:
        return self._hash.hexdigest()

    def summary(self) -> Dict[str, Any]:
        """What the artifact embeds (``OffloadResult.trace``)."""
        return {
            "path": os.path.basename(self.path),
            "digest": self.digest(),
            "records": self.records,
        }

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# loading + validation
# ---------------------------------------------------------------------------


def _read_records(path: str) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = []
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except (json.JSONDecodeError, ValueError) as e:
                raise TraceError(
                    f"{path}:{lineno}: not valid JSON ({e})"
                ) from e
            if not isinstance(rec, dict):
                raise TraceError(f"{path}:{lineno}: record is not an object")
            out.append(rec)
    return out


@dataclasses.dataclass
class Trace:
    """A loaded, validated trace: the records of one artifact's runs."""

    path: str
    records: List[Dict[str, Any]]

    @property
    def digest(self) -> str:
        return trace_digest(self.records)

    def spans(self) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == "span"]

    def events(self, span: Optional[str] = None) -> List[Dict[str, Any]]:
        return [
            r for r in self.records
            if r["kind"] == "event" and (span is None or r["span"] == span)
        ]


def load_trace(path: str) -> Trace:
    """Read + validate a trace file. Raises :class:`TraceError` on any
    malformed line, sequence gap, unknown kind, or a ``run`` header with
    a foreign schema/version — a trace either validates whole or not at
    all (it is evidence, not best-effort telemetry)."""
    records = _read_records(path)
    if not records:
        raise TraceError(f"{path}: empty trace")
    for i, rec in enumerate(records):
        if rec.get("seq") != i:
            raise TraceError(
                f"{path}: record {i} has seq {rec.get('seq')!r} "
                f"(expected {i}; truncated or interleaved writers?)"
            )
        kind = rec.get("kind")
        if kind not in _KINDS:
            raise TraceError(f"{path}: record {i} has unknown kind {kind!r}")
        if kind == "run":
            if rec.get("schema") != TRACE_SCHEMA or \
                    rec.get("v") != TRACE_VERSION:
                raise TraceError(
                    f"{path}: record {i} is not a {TRACE_SCHEMA}/v"
                    f"{TRACE_VERSION} run header (schema="
                    f"{rec.get('schema')!r}, v={rec.get('v')!r})"
                )
        if kind == "span" and not isinstance(rec.get("name"), str):
            raise TraceError(f"{path}: span record {i} has no name")
        if kind == "event" and not isinstance(rec.get("span"), str):
            raise TraceError(f"{path}: event record {i} names no span")
    if records[0].get("kind") != "run":
        raise TraceError(f"{path}: first record must be a run header")
    return Trace(path=path, records=records)


# ---------------------------------------------------------------------------
# rendering: tree + budget attribution
# ---------------------------------------------------------------------------


def _span_measurements(span: Dict[str, Any],
                       events: List[Dict[str, Any]]) -> int:
    """Fresh measurements attributable to one span: the span's own
    ``evaluations`` attr when it carries one (the search span totals its
    generations), else the sum over its events (the report span's
    stability re-searches and rank probes)."""
    n = span.get("attrs", {}).get("evaluations")
    if n is not None:
        return int(n)
    return sum(
        int(e.get("attrs", {}).get(
            "evaluated", e.get("attrs", {}).get("evaluations", 0)
        ))
        for e in events
    )


def _concentration_line(gen_events: List[Dict[str, Any]]) -> Optional[str]:
    """The smallest leading generation prefix holding at least
    :data:`_CONCENTRATION` of the search's fresh measurements."""
    per_gen = [int(e.get("attrs", {}).get("evaluated", 0))
               for e in gen_events]
    total = sum(per_gen)
    if total <= 0:
        return None
    acc = 0
    for g, n in enumerate(per_gen):
        acc += n
        if acc >= _CONCENTRATION * total:
            pct = 100.0 * acc / total
            span_txt = f"generations 0-{g}" if g else "generation 0"
            return (
                f"measurement concentration: this search spent "
                f"{pct:.0f}% of its measurements in {span_txt} "
                f"({acc}/{total})"
            )
    return None


def render_trace(trace: Trace, artifact=None) -> str:
    """Tree view of the trace plus the per-stage budget-attribution
    table. ``artifact`` (an ``OffloadResult``) adds the embedded-digest
    verdict line when it carries one."""
    rows: List[str] = []
    runs = [r for r in trace.records if r["kind"] == "run"]
    head = runs[0]
    rows.append(
        f"== repro.offload trace: {head.get('program')} "
        f"[{head.get('mode')}/{head.get('fidelity')}] — "
        f"{len(trace.records)} records, {len(runs)} run(s), "
        f"digest {trace.digest[:12]} =="
    )

    spans = trace.spans()
    # nest events under the LAST span of their stage only — a resumed
    # pipeline may record a failed span and a later done one, but the
    # events belong to the trace, not to each span line
    last_span_idx: Dict[str, int] = {}
    for i, rec in enumerate(trace.records):
        if rec["kind"] == "span":
            last_span_idx[rec["name"]] = i
    run_no = 0
    for i, rec in enumerate(trace.records):
        if rec["kind"] == "event" and rec.get("span") == "service":
            # serving-layer job events (docs/serving.md) have no parent
            # span record; render them inline where they occurred.
            # Additive: non-service traces never carry these.
            a = rec.get("attrs", {})
            detail = ", ".join(f"{k}={_short(v)}"
                               for k, v in sorted(a.items()))
            rows.append(f"├─ service::{rec.get('name')}  {detail}")
            continue
        if rec["kind"] == "run":
            run_no += 1
            rows.append(
                f"run {run_no} ({'resumed' if rec.get('resumed') else 'fresh'}"
                f", spec {str(rec.get('spec_digest'))[:12]})"
            )
        elif rec["kind"] == "span":
            dur = float(rec["t1"]) - float(rec["t0"])
            attrs = rec.get("attrs", {})
            extra = ", ".join(
                f"{k}={_short(v)}" for k, v in sorted(attrs.items())
            )
            line = (f"├─ {rec['name']:9s} {rec['status']:6s} "
                    f"{dur:8.3f}s")
            if extra:
                line += f"  {extra}"
            if rec.get("error"):
                line += f"  !! {rec['error']}"
            rows.append(line)
            if rec["name"] == "analyze" and last_span_idx["analyze"] == i:
                for e in trace.events("analyze"):
                    a = e.get("attrs", {})
                    if e.get("name") != "block_match":
                        continue
                    rows.append(
                        f"│    block [{a.get('entry')}] "
                        f"{a.get('loops', '?')} "
                        f"({a.get('n_loops', '?')} loops)"
                    )
            if rec["name"] == "search" and last_span_idx["search"] == i:
                for e in trace.events("search"):
                    a = e.get("attrs", {})
                    if e.get("name") != "generation":
                        continue
                    rows.append(
                        f"│    gen {a.get('generation', '?'):>3}: "
                        f"best {a.get('best_time_s', float('nan')):.4g}s  "
                        f"evaluated {a.get('evaluated', 0):>3}  "
                        f"hits {a.get('cache_hits', 0):>3}  "
                        f"entropy {a.get('allele_entropy', 0.0):.3f}"
                    )
            if rec["name"] == "report" and last_span_idx["report"] == i:
                for e in trace.events("report"):
                    a = e.get("attrs", {})
                    if e.get("name") == "stability_search":
                        rows.append(
                            f"│    stability seed {a.get('seed')}: best "
                            f"{a.get('best_time_s', float('nan')):.4g}s "
                            f"({a.get('evaluations', 0)} measurements, "
                            f"{a.get('cache_hits', 0)} cache hits)"
                        )
                    elif e.get("name") == "rank_probe":
                        rows.append(
                            f"│    rank probe {a.get('projection')}: "
                            f"measured "
                            f"{a.get('measured_s', float('nan')):.4g}s"
                        )
            if rec["name"] == "verify" and last_span_idx["verify"] == i:
                for e in trace.events("verify"):
                    a = e.get("attrs", {})
                    if e.get("name") != "block_substitution":
                        continue
                    ok = "PASS" if a.get("oracle_ok") else "FAIL"
                    rows.append(
                        f"│    block [{a.get('entry')}]"
                        f"@{a.get('destination')} oracle {ok} "
                        f"(max_abs {a.get('max_abs_err', float('nan')):.2e})"
                    )

    # budget attribution: wall + fresh measurements per stage (summed
    # over runs — a resumed pipeline's stages add up)
    by_stage: Dict[str, Dict[str, float]] = {}
    order: List[str] = []
    for s in spans:
        st = by_stage.setdefault(s["name"], {"wall_s": 0.0, "meas": 0,
                                             "eval_s": 0.0, "idle_s": 0.0,
                                             "counted": False})
        if s["name"] not in order:
            order.append(s["name"])
        st["wall_s"] += float(s["t1"]) - float(s["t0"])
        n = s.get("attrs", {}).get("evaluations")
        if n is not None:
            st["meas"] += int(n)
            st["counted"] = True
    for name in order:
        st = by_stage[name]
        if not st["counted"]:
            # no span-level total: attribute the stage's events (the
            # report span's stability re-searches and rank probes),
            # counted once per stage however many spans recorded
            st["meas"] += _span_measurements({}, trace.events(name))
        # the evalpool's per-generation clocks: evaluation wall and
        # worker-lane idle (barrier stall / steady-state starvation) —
        # recorded under the digest-exempt event "timing" sub-dict
        for e in trace.events(name):
            tm = e.get("timing") or {}
            st["eval_s"] += float(tm.get("wall_s", 0.0))
            st["idle_s"] += float(tm.get("idle_s", 0.0))
    total_wall = sum(st["wall_s"] for st in by_stage.values())
    total_meas = sum(st["meas"] for st in by_stage.values())
    rows.append("budget attribution:")
    rows.append(f"  {'stage':9s} {'wall_s':>9s} {'share':>6s} "
                f"{'measurements':>13s} {'share':>6s} "
                f"{'eval_s':>8s} {'idle_s':>8s}")
    for name in order:
        st = by_stage[name]
        w_share = st["wall_s"] / total_wall if total_wall > 0 else 0.0
        m_share = st["meas"] / total_meas if total_meas > 0 else 0.0
        rows.append(
            f"  {name:9s} {st['wall_s']:9.3f} {w_share:6.0%} "
            f"{int(st['meas']):13d} {m_share:6.0%} "
            f"{st['eval_s']:8.3f} {st['idle_s']:8.3f}"
        )
    conc = _concentration_line(
        [e for e in trace.events("search") if e.get("name") == "generation"]
    )
    if conc:
        rows.append(conc)

    if artifact is not None and getattr(artifact, "trace", None):
        embedded = artifact.trace.get("digest")
        verdict = "matches" if embedded == trace.digest else "MISMATCH"
        rows.append(
            f"artifact digest: {str(embedded)[:12]} — {verdict}"
        )
    return "\n".join(rows)


def _short(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    s = str(v)
    return s if len(s) <= 24 else s[:21] + "..."
