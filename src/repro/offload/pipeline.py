"""The Offloader facade: the paper's whole flow as one staged pipeline.

Stages (in order, each recorded into the :class:`OffloadResult` artifact):

- **calibrate** — fidelity="calibrated" only: measure the designed probe
  set on this machine, fit per-destination constants by least squares
  (:mod:`repro.offload.calibrate`), install the resulting named machine
  entry, and record the fit residuals. Every other fidelity records the
  stage as not applicable.
- **analyze** — load the program, assign directives per loop/unit (the
  paper's Clang-parse + pgcc-classification step), price the all-host
  baseline (a REAL wall-clocked run under fidelity="measured").
- **seed** — build the initial-population seeds. With
  ``spec.warm_start`` (mixed mode), runs one quick binary GA per
  non-host destination and re-expresses each single-destination best in
  the full k-ary alphabet (genome-aware seeding); the pre-searches share
  the spec's fitness cache with the main search (the mixed fingerprint
  is subset-independent).
- **search** — the GA over an :class:`EvalPool` with the persistent
  JSONL fitness cache; a killed search re-run resumes warm from the
  cache without re-measuring anything already paid for.
- **verify** — re-measure the winner against the recorded best (exact
  for the analytic evaluators) and run the PCAST result-difference check
  of the offloaded implementation vs the CPU reference, where the
  program has a runnable implementation.
- **report** — render the human-readable summary into the artifact.

Completed stages are skipped when re-running from a loaded artifact, so
``Offloader.resume(path).run()`` continues a killed pipeline exactly
where it stopped. A stage failure is recorded (status ``failed``) and
saved *before* :class:`StageFailure` propagates, so the artifact always
reflects what actually happened.

With ``spec`` defaults, the facade's searches are byte-identical to the
pre-redesign hand-wired paths (parity-tested in
tests/test_offload_pipeline.py): same GAParams, same pool construction,
same RNG stream.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Sequence

from repro.core import ga
from repro.core.evalpool import (
    EvalPool,
    FitnessCache,
    evaluator_fingerprint,
)
from repro.core.evaluator import HardwareModel
from repro.offload import programs
from repro.offload import quality as qual
from repro.offload import trace as trace_mod
from repro.offload.result import (
    STAGES,
    OffloadResult,
    StageFailure,
    timed,
)
from repro.offload.spec import OffloadSpec

# relative mismatch tolerated when re-measuring the winner with a
# deterministic (analytic) evaluator
_REMEASURE_RTOL = 1e-9


def _spec_digest(spec: OffloadSpec) -> str:
    """Short content digest of the spec (trace run headers)."""
    return hashlib.sha256(spec.to_json().encode("utf-8")).hexdigest()[:16]


def _evaluator_label(evaluator) -> str:
    """The evaluator's fingerprint, or an explicit ``injected:`` marker
    for fingerprint-less injected callables. This labels stage payloads
    for the resume drift guard only — persistent fitness-cache keying
    always goes through ``evaluator_fingerprint``, which refuses
    fingerprint-less evaluators outright."""
    if callable(getattr(evaluator, "fingerprint", None)):
        return evaluator_fingerprint(evaluator)
    mod = getattr(evaluator, "__module__", type(evaluator).__module__)
    name = getattr(evaluator, "__qualname__", type(evaluator).__qualname__)
    return f"injected:{mod}.{name}"


def _span_attrs(name: str, payload: Dict[str, Any]) -> Dict[str, Any]:
    """Deterministic data attrs for a stage span, derived from the stage
    payload alone (wall clocks stay out — they belong to span timing,
    which the trace digest ignores)."""
    a: Dict[str, Any] = {}
    if name == "calibrate":
        a["applicable"] = bool(payload.get("applicable"))
        if payload.get("entry"):
            a["entry"] = payload["entry"]
    elif name == "analyze":
        if "gene_length" in payload:
            a["gene_length"] = int(payload["gene_length"])
        if "baseline_s" in payload:
            a["baseline_s"] = float(payload["baseline_s"])
        if "blocks" in payload:  # key only present on block-enabled runs
            a["block_matches"] = len(payload["blocks"].get("matches", []))
    elif name == "seed":
        a["seeds"] = len(payload.get("seeds", []))
    elif name == "search":
        a["evaluations"] = int(payload.get("evaluations", 0))
        a["cache_hits"] = int(payload.get("cache_hits", 0))
        a["timeouts"] = int(payload.get("timeouts", 0))
        a["generations"] = len(payload.get("history", []))
        if payload.get("best_time_s") is not None:
            a["best_time_s"] = float(payload["best_time_s"])
        if "substitutions" in payload:  # block-enabled runs only
            a["substitutions"] = sum(
                1 for s in payload["substitutions"] if s.get("active")
            )
    elif name == "verify":
        pc = payload.get("pcast") or {}
        a["pcast"] = "skipped" if "skipped" in pc else (
            "ok" if pc.get("ok") else "fail") if pc else "none"
        a["consistent"] = bool(payload.get("consistent", False))
        if "block_oracles" in payload:  # block-enabled runs only
            a["block_oracles"] = "ok" if all(
                r.get("ok") for r in payload["block_oracles"]
            ) else "fail"
    elif name == "report":
        # NOTE: no "evaluations" attr here — the report span's
        # stability_search / rank_probe EVENTS carry the measurement
        # counts, and the budget table counts events only when the
        # span has no count of its own (else it would double-count)
        q = payload.get("quality") or {}
        st = q.get("stability") or {}
        if "pass_at_k" in st:
            a["pass_at_k"] = st["pass_at_k"]
            a["stability_k"] = st["k"]
        rk = q.get("rank") or {}
        if rk.get("spearman") is not None:
            a["spearman"] = round(float(rk["spearman"]), 4)
    return a


class Offloader:
    """Facade running the staged pipeline for one :class:`OffloadSpec`.

    Parameters
    ----------
    spec:
        The declarative pipeline input.
    artifact:
        An existing :class:`OffloadResult` to continue (its completed
        stages are skipped). Defaults to a fresh artifact for ``spec``.
    artifact_path:
        Where to save the artifact after every stage (None = in-memory).
    evaluator:
        Injected evaluator for the search/verify stages, overriding the
        adapter's (e.g. a ``CompiledEvaluator``, or a calibration
        candidate). Injection is process-local: resuming such an
        artifact in a new process needs the same injection again.
    hw:
        Injected :class:`HardwareModel` overriding the ``spec.hw``
        registry lookup (calibration sweeps score unregistered
        candidate models).
    calibration:
        A pre-built ``CalibrationResult`` for fidelity="calibrated"
        specs: the calibrate stage records and installs it instead of
        re-measuring the probe set (calibrate once, search many apps).
        Its ``base`` must match ``spec.hw``.
    on_generation:
        Optional per-generation callback forwarded to ``run_ga``.
    trace:
        Write a structured JSONL trace next to the artifact
        (:mod:`repro.offload.trace`). On by default; a no-op for
        in-memory artifacts unless ``trace_path`` names a file. The
        trace never feeds back into any stage, so search results and
        cache fingerprints are byte-identical with tracing on or off.
    trace_path:
        Explicit trace file path (default: artifact path with
        ``.json`` swapped for ``.trace.jsonl``).
    trace_clock:
        Injected monotonic clock for the trace spans (tests pin it to
        make whole trace files deterministic; timing never enters the
        trace digest either way).
    cache_factory:
        Injected ``evaluator -> FitnessCache`` opener overriding the
        default per-stage ``FitnessCache(spec.cache, fingerprint)``
        construction. The serving layer (repro.serve) passes an
        :class:`~repro.core.evalpool.EvalBroker` view opener here so
        concurrent jobs share one in-memory store; the stage still calls
        ``close()`` on what it gets back, so factories must hand out
        refcounted views. ``None`` (the default) keeps single-run
        behavior byte-identical to the pre-serving pipeline.
    """

    def __init__(
        self,
        spec: OffloadSpec,
        artifact: Optional[OffloadResult] = None,
        artifact_path: Optional[str] = None,
        evaluator: Optional[Callable[[Sequence[int]], float]] = None,
        hw: Optional[HardwareModel] = None,
        calibration=None,
        on_generation: Optional[Callable[[ga.GenerationStats], None]] = None,
        trace: bool = True,
        trace_path: Optional[str] = None,
        trace_clock: Optional[Callable[[], float]] = None,
        cache_factory: Optional[
            Callable[[Callable], Optional[FitnessCache]]
        ] = None,
    ):
        if artifact is not None and artifact.spec != spec:
            raise ValueError("artifact was produced by a different spec; "
                             "use Offloader.resume to continue it")
        self.spec = spec
        self.result = artifact or OffloadResult(spec=spec)
        if artifact_path is not None:
            self.result.path = artifact_path
        self._evaluator = evaluator
        self._hw = hw
        self._on_generation = on_generation
        self._trace_enabled = trace
        self._trace_path = trace_path
        self._trace_clock = trace_clock
        self._cache_factory = cache_factory
        self._tracer: Optional[trace_mod.TraceWriter] = None
        self._trace_header_written = False
        self._adapter = None  # built lazily (adapters may import jax-side)
        # CalibrationResult (fidelity="calibrated" only); an injected one
        # is recorded by the calibrate stage in place of a fresh sweep
        if calibration is not None and calibration.base != spec.hw:
            raise ValueError(
                f"injected calibration was fitted for base "
                f"{calibration.base!r}, spec.hw is {spec.hw!r}"
            )
        self._injected_cal = calibration
        self._cal = None

    @classmethod
    def resume(
        cls,
        artifact_path: str,
        evaluator: Optional[Callable[[Sequence[int]], float]] = None,
        hw: Optional[HardwareModel] = None,
        on_generation: Optional[Callable[[ga.GenerationStats], None]] = None,
        trace: bool = True,
        trace_path: Optional[str] = None,
        trace_clock: Optional[Callable[[], float]] = None,
        cache_factory: Optional[
            Callable[[Callable], Optional[FitnessCache]]
        ] = None,
    ) -> "Offloader":
        """Continue a saved artifact: its spec is authoritative and its
        completed stages are skipped on the next :meth:`run`. An
        existing trace file is continued, not truncated (the resumed
        process appends a second run header)."""
        art = OffloadResult.load(artifact_path)
        return cls(art.spec, artifact=art, artifact_path=artifact_path,
                   evaluator=evaluator, hw=hw, on_generation=on_generation,
                   trace=trace, trace_path=trace_path,
                   trace_clock=trace_clock, cache_factory=cache_factory)

    # -- plumbing ----------------------------------------------------------

    @property
    def adapter(self):
        if self._adapter is None:
            self._adapter = programs.resolve_adapter(
                self._effective_spec(), self._hw
            )
        return self._adapter

    def _effective_spec(self) -> OffloadSpec:
        """The spec the adapters see. fidelity="calibrated" resolves to a
        MODELED spec pointing at the installed calibrated machine entry —
        downstream stages price candidates exactly like any other modeled
        search, just under the fitted constants (whose fingerprints carry
        the calibration digest). The artifact keeps the original spec."""
        if self.spec.fidelity != "calibrated":
            return self.spec
        cal = self._ensure_calibration()
        return dataclasses.replace(
            self.spec, fidelity="modeled", hw=cal.name
        )

    def _ensure_calibration(self):
        """The CalibrationResult for this run, installed in-process.
        After the calibrate stage it is cached; on resume it is rebuilt
        from the stage payload (same constants -> same digest -> same
        fingerprints, so resumed searches keep their cache hits) without
        re-measuring anything."""
        if self._cal is not None:
            return self._cal
        from repro.offload import calibrate

        if not self.result.completed("calibrate"):
            raise StageFailure(
                "calibrate",
                "fidelity='calibrated' needs the calibrate stage to run "
                "before any adapter-facing stage (run() orders this)",
            )
        payload = self.result.stage("calibrate").payload
        cal = calibrate.CalibrationResult.from_dict(payload["calibration"])
        calibrate.install(cal, replace=True)
        self._cal = cal
        return cal

    def _search_evaluator(self):
        return self._evaluator if self._evaluator is not None \
            else self.adapter.build_evaluator()

    def _open_cache(self, evaluator) -> Optional[FitnessCache]:
        if self._cache_factory is not None:
            # serving-side injection: a refcounted shared-store view
            # (the stage's close() releases its reference only)
            return self._cache_factory(evaluator)
        if not self.spec.cache:
            return None
        return FitnessCache(self.spec.cache,
                            fingerprint=evaluator_fingerprint(evaluator))

    def _trace(self) -> Optional[trace_mod.TraceWriter]:
        """The lazily-built TraceWriter, or None when tracing is off (or
        there is nowhere to write: in-memory artifact, no trace_path).
        Emits exactly one run header per process, flagged ``resumed``
        when any stage was already complete at construction."""
        if not self._trace_enabled:
            return None
        if self._tracer is None:
            path = self._trace_path
            if path is None:
                if self.result.path is None:
                    return None
                path = trace_mod.default_trace_path(self.result.path)
            self._tracer = trace_mod.TraceWriter(
                path, clock=self._trace_clock
            )
        if not self._trace_header_written:
            self._tracer.run_header(
                program=self.spec.program,
                mode=self.spec.mode,
                fidelity=self.spec.fidelity,
                spec_digest=_spec_digest(self.spec),
                resumed=any(self.result.completed(s) for s in STAGES),
            )
            self._trace_header_written = True
        return self._tracer

    # -- driver ------------------------------------------------------------

    def run(self, until: str = "report") -> OffloadResult:
        """Run every not-yet-completed stage up to and including
        ``until``, saving the artifact after each one."""
        if until not in STAGES:
            raise ValueError(f"unknown stage {until!r}; have {STAGES}")
        for name in STAGES[: STAGES.index(until) + 1]:
            if self.result.completed(name):
                continue
            self.run_stage(name)
        return self.result

    def run_stage(self, name: str) -> None:
        tr = self._trace()
        t0 = tr.clock() if tr is not None else 0.0
        fn = getattr(self, f"_stage_{name}")
        try:
            payload, wall = timed(fn)
        except StageFailure as e:
            if tr is not None:
                tr.span(name, t0, tr.clock(), "failed", error=str(e))
                self.result.trace = tr.summary()
            raise
        except Exception as e:  # noqa: BLE001 — record, then propagate
            if tr is not None:
                tr.span(name, t0, tr.clock(), "failed", error=repr(e))
                self.result.trace = tr.summary()
            self.result.record(name, {}, 0.0, status="failed",
                               error=repr(e))
            self.result.save()
            raise
        status = "done"
        error = payload.pop("_error", None)
        if error is not None:
            status = "failed"
        if tr is not None:
            tr.span(name, t0, tr.clock(), status,
                    attrs=_span_attrs(name, payload), error=error)
            self.result.trace = tr.summary()
        self.result.record(name, payload, wall, status=status, error=error)
        self.result.save()
        if error is not None:
            raise StageFailure(name, error)

    # -- stages ------------------------------------------------------------

    def _stage_calibrate(self) -> Dict[str, Any]:
        if self.spec.fidelity != "calibrated":
            return {"fidelity": self.spec.fidelity, "applicable": False}
        from repro.offload import calibrate

        cal = self._injected_cal
        if cal is None:
            cal = calibrate.run_calibration(
                base=self.spec.hw, repeats=self.spec.repeats,
                kernels=self.spec.blocks,
            )
        calibrate.install(cal, replace=True)
        self._cal = cal
        return {
            "fidelity": "calibrated",
            "applicable": True,
            "provided": self._injected_cal is not None,
            "base": cal.base,
            "entry": cal.name,
            "hw_name": cal.hw_name,
            "host": cal.host,
            "pinned": list(cal.pinned),
            "residuals": cal.residuals(),
            "calibration": cal.to_dict(),
        }

    def _stage_analyze(self) -> Dict[str, Any]:
        payload = self.adapter.analyze_payload()
        payload["baseline_s"] = float(self.adapter.baseline_time())
        blocks = payload.get("blocks")
        if blocks and blocks.get("matches"):
            tracer = self._trace()
            if tracer is not None:
                for m in blocks["matches"]:
                    tracer.event("block_match", span="analyze", attrs={
                        "entry": m["entry"],
                        "loops": "+".join(m["loops"]),
                        "n_loops": len(m["loops"]),
                    })
        return payload

    def _stage_seed(self) -> Dict[str, Any]:
        payload: Dict[str, Any] = {
            "warm_start": bool(self.spec.warm_start),
            "seeds": [],
            "seed_info": [],
        }
        if not self.spec.warm_start:
            return payload
        # mixed-mode genome-aware seeding: one quick binary GA per
        # non-host destination, bests re-expressed in the k-ary alphabet
        adapter = self.adapter
        host = self.spec.destinations[0]
        n = adapter.gene_length
        for device in self.spec.destinations[1:]:
            sub = adapter.sub_evaluator((host, device))
            params = ga.GAParams.for_gene_length(
                n,
                seed=self.spec.seed,
                timeout_s=self.spec.timeout_s
                if self.spec.timeout_s is not None else 1e6,
                penalty_time_s=self.spec.penalty_time_s,
                alleles=sub.k,
            )
            cache = self._open_cache(sub)
            try:
                with EvalPool(sub, workers=self.spec.workers,
                              executor=self.spec.executor,
                              cache=cache) as pool:
                    res = ga.run_ga(None, n, params, pool=pool)
                    tot = pool.totals()
            finally:
                if cache is not None:
                    cache.close()
            seed_genes = adapter.reexpress(res.best_genes, device)
            payload["seeds"].append([int(g) for g in seed_genes])
            payload["seed_info"].append({
                "device": device,
                "best_time_s": float(res.best_time_s),
                "evaluations": int(tot.evaluated),
                "cache_hits": int(tot.cache_hits),
            })
        return payload

    def _stage_search(self) -> Dict[str, Any]:
        adapter = self.adapter
        evaluator = self._search_evaluator()
        n = adapter.gene_length
        params = self.spec.ga_params(n, adapter.alleles)
        seeds = [
            tuple(int(g) for g in s)
            for s in self.result.stage("seed").payload.get("seeds", [])
        ]
        cache = self._open_cache(evaluator)
        resumed = len(cache) if cache is not None else 0
        tracer = self._trace()
        pool: Optional[EvalPool] = None

        def on_generation(gs: ga.GenerationStats) -> None:
            # per-generation trace event: population shape + the pool's
            # GenerationTelemetry for this generation. The pool's wall
            # clock is real time -> "timing" (digest-exempt); everything
            # else is deterministic data -> "attrs".
            if tracer is not None:
                attrs: Dict[str, Any] = {
                    "generation": int(gs.generation),
                    "best_time_s": float(gs.best_time_s),
                    "mean_time_s": float(gs.mean_time_s),
                    "best_fitness": ga.fitness_of_time(gs.best_time_s),
                }
                if gs.times:
                    med = qual.median(gs.times)
                    attrs["median_time_s"] = med
                    attrs["median_fitness"] = ga.fitness_of_time(med)
                if gs.population is not None:
                    attrs["allele_entropy"] = round(qual.allele_entropy(
                        gs.population, params.alleles), 6)
                timing = None
                if pool is not None and pool.history:
                    tel = pool.history[-1]
                    attrs.update(
                        submitted=int(tel.submitted),
                        unique=int(tel.unique),
                        cache_hits=int(tel.cache_hits),
                        evaluated=int(tel.evaluated),
                        timeouts=int(tel.timeouts),
                        dedup_ratio=round(tel.dedup_ratio, 4),
                        hit_rate=round(tel.hit_rate, 4),
                    )
                    # timing keys are digest-exempt on the trace side;
                    # idle_s is the barrier-stall / lane-starvation
                    # attribution the trace CLI's budget table renders
                    timing = {"wall_s": tel.wall_s, "idle_s": tel.idle_s}
                tracer.event("generation", span="search", attrs=attrs,
                             timing=timing)
            if self._on_generation is not None:
                self._on_generation(gs)

        try:
            with EvalPool(evaluator, workers=self.spec.workers,
                          executor=self.spec.executor, cache=cache) as pool:
                res = ga.run_ga(
                    None, n, params, pool=pool,
                    on_generation=on_generation,
                    seeds=seeds or None,
                )
                tot = pool.totals()
                telemetry = [t.row() for t in pool.history]
        finally:
            if cache is not None:
                cache.close()
        if res.history:
            best_genes = [int(g) for g in res.best_genes]
            best_t: Optional[float] = float(res.best_time_s)
            placement = adapter.placement(res.best_genes)
            stats_fn = getattr(adapter, "schedule_stats", None)
            residency = stats_fn(res.best_genes) if stats_fn is not None \
                else None
            subs_fn = getattr(adapter, "substitutions", None)
            substitutions = subs_fn(res.best_genes) \
                if subs_fn is not None else None
            last = res.history[-1]
            final_population = [[int(g) for g in ind]
                                for ind in (last.population or [])]
            final_times = [float(t) for t in (last.times or [])]
        else:
            # a zero-generation budget evaluates nothing: record an
            # explicit no-winner search instead of a fake one
            best_genes, best_t, placement, residency = [], None, {}, None
            final_population, final_times = [], []
            substitutions = None
        return {
            "best_genes": best_genes,
            "best_time_s": best_t,
            **({"residency": residency} if residency is not None else {}),
            **({"substitutions": substitutions}
               if substitutions is not None else {}),
            "wall_s": float(res.wall_s),
            "evaluations": int(tot.evaluated),
            "cache_hits": int(tot.cache_hits),
            "timeouts": int(tot.timeouts),
            "cache_resumed": int(resumed),
            "evaluator": _evaluator_label(evaluator),
            "telemetry": telemetry,
            "final_population": final_population,
            "final_times_s": final_times,
            "ga": {
                "population": params.population,
                "generations": params.generations,
                "alleles": params.alleles,
                "allele_names": list(getattr(adapter, "allele_names",
                                             ()) or ()),
                "seed": params.seed,
                "seeded": len(seeds),
                "diversity": float(params.diversity),
                # recorded only when on: knobs-off payloads stay
                # byte-identical to pre-fast-search artifacts
                **({"steady_state": True} if params.steady_state else {}),
                **({"batch": True} if self.spec.ga.batch else {}),
            },
            "placement": placement,
            "history": [
                {
                    "generation": h.generation,
                    "best_time_s": float(h.best_time_s),
                    "mean_time_s": float(h.mean_time_s),
                    "gen_wall_s": float(h.gen_wall_s),
                    "dedup_ratio": float(h.dedup_ratio),
                    "hit_rate": float(h.hit_rate),
                }
                for h in res.history
            ],
        }

    def _stage_verify(self) -> Dict[str, Any]:
        adapter = self.adapter
        search = self.result.stage("search").payload
        if search.get("best_time_s") is None:
            # zero-generation search: nothing was evaluated, no winner
            return {
                "re_measured_s": None,
                "search_best_s": None,
                "consistent": True,
                "note": "search recorded zero generations; "
                        "no winner to verify",
                "pcast": {"skipped": "no winner to check"},
            }
        best = tuple(int(g) for g in search["best_genes"])
        best_t = float(search["best_time_s"])

        evaluator = self._search_evaluator()
        # guard against evaluator drift across resume: the search stage
        # recorded its evaluator's fingerprint, and re-measuring the
        # winner with a DIFFERENT one (e.g. a compiled-evaluator
        # artifact resumed without re-injecting it) would either fail
        # spuriously or silently bless an unverified number
        searched_fp = search.get("evaluator")
        verify_fp = _evaluator_label(evaluator)
        if searched_fp is not None and searched_fp != verify_fp:
            return {
                "re_measured_s": None,
                "search_best_s": best_t,
                "pcast": {"skipped": "evaluator mismatch"},
                "_error": (
                    f"verify evaluator {verify_fp!r} differs from the one "
                    f"the search used ({searched_fp!r}); resume with the "
                    "same evaluator injection (Offloader.resume(path, "
                    "evaluator=...))"
                ),
            }
        if self._evaluator is not None:
            # injected evaluators (compiled / measured): a re-measurement
            # would redo the expensive per-individual work (an AOT
            # compile, a wall-clocked run) outside the pool/cache for a
            # number that could not be held to exactness anyway — skip it
            payload: Dict[str, Any] = {
                "re_measured_s": None,
                "search_best_s": best_t,
                "consistent": True,
                "note": "injected evaluator: re-measurement skipped",
            }
            consistent = True
        else:
            re_t = float(evaluator(best))
            exact = adapter.deterministic
            mismatch = abs(re_t - best_t) / max(best_t, 1e-300)
            consistent = (not exact) or mismatch <= _REMEASURE_RTOL
            payload = {
                "re_measured_s": re_t,
                "search_best_s": best_t,
                "mismatch_rel": mismatch,
                "consistent": bool(consistent),
            }
        report = adapter.pcast_check(best)
        if report is None:
            payload["pcast"] = {
                "skipped": "no runnable reference implementation",
            }
        else:
            payload["pcast"] = {
                "ok": bool(report.ok),
                "max_rel": float(report.max_rel),
                "n_leaves": len(report.leaves),
                "detail": report.describe(),
            }
        fid = self._fidelity_section(best, best_t)
        if fid is not None:
            payload["fidelity"] = fid
        oracles = self._block_oracles(adapter, best)
        if oracles is not None:
            payload["block_oracles"] = oracles
        if not consistent:
            payload["_error"] = (
                f"winner re-measurement drifted: "
                f"{payload['re_measured_s']:.6g}s vs recorded "
                f"{best_t:.6g}s (rel {payload['mismatch_rel']:.3g})"
            )
        elif report is not None and not report.ok:
            payload["_error"] = (
                f"PCAST result-difference check FAILED "
                f"(max_rel {report.max_rel:.3e})"
            )
        elif oracles is not None and not all(r["ok"] for r in oracles):
            bad = [r for r in oracles if not r["ok"]]
            payload["_error"] = (
                "block substitution oracle check FAILED: "
                + "; ".join(
                    f"{r['kernel']} vs {r['oracle']} "
                    f"(max_abs {r['max_abs_err']:.3e} > tol {r['tol']:.3e})"
                    for r in bad
                )
            )
        return payload

    def _block_oracles(self, adapter, best) -> Optional[list]:
        """Kernel-oracle checks for every substitution the winner
        activates: the substituted implementation (the real kernel body,
        interpret mode) vs its ``kernels/ref.py`` oracle on a tiny
        seeded input — the block analogue of the PCAST placement check.
        None when the run has no block genome (blocks-off byte parity)."""
        subs_fn = getattr(adapter, "substitutions", None)
        if subs_fn is None:
            return None
        subs = subs_fn(best)
        if subs is None:
            return None
        from repro import blocks as blocks_mod

        tracer = self._trace()
        rows = []
        for s in subs:
            if not s.get("active"):
                continue
            entry = adapter.library.get(s["entry"])
            row = blocks_mod.oracle_check(entry, seed=self.spec.seed)
            row["destination"] = s["destination"]
            row["loops"] = list(s["loops"])
            rows.append(row)
            if tracer is not None:
                tracer.event("block_substitution", span="verify", attrs={
                    "entry": s["entry"],
                    "destination": s["destination"],
                    "loops": "+".join(s["loops"]),
                    "oracle_ok": bool(row["ok"]),
                    "max_abs_err": float(row["max_abs_err"]),
                })
        return rows

    def _scale_model(self) -> Callable[[Sequence[int]], float]:
        """The analytic model of the effective spec's machine AT THE
        MEASURED SCALE — what fidelity/rank sections compare real wall
        clocks against (a paper-scale prediction would be off by the
        problem-size ratio, not by model error)."""
        from repro.core import evaluator as ev
        from repro.core import transfer as tr

        spec = self.spec
        if spec.fidelity == "measured":
            return self.adapter.model_evaluator()
        eff = self._effective_spec()
        scale_prog = programs.measured_scale_program(spec.program)
        if spec.mode == "mixed":
            from repro.destinations import MixedEvaluator, get_registry

            reg = get_registry(eff.hw)
            if getattr(self.adapter, "matches", ()):
                # block-enabled genomes carry block genes; price them
                # with a block evaluator over the scale program (same
                # loop structure -> same matches)
                from repro.blocks import BlockMixedEvaluator

                return BlockMixedEvaluator(
                    scale_prog, eff.destinations, registry=reg,
                    library=self.adapter.library,
                )
            return MixedEvaluator(scale_prog, eff.destinations,
                                  registry=reg)
        method = programs.METHODS[eff.method]
        return ev.MiniappEvaluator(
            scale_prog,
            tr.TransferMode(method["transfer"]),
            staged=method["staged"],
            hw=programs.resolve_hw(eff),
            kernels_only=method["kernels_only"],
        )

    def _fidelity_section(self, best, best_t: float) -> Optional[Dict]:
        """Predicted-vs-measured honesty check of the winner (and the
        all-host baseline), one row per destination involved. Modeled
        runs skip it (nothing was measured, and the pipeline must stay
        byte-identical to the pre-fidelity artifacts); programs without
        a runnable implementation record why.

        - fidelity="measured": predicted comes from the analytic model
          of the spec's machine AT THE MEASURED SCALE; measured numbers
          are the search's own wall clocks (no extra runs).
        - fidelity="calibrated": predicted comes from the calibrated
          model at the measured scale; the winner and baseline are
          freshly wall-clocked in-process.
        """
        from repro.core import evaluator as ev
        from repro.offload.spec import MEASURED_PROGRAMS

        spec = self.spec
        if spec.fidelity == "modeled":
            return None
        if spec.program not in MEASURED_PROGRAMS:
            return {
                "level": spec.fidelity,
                "skipped": "no runnable implementation to measure "
                           "(calibration residuals still recorded in the "
                           "calibrate stage)",
            }
        adapter = self.adapter
        n = adapter.gene_length
        zeros = (0,) * n
        run_fn = programs.MEASURED_RUN_FNS[spec.program]()
        model = self._scale_model()

        if spec.fidelity == "measured":
            reference = f"model:{adapter.hw.name}"
            meas_host = float(
                self.result.stage("analyze").payload["baseline_s"]
            )
            meas_win = float(best_t)
        else:  # calibrated
            reference = f"calibrated:{self._ensure_calibration().hw_name}"
            m = ev.MeasuredEvaluator(run_fn, repeats=spec.repeats,
                                     tag=run_fn.tag)
            meas_host = float(m(zeros))
            meas_win = float(m(best))

        # the runnable implementations realize exactly ONE placement
        # switch (the hot loop on the generic jit/accelerator path), so
        # the winner row compares the model and the clock on the
        # REALIZABLE projection of the winner — anything else would
        # price loops (or backends, for k-ary genomes: the run_fn jits
        # for ANY nonzero allele) the measurement cannot move
        hot = programs.hot_gene_index(spec.program)
        hot_name = programs.RUNNABLE[spec.program][0]
        host = "cpu"
        hot_offloaded = adapter.placement(best).get(hot_name, host) != host
        if spec.mode == "mixed":
            dests = adapter.build_evaluator().dests
            accel = next((i for i, d in enumerate(dests)
                          if d.kind in ("gpu", "tpu")), None)
        else:
            dests, accel = None, 1

        def row(dest: str, label: str, pred: float, meas: float) -> Dict:
            return {
                "destination": dest,
                "placement": label,
                "predicted_s": float(pred),
                "measured_s": float(meas),
                "ratio": float(pred / meas) if meas > 0 else float("inf"),
            }

        rows = [row(host, "all-host", model(zeros), meas_host)]
        if hot_offloaded and accel is None:
            # e.g. a cpu+fpga subset: the jit path the clock runs has no
            # counterpart destination in the model — say so, don't fake it
            rows.append({
                "destination": "?",
                "placement": "winner:hot-loop",
                "skipped": "searched subset has no gpu/tpu-kind "
                           "destination matching the jit measurement",
            })
        else:
            allele = accel if hot_offloaded else 0
            realized = tuple(
                allele if i == hot else 0 for i in range(n)
            )
            win_dest = dests[allele].name if dests is not None \
                else ("gpu" if allele else host)
            rows.append(row(win_dest, "winner:hot-loop",
                            model(realized), meas_win))
        return {
            "level": spec.fidelity,
            "scale": run_fn.tag,
            "reference": reference,
            "rows": rows,
        }

    def _stage_report(self) -> Dict[str, Any]:
        quality = self._quality_section()
        payload: Dict[str, Any] = {}
        if quality is not None:
            payload["quality"] = quality
        payload["text"] = render_report(self.result, quality=quality)
        gate = self.spec.ga.stability_gate
        st = (quality or {}).get("stability") or {}
        if gate is not None and st.get("rel_spread", 0.0) > gate:
            payload["_error"] = (
                f"winner stability gate: relative spread "
                f"{st['rel_spread']:.1%} across {st['k']} GA seeds exceeds "
                f"the gate {gate:.1%} (ga.stability_gate)"
            )
        return payload

    # -- search-quality metrics (report stage; never feed the search) ------

    def _quality_section(self) -> Optional[Dict[str, Any]]:
        """pass@k winner stability + modeled-vs-measured rank fidelity
        (repro.offload.quality), computed in the REPORT stage only: by
        construction nothing here can perturb the recorded search."""
        if not self.result.completed("search"):
            return None
        search = self.result.stage("search").payload
        return {
            "stability": self._stability_section(search),
            "rank": self._rank_section(search),
        }

    def _stability_section(self, search: Dict[str, Any]) -> Dict[str, Any]:
        knobs = self.spec.ga
        if knobs.stability_seeds <= 1:
            return {"skipped": "disabled (ga.stability_seeds <= 1)"}
        if not search.get("history"):
            return {"skipped": "search recorded zero generations"}
        if self._evaluator is not None:
            return {"skipped": "injected evaluator (a re-search could be "
                               "arbitrarily expensive; call "
                               "quality.winner_stability directly)"}
        adapter = self.adapter
        # re-searches always run the cheap MODELED evaluator: for
        # fidelity="measured" that is the analytic model at measured
        # scale, not the wall-clocking run_fn
        model_fn = getattr(adapter, "model_evaluator", None)
        evaluator = model_fn() if callable(model_fn) \
            else self._search_evaluator()
        fp = evaluator_fingerprint(evaluator)
        recorded = None
        if search.get("evaluator") == fp \
                and search.get("best_time_s") is not None:
            # the recorded search IS the k=0 member (same evaluator)
            recorded = (search["best_genes"], search["best_time_s"])
        n = adapter.gene_length
        params = self.spec.ga_params(n, adapter.alleles)
        seeds = [
            tuple(int(g) for g in s)
            for s in self.result.stage("seed").payload.get("seeds", [])
        ]
        tracer = self._trace()

        def on_search(row: Dict[str, Any]) -> None:
            if tracer is not None:
                tracer.event("stability_search", span="report", attrs={
                    "seed": row["seed"],
                    "best_time_s": row["best_time_s"],
                    "evaluations": row["evaluations"],
                    "cache_hits": row["cache_hits"],
                })

        st = qual.winner_stability(
            evaluator, n, params,
            k=knobs.stability_seeds,
            window=knobs.stability_window,
            seeds=seeds or None,
            workers=self.spec.workers,
            cache_path=self.spec.cache,
            recorded=recorded,
            on_search=on_search,
        )
        st["evaluator"] = fp
        st["reused_recorded"] = recorded is not None
        return st

    def _rank_section(self, search: Dict[str, Any]) -> Dict[str, Any]:
        from repro.core import evaluator as ev
        from repro.offload.spec import MEASURED_PROGRAMS

        spec = self.spec
        knobs = spec.ga
        final = search.get("final_population") or []
        times = search.get("final_times_s") or []
        if not final:
            return {"skipped": "no final population recorded "
                               "(zero generations, or an artifact from "
                               "before tracing)"}
        if spec.is_arch or spec.program not in MEASURED_PROGRAMS:
            return {"skipped": "no runnable implementation to measure "
                               "against"}
        if self._evaluator is not None:
            return {"skipped": "injected evaluator"}
        if spec.fidelity != "measured" and not knobs.rank_probe:
            return {"skipped": "rank probe off (ga.rank_probe=false; "
                               "measured fidelity ranks for free)"}
        adapter = self.adapter
        n = adapter.gene_length
        run_fn = programs.MEASURED_RUN_FNS[spec.program]()
        model = self._scale_model()
        pop = [tuple(int(g) for g in ind) for ind in final]
        modeled = [float(model(g)) for g in pop]
        tracer = self._trace()

        if spec.fidelity == "measured":
            # the final generation's times ARE wall clocks — free
            if len(times) != len(pop):
                return {"skipped": "final population and times out of "
                                   "sync in the search payload"}
            measured = [float(t) for t in times]
        else:
            # two wall-clocked projections cover every candidate: the
            # runnable implementations realize exactly one placement
            # switch (hot loop on the jit path or not), so measurement
            # can only ever distinguish those two classes
            hot = programs.hot_gene_index(spec.program)
            hot_name = programs.RUNNABLE[spec.program][0]
            host = "cpu"
            if spec.mode == "mixed":
                dests = adapter.build_evaluator().dests
                accel = next((i for i, d in enumerate(dests)
                              if d.kind in ("gpu", "tpu")), None)
            else:
                accel = 1
            m = ev.MeasuredEvaluator(run_fn, repeats=spec.repeats,
                                     tag=run_fn.tag)
            zeros = (0,) * n
            t_host = float(m(zeros))
            if tracer is not None:
                tracer.event("rank_probe", span="report", attrs={
                    "projection": "all-host", "evaluations": 1,
                    "measured_s": t_host,
                })
            offloaded = [
                adapter.placement(g).get(hot_name, host) != host
                for g in pop
            ]
            t_off = None
            if any(offloaded):
                on_genome = tuple(
                    (accel if accel is not None else 1) if i == hot else 0
                    for i in range(n)
                )
                t_off = float(m(on_genome))
                if tracer is not None:
                    tracer.event("rank_probe", span="report", attrs={
                        "projection": "hot-offloaded", "evaluations": 1,
                        "measured_s": t_off,
                    })
            measured = [t_off if off else t_host for off in offloaded]
        eff = self._effective_spec()
        return qual.rank_section(
            modeled, measured,
            scale=run_fn.tag,
            reference=f"model:{eff.hw}",
        )


def render_report(result: OffloadResult,
                  quality: Optional[Dict[str, Any]] = None) -> str:
    """Human-readable end-to-end summary from artifact payloads alone
    (used by the report stage AND ``python -m repro.offload report`` on
    loaded artifacts, partial ones included). ``quality`` is the
    search-quality section the report stage just computed; for loaded
    artifacts it falls back to the recorded report payload."""
    spec = result.spec
    tag = spec.method if spec.mode == "binary" and not spec.is_arch \
        else "+".join(spec.destinations) if spec.mode == "mixed" \
        else "plan-search"
    if spec.fidelity != "modeled":
        tag += f"/{spec.fidelity}"
    rows = [f"== repro.offload report: {spec.program} [{spec.mode}/{tag}] =="]

    if result.completed("calibrate"):
        c = result.stage("calibrate").payload
        if c.get("applicable"):
            r = c["residuals"]
            rows.append(
                f"calibrate: {c['base']} -> {c['entry']} on {c['host']} "
                f"({r['n']} probes, |resid| max {r['max_abs_rel']:.1%} / "
                f"mean {r['mean_abs_rel']:.1%}; "
                f"pinned: {', '.join(c['pinned'])})"
            )
    if result.completed("analyze"):
        a = result.stage("analyze").payload
        rows.append(
            f"analyze: {a.get('description', spec.program)} — "
            f"{a['gene_length']} genes"
            + (f" / {a['n_loops']} loops" if "n_loops" in a else "")
            + f"; all-host baseline {a['baseline_s']:.4g}s"
        )
    if result.completed("seed"):
        s = result.stage("seed").payload
        if s.get("seeds"):
            info = ", ".join(
                f"{i['device']} {i['best_time_s']:.4g}s"
                for i in s["seed_info"]
            )
            rows.append(f"seed: warm-start with {len(s['seeds'])} "
                        f"single-destination bests ({info})")
        else:
            rows.append("seed: random initial population")
    if result.completed("search"):
        p = result.stage("search").payload
        if p.get("best_time_s") is None:
            rows.append(
                "search: no generations run (generations=0 budget); "
                "nothing evaluated, no winner recorded"
            )
        else:
            line = (
                f"search: best {p['best_time_s']:.4g}s in "
                f"{p['ga']['generations']} generations "
                f"({p['evaluations']} measurements, {p['cache_hits']} cache "
                f"hits, wall {p['wall_s']:.2f}s)"
            )
            if result.speedup:
                line += f"; speedup {result.speedup:.1f}x over all-host"
            rows.append(line)
            moved = {u: d for u, d in p["placement"].items()
                     if d not in ("cpu", "host")}
            rows.append(f"placement: {len(moved)}/{len(p['placement'])} "
                        "units offloaded")
            for u, d in moved.items():
                rows.append(f"    {u:24s} -> {d}")
            subs = p.get("substitutions")
            if subs is not None:
                act = [s for s in subs if s.get("active")]
                rows.append(f"blocks: {len(act)}/{len(subs)} matched "
                            "blocks substituted (docs/blocks.md)")
                for s in act:
                    rows.append(
                        f"    [{s['entry']}] {'+'.join(s['loops'])} "
                        f"-> {s['destination']}"
                    )
            r = p.get("residency")
            if r and r.get("capacities"):
                caps = ", ".join(f"{n} {b/1e6:.0f} MB"
                                 for n, b in sorted(r["capacities"].items()))
                line = (f"residency: evicted "
                        f"{r['evicted_bytes']/1e6:.1f} MB, "
                        f"streamed {r['spilled_bytes']/1e6:.1f} MB "
                        f"under capacities [{caps}]")
                if r.get("oversubscribed"):
                    line += ("; oversubscribed: "
                             + ", ".join(r["oversubscribed"]))
                rows.append(line)
    if "verify" in result.stages:
        v = result.stages["verify"]
        pc = v.payload.get("pcast", {})
        if "skipped" in pc:
            pc_txt = f"PCAST skipped ({pc['skipped']})"
        elif pc:
            pc_txt = (f"PCAST {'PASS' if pc['ok'] else 'FAIL'} "
                      f"(max_rel {pc['max_rel']:.3e}, "
                      f"{pc['n_leaves']} tensors)")
        else:
            pc_txt = "PCAST not run"
        ok = "ok" if v.done else f"FAILED: {v.error}"
        re_t = v.payload.get("re_measured_s")
        re_txt = "re-measurement skipped" if re_t is None \
            else f"re-measured {re_t:.4g}s"
        rows.append(f"verify: {ok}; {re_txt}; {pc_txt}")
        bo = v.payload.get("block_oracles")
        if bo:
            parts = ", ".join(
                f"{r['kernel']}@{r['destination']} "
                f"{'PASS' if r['ok'] else 'FAIL'} "
                f"(max_abs {r['max_abs_err']:.2e} vs {r['oracle']})"
                for r in bo
            )
            rows.append(f"block oracles: {parts}")
        fid = v.payload.get("fidelity")
        if fid and "skipped" in fid:
            rows.append(f"fidelity[{fid['level']}]: skipped "
                        f"({fid['skipped']})")
        elif fid:
            parts = ", ".join(
                f"{r['destination']}/{r['placement']} "
                f"{r['ratio']:.2f}x ({r['predicted_s']:.4g}s vs "
                f"{r['measured_s']:.4g}s)"
                if "ratio" in r else
                f"{r['placement']} skipped ({r['skipped']})"
                for r in fid["rows"]
            )
            rows.append(
                f"fidelity[{fid['level']} @ {fid['scale']}]: "
                f"predicted/measured {parts}"
            )
    q = quality
    if q is None and "report" in result.stages:
        q = result.stages["report"].payload.get("quality")
    if q:
        st = q.get("stability") or {}
        if "skipped" in st:
            rows.append(f"quality: stability skipped ({st['skipped']})")
        elif st:
            rows.append(
                f"quality: winner stability pass@{st['k']} "
                f"{st['pass_at_k']:.0%} (window {st['window']:.1%}, "
                f"spread +{st['rel_spread']:.1%}, "
                f"{st['distinct_winners']} distinct winner(s))"
            )
        rk = q.get("rank") or {}
        if "skipped" in rk:
            rows.append(f"quality: rank fidelity skipped ({rk['skipped']})")
        elif rk:
            if rk.get("spearman") is None:
                rows.append(
                    f"quality: rank fidelity undefined over {rk['n']} "
                    f"final candidates ({rk.get('note', 'degenerate')})"
                )
            else:
                kd = rk.get("kendall")
                kd_txt = f"{kd:+.2f}" if kd is not None else "n/a"
                rows.append(
                    f"quality: rank fidelity spearman "
                    f"{rk['spearman']:+.2f} / kendall {kd_txt} "
                    f"over {rk['n']} final candidates vs "
                    f"{rk.get('reference', 'model')}"
                    + (f" @ {rk['scale']}" if rk.get("scale") else "")
                )
    return "\n".join(rows)
