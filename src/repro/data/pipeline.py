"""Data pipeline: deterministic sharded token streams with host prefetch.

Design points for the 1000+ node regime:
- **Host-sharded reads**: every host materializes only its slice of the
  global batch (``host_slice``), indexed by (step, host) — no coordinator.
- **Deterministic resume**: the stream is a pure function of (seed, step),
  so restoring a checkpoint at step k replays exactly the remaining data —
  no data-state checkpointing needed.
- **Prefetch**: a background thread keeps ``prefetch`` batches ready so the
  accelerator never blocks on host-side generation/IO.
- Two sources: ``SyntheticLM`` (zipfian token soup with a learnable signal:
  next-token = f(current) mixture) and ``MemmapTokens`` (pre-tokenized
  binary file, the production path).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    prefetch: int = 2
    # memmap source (optional)
    path: Optional[str] = None


class SyntheticLM:
    """Deterministic synthetic LM stream: zipfian unigrams + a planted
    bigram structure (next = (5*cur + 7) % vocab with prob 0.5) so models
    can measurably learn; loss decreasing == pipeline + model wired right."""

    def __init__(self, vocab: int, seed: int = 1234):
        self.vocab = vocab
        self.seed = seed
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self.probs = probs / probs.sum()

    def batch(self, step: int, host: int, shape: Tuple[int, int]) -> np.ndarray:
        b, s = shape
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host])
        )
        toks = rng.choice(self.vocab, size=(b, s + 1), p=self.probs)
        structured = rng.random((b, s)) < 0.5
        # chain the planted bigram over the FINAL tokens so that
        # P(next == f(cur)) ~ 0.5 holds pairwise (learnable signal)
        for j in range(s):
            nxt = (5 * toks[:, j] + 7) % self.vocab
            toks[:, j + 1] = np.where(structured[:, j], nxt, toks[:, j + 1])
        return toks.astype(np.int32)


class MemmapTokens:
    """Flat binary int32 token file; strided deterministic sampling."""

    def __init__(self, path: str, seed: int = 1234):
        self.arr = np.memmap(path, dtype=np.int32, mode="r")
        self.seed = seed

    def batch(self, step: int, host: int, shape: Tuple[int, int]) -> np.ndarray:
        b, s = shape
        n = len(self.arr) - (s + 1)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host])
        )
        starts = rng.integers(0, n, size=b)
        return np.stack(
            [self.arr[st : st + s + 1] for st in starts]
        ).astype(np.int32)


class Pipeline:
    """Per-host pipeline yielding {tokens, targets} (+ modality stubs)."""

    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        data: DataConfig = DataConfig(),
        host_index: int = 0,
        n_hosts: int = 1,
        start_step: int = 0,
    ):
        self.cfg = cfg
        self.shape = shape
        self.dcfg = data
        self.host = host_index
        self.n_hosts = n_hosts
        assert shape.global_batch % n_hosts == 0, "batch must split over hosts"
        self.local_batch = shape.global_batch // n_hosts
        self.step = start_step
        src_vocab = cfg.vocab
        if data.path:
            self.source = MemmapTokens(data.path, data.seed)
        else:
            self.source = SyntheticLM(src_vocab, data.seed)
        self._q: "queue.Queue[Tuple[int, Dict[str, np.ndarray]]]" = queue.Queue(
            maxsize=max(data.prefetch, 1)
        )
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- batch construction ---------------------------------------------------
    def _make(self, step: int) -> Dict[str, np.ndarray]:
        cfg, S = self.cfg, self.shape.seq_len
        if cfg.family == "encoder":
            rng = np.random.default_rng(
                np.random.SeedSequence([self.dcfg.seed, step, self.host, 7])
            )
            frames = rng.standard_normal(
                (self.local_batch, S, cfg.d_model), dtype=np.float32
            )
            toks = self.source.batch(step, self.host, (self.local_batch, S))
            return {"frames": frames, "targets": toks[:, 1:]}
        if cfg.family == "vlm":
            pv = cfg.frontend_positions
            toks = self.source.batch(
                step, self.host, (self.local_batch, S - pv)
            )
            rng = np.random.default_rng(
                np.random.SeedSequence([self.dcfg.seed, step, self.host, 7])
            )
            vision = rng.standard_normal(
                (self.local_batch, pv, cfg.d_model), dtype=np.float32
            )
            return {
                "tokens": toks[:, :-1],
                "vision": vision,
                "targets": toks[:, 1:],
            }
        toks = self.source.batch(step, self.host, (self.local_batch, S))
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    # -- prefetch loop ---------------------------------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self) -> "Pipeline":
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            # synchronous fallback (tests)
            while True:
                yield self._make(self.step)
                self.step += 1
        else:
            while True:
                step, batch = self._q.get()
                self.step = step + 1
                yield batch
