"""Lightweight training telemetry: step timing, tokens/s, loss EWMA,
and a ring buffer the trainer/serving engine can export.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    loss: Optional[float] = None
    tokens: int = 0
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)


class Monitor:
    def __init__(self, window: int = 200):
        self.records: Deque[StepRecord] = collections.deque(maxlen=window)
        self._t0: Optional[float] = None
        self.loss_ewma: Optional[float] = None

    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self, step: int, loss: Optional[float] = None,
                 tokens: int = 0, **extra) -> StepRecord:
        dt = time.perf_counter() - (self._t0 or time.perf_counter())
        rec = StepRecord(step, dt, loss, tokens, dict(extra))
        self.records.append(rec)
        if loss is not None:
            self.loss_ewma = (
                loss if self.loss_ewma is None
                else 0.95 * self.loss_ewma + 0.05 * loss
            )
        return rec

    @property
    def tokens_per_second(self) -> float:
        recs = [r for r in self.records if r.tokens]
        if not recs:
            return 0.0
        return sum(r.tokens for r in recs) / max(
            sum(r.seconds for r in recs), 1e-9
        )

    def summary(self) -> Dict[str, float]:
        if not self.records:
            return {}
        times = [r.seconds for r in self.records]
        return {
            "steps": float(len(self.records)),
            "mean_step_s": sum(times) / len(times),
            "last_step_s": times[-1],
            "tokens_per_s": self.tokens_per_second,
            "loss_ewma": float(self.loss_ewma or 0.0),
        }
