"""Fault tolerance & elasticity runtime (simulated multi-host semantics).

At 1000+ nodes the failure model is: hosts heartbeat to a coordinator; a
missed deadline marks the host suspect, a second consecutive miss evicts
it; the job rebuilds its mesh from survivors and restores the latest
checkpoint. Stragglers (alive but slow) are detected from per-step time
EWMA z-scores and mitigated by skip-and-rescale (bounded staleness: drop
the straggler's microbatch from the global batch and rescale the gradient
sum) rather than eviction.

This container has one process, so hosts are simulated objects — the same
state machine a multi-controller deployment would run. Everything is pure
and unit-testable; ``launch.train`` wires it to the real loop.
"""
from __future__ import annotations

import dataclasses
import enum
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple


class HostState(str, enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    EVICTED = "evicted"


@dataclasses.dataclass
class HostRecord:
    host_id: int
    state: HostState = HostState.HEALTHY
    last_beat: float = 0.0
    missed: int = 0


class HeartbeatRegistry:
    """Coordinator-side failure detector (deadline + consecutive misses)."""

    def __init__(self, n_hosts: int, deadline_s: float = 10.0,
                 max_missed: int = 2):
        self.deadline_s = deadline_s
        self.max_missed = max_missed
        self.hosts = {h: HostRecord(h) for h in range(n_hosts)}

    def beat(self, host_id: int, now: Optional[float] = None):
        rec = self.hosts[host_id]
        if rec.state == HostState.EVICTED:
            return  # must rejoin via admit()
        rec.last_beat = time.time() if now is None else now
        rec.missed = 0
        rec.state = HostState.HEALTHY

    def sweep(self, now: Optional[float] = None) -> List[int]:
        """Advance the detector; returns hosts evicted by this sweep."""
        now = time.time() if now is None else now
        evicted = []
        for rec in self.hosts.values():
            if rec.state == HostState.EVICTED:
                continue
            if now - rec.last_beat > self.deadline_s:
                rec.missed += 1
                rec.last_beat = now
                if rec.missed >= self.max_missed:
                    rec.state = HostState.EVICTED
                    evicted.append(rec.host_id)
                else:
                    rec.state = HostState.SUSPECT
        return evicted

    def admit(self, host_id: int, now: Optional[float] = None):
        """Re-admit a replaced/recovered host (elastic scale-up)."""
        self.hosts[host_id] = HostRecord(
            host_id, HostState.HEALTHY,
            time.time() if now is None else now, 0,
        )

    def survivors(self) -> List[int]:
        return [h for h, r in self.hosts.items()
                if r.state != HostState.EVICTED]


@dataclasses.dataclass
class StragglerVerdict:
    host_id: int
    z_score: float
    is_straggler: bool


class StragglerDetector:
    """Per-host step-time EWMA + EWcross-host z-score.

    A host is a straggler when its step time exceeds the fleet mean by
    ``z_threshold`` fleet standard deviations for ``patience`` consecutive
    steps. Mitigation is the caller's choice; ``skip_and_rescale`` computes
    the gradient rescale factor for deadline-skipped microbatches.
    """

    def __init__(self, n_hosts: int, alpha: float = 0.2,
                 z_threshold: float = 3.0, patience: int = 2):
        self.alpha = alpha
        self.z_threshold = z_threshold
        self.patience = patience
        self.ewma = [0.0] * n_hosts
        self.initialized = [False] * n_hosts
        self.strikes = [0] * n_hosts

    def observe(self, step_times: Sequence[float]) -> List[StragglerVerdict]:
        for h, t in enumerate(step_times):
            if not self.initialized[h]:
                self.ewma[h] = t
                self.initialized[h] = True
            else:
                self.ewma[h] = (1 - self.alpha) * self.ewma[h] + self.alpha * t
        mean = sum(self.ewma) / len(self.ewma)
        var = sum((e - mean) ** 2 for e in self.ewma) / max(len(self.ewma), 1)
        sd = math.sqrt(var)
        out = []
        for h, e in enumerate(self.ewma):
            z = (e - mean) / sd if sd > 1e-12 else 0.0
            if z > self.z_threshold:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            out.append(
                StragglerVerdict(h, z, self.strikes[h] >= self.patience)
            )
        return out


def skip_and_rescale(n_total_microbatches: int, n_skipped: int) -> float:
    """Gradient rescale when skipping straggler microbatches: the sum over
    the surviving microbatches is an unbiased estimate of the full-batch
    mean after scaling by total/survived."""
    survived = n_total_microbatches - n_skipped
    if survived <= 0:
        raise ValueError("cannot skip every microbatch")
    return n_total_microbatches / survived


# ---------------------------------------------------------------------------
# elastic mesh planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    n_devices: int

    def describe(self) -> str:
        dims = "x".join(str(s) for s in self.shape)
        return f"{dims} ({','.join(self.axes)}) = {self.n_devices} devices"


def plan_elastic_mesh(
    n_devices: int,
    model_parallel: int,
    axes: Tuple[str, str] = ("data", "model"),
) -> MeshPlan:
    """Largest (data, model) mesh from surviving devices: the model axis is
    fixed by the plan (TP degree must divide heads/experts); leftover
    devices idle until replacements arrive. data = floor(n / model)."""
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot host model_parallel={model_parallel}"
        )
    data = n_devices // model_parallel
    return MeshPlan(
        shape=(data, model_parallel),
        axes=axes,
        n_devices=data * model_parallel,
    )


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    evicted_hosts: List[int]
    old_mesh: str
    new_mesh: str
    restored_step: Optional[int]


class FaultCoordinator:
    """Glue object: heartbeats -> eviction -> elastic replan -> restore.

    ``on_step`` is called once per training step with the per-host step
    times; when the registry evicts hosts it returns a RecoveryEvent the
    trainer uses to rebuild (mesh, state). Simulation hooks (``fail_host``)
    let tests inject failures deterministically.
    """

    def __init__(
        self,
        n_hosts: int,
        devices_per_host: int,
        model_parallel: int,
        deadline_s: float = 10.0,
    ):
        self.registry = HeartbeatRegistry(n_hosts, deadline_s=deadline_s)
        self.straggler = StragglerDetector(n_hosts)
        self.devices_per_host = devices_per_host
        self.model_parallel = model_parallel
        self.events: List[RecoveryEvent] = []
        now = time.time()
        for h in range(n_hosts):
            self.registry.beat(h, now)
        self._last_plan = self.current_plan()

    def current_plan(self) -> MeshPlan:
        n = len(self.registry.survivors()) * self.devices_per_host
        return plan_elastic_mesh(n, self.model_parallel)

    def fail_host(self, host_id: int):
        self.registry.hosts[host_id].state = HostState.EVICTED

    def on_step(
        self, step: int, host_step_times: Dict[int, float],
        now: Optional[float] = None,
    ) -> Optional[RecoveryEvent]:
        old_plan = self._last_plan
        for h, t in host_step_times.items():
            self.registry.beat(h, now)
        evicted = self.registry.sweep(now)
        dead = [
            h for h, r in self.registry.hosts.items()
            if r.state == HostState.EVICTED
        ]
        new_plan = self.current_plan()
        self._last_plan = new_plan
        if evicted or old_plan.n_devices != new_plan.n_devices:
            ev = RecoveryEvent(
                step=step,
                evicted_hosts=dead,
                old_mesh=old_plan.describe(),
                new_mesh=new_plan.describe(),
                restored_step=None,
            )
            self.events.append(ev)
            return ev
        return None
