"""Checkpoint retention + restart policy on top of ``Checkpointer``.

- keep the last ``keep_last`` checkpoints and every ``keep_every`` steps
  (permanent archive points), delete the rest after each save;
- ``restore_latest`` walks backward past torn/corrupt directories — the
  node-failure recovery path.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint.checkpointer import Checkpointer


class CheckpointManager:
    def __init__(
        self,
        root: str,
        save_every: int = 100,
        keep_last: int = 3,
        keep_every: int = 1000,
        async_save: bool = True,
    ):
        self.ckpt = Checkpointer(root)
        self.save_every = save_every
        self.keep_last = keep_last
        self.keep_every = keep_every
        self.async_save = async_save

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, state: Any, metadata: Optional[Dict] = None):
        if self.async_save:
            self.ckpt.save_async(step, state, metadata)
        else:
            self.ckpt.save(step, state, metadata)
        self._gc(at_step=step)

    def finalize(self):
        self.ckpt.wait()

    def _gc(self, at_step: int):
        steps = self.ckpt.steps()
        keep = set(steps[-self.keep_last :])
        keep |= {s for s in steps if self.keep_every and s % self.keep_every == 0}
        for s in steps:
            if s not in keep and s != at_step:
                shutil.rmtree(
                    os.path.join(self.ckpt.root, f"step_{s:08d}"),
                    ignore_errors=True,
                )

    def restore_latest(
        self, target: Any, shardings: Any = None
    ) -> Tuple[Optional[int], Any]:
        """Walk backward over available checkpoints until one restores."""
        self.ckpt.wait()
        for step in reversed(self.ckpt.steps()):
            try:
                state = self.ckpt.restore(step, target, shardings)
                return step, state
            except (KeyError, ValueError, OSError, json.JSONDecodeError):
                continue
        return None, target
