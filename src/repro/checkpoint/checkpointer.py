"""Sharded, async, mesh-agnostic checkpointing.

Format (directory per step):
    step_000100/
      index.json            # {leaf path: {shape, dtype, file}} + metadata
      host0_<leaf>.npy      # this host's shard rows (or the full array)

Design for 1000+ nodes:
- **Host-parallel IO**: every host writes only the rows of each global
  array it owns (here: single-host container writes all, but the format is
  per-host so restore composes shards).
- **Async**: ``save_async`` snapshots to host RAM (device_get) then writes
  in a background thread — the train loop resumes immediately (one step of
  staleness max, bounded by ``wait()``).
- **Mesh-agnostic restore**: the index stores only LOGICAL state (global
  shape + dtype). ``restore`` re-shards onto whatever mesh/specs the
  restoring job uses — elastic scaling = checkpoint/restore onto a smaller
  or larger mesh.
- **Atomicity**: writes go to ``<dir>.tmp`` then ``os.rename`` (POSIX
  atomic) so a crash mid-save never corrupts the latest-complete pointer.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


def _leaf_name(path) -> str:
    return _SAFE.sub("_", jax.tree_util.keystr(path)).strip("_")


def flatten_with_names(tree) -> List[Tuple[str, Any]]:
    return [
        (_leaf_name(kp), leaf)
        for kp, leaf in jax.tree_util.tree_leaves_with_path(tree)
    ]


@dataclasses.dataclass
class SaveResult:
    step: int
    directory: str
    seconds: float
    bytes_written: int


class Checkpointer:
    def __init__(self, root: str, host_index: int = 0):
        self.root = root
        self.host = host_index
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last: Optional[SaveResult] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, state: Any, metadata: Optional[Dict] = None,
             ) -> SaveResult:
        """Synchronous save of a pytree of (host-fetchable) arrays."""
        t0 = time.time()
        snap = jax.device_get(state)
        return self._write(step, snap, metadata or {}, t0)

    def save_async(self, step: int, state: Any,
                   metadata: Optional[Dict] = None) -> None:
        """Snapshot now, write in the background. Join with ``wait()``."""
        self.wait()
        t0 = time.time()
        snap = jax.device_get(state)  # snapshot before training mutates it

        def work():
            try:
                self._last = self._write(step, snap, metadata or {}, t0)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> Optional[SaveResult]:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        return self._last

    def _write(self, step: int, snap, metadata: Dict, t0: float) -> SaveResult:
        final = self._step_dir(step)
        tmp = final + f".tmp{self.host}"
        os.makedirs(tmp, exist_ok=True)
        index: Dict[str, Any] = {"leaves": {}, "metadata": metadata,
                                 "step": step}
        total = 0
        for name, leaf in flatten_with_names(snap):
            arr = np.asarray(leaf)
            fname = f"host{self.host}_{name}.npy"
            np.save(os.path.join(tmp, fname), arr)
            index["leaves"][name] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "file": fname,
            }
            total += arr.nbytes
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return SaveResult(step, final, time.time() - t0, total)

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.root, d, "index.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def metadata(self, step: int) -> Dict:
        with open(os.path.join(self._step_dir(step), "index.json")) as f:
            return json.load(f)["metadata"]

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Restore into ``target``'s pytree structure; ``shardings`` (same
        structure, NamedSharding leaves or None) re-shards for the CURRENT
        mesh — independent of the mesh that saved it."""
        d = self._step_dir(step)
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        names = [n for n, _ in flatten_with_names(target)]
        assert len(names) == len(set(names)), "leaf name collision"
        missing = [n for n in names if n not in index["leaves"]]
        if missing:
            raise KeyError(f"checkpoint {d} missing leaves: {missing[:5]}")

        loaded = {}
        for name in names:
            rec = index["leaves"][name]
            arr = np.load(os.path.join(d, rec["file"]))
            loaded[name] = arr

        flat_sh = (
            [s for _, s in flatten_with_names(shardings)]
            if shardings is not None
            else [None] * len(names)
        )

        def put(name, tgt_leaf, sh):
            arr = loaded[name]
            want_dtype = getattr(tgt_leaf, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            want_shape = tuple(getattr(tgt_leaf, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != target {want_shape}"
                )
            if sh is not None:
                return jax.device_put(arr, sh)
            return jax.device_put(arr)

        leaves = [
            put(n, t, s)
            for (n, t), s in zip(flatten_with_names(target), flat_sh)
        ]
        treedef = jax.tree_util.tree_structure(target)
        return jax.tree_util.tree_unflatten(treedef, leaves)
