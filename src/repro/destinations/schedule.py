"""N-memory transfer scheduling for mixed-destination placements.

Generalizes :func:`repro.core.transfer.build_schedule`'s BULK mode — the
source paper's program-wide data region with host/device validity
tracking — from one device memory to N. The residency state per variable
is the SET of memories holding a valid copy (an MSI-like protocol):

- a loop reading ``v`` on destination ``d`` with no valid copy at ``d``
  copies it in from the host if the host copy is valid, else from the
  (sorted-first) device that owns it — routed through the host when no
  direct link exists, which also leaves a valid staged copy in host RAM;
- a loop writing ``v`` on ``d`` invalidates every other copy (only ``d``
  is valid afterwards);
- program end flushes device-dirty variables back to the host once.

Transfers coalesce per (loop execution, link) into one latency-bearing
batch, exactly like BULK's multi-file coalescing. The dynamic execution
order (first + weighted steady-state iteration per region) is replayed
from :func:`repro.core.transfer.dynamic_events`.

Costs are counted per directed link (bytes + batch events) and priced by
the :class:`~repro.destinations.profiles.Registry`'s topology, so
asymmetric H2D/D2H links and routed device->device hops fall out of the
same accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Set, Tuple

from repro.core.loopir import LoopProgram
from repro.core.transfer import dynamic_events
from repro.destinations.profiles import Registry

Pair = Tuple[str, str]  # (src memory, dst memory), a directed link


@dataclasses.dataclass
class MixedSchedule:
    """Per-link totals of the scheduled copies across all memories."""

    bytes_by_link: Dict[Pair, float] = dataclasses.field(default_factory=dict)
    events_by_link: Dict[Pair, float] = dataclasses.field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_link.values())

    @property
    def total_events(self) -> float:
        return sum(self.events_by_link.values())

    def _add(self, pair: Pair, nbytes: float) -> None:
        self.bytes_by_link[pair] = self.bytes_by_link.get(pair, 0.0) + nbytes

    def _add_event(self, pair: Pair, times: float) -> None:
        self.events_by_link[pair] = (
            self.events_by_link.get(pair, 0.0) + times
        )

    def seconds(self, registry: Registry) -> float:
        """Price the per-link totals through the registry's topology."""
        t = 0.0
        for pair, b in self.bytes_by_link.items():
            link = registry.link(*pair)
            assert link is not None, pair
            t += b / link.bw
        for pair, n in self.events_by_link.items():
            link = registry.link(*pair)
            assert link is not None, pair
            t += n * link.latency
        return t

    def describe(self) -> str:
        rows = []
        for pair in sorted(self.bytes_by_link):
            rows.append(
                f"{pair[0]}->{pair[1]} "
                f"{self.bytes_by_link[pair]/1e6:.1f} MB"
                f"/{self.events_by_link.get(pair, 0.0):.0f} batches"
            )
        return ", ".join(rows) if rows else "no transfers"


def build_mixed_schedule(
    prog: LoopProgram,
    placement: Mapping[str, str],
    registry: Registry,
) -> MixedSchedule:
    """Residency simulation over N memories.

    ``placement`` maps every loop name to a destination name (the host
    for CPU-resident and non-offloadable loops).
    """
    host = registry.host.name
    sched = MixedSchedule()
    valid: Dict[str, Set[str]] = {v.name: {host} for v in prog.vars}
    dirty_dev: Dict[str, str] = {}  # var -> device holding the only copy

    for kind, loop, times in dynamic_events(prog, boundaries=False):
        if kind != "loop":
            continue
        assert loop is not None
        dest = placement[loop.name]
        moved: Dict[Pair, float] = {}
        for vn in sorted(loop.reads):
            if dest in valid[vn]:
                continue
            src = host if host in valid[vn] else sorted(valid[vn])[0]
            nbytes = prog.var(vn).nbytes
            for hop in registry.route(src, dest):
                moved[hop] = moved.get(hop, 0.0) + nbytes
                # a routed transfer stages a valid copy at each hop's end
                valid[vn].add(hop[1])
        for vn in sorted(loop.writes):
            valid[vn] = {dest}
            if dest == host:
                dirty_dev.pop(vn, None)
            else:
                dirty_dev[vn] = dest
        for pair, b in moved.items():
            sched._add(pair, b * times)
            sched._add_event(pair, times)  # coalesced per loop execution

    # program end: device-dirty results return to the host once
    end_moved: Dict[Pair, float] = {}
    for vn in sorted(dirty_dev):
        if host in valid[vn]:
            continue
        nbytes = prog.var(vn).nbytes
        for hop in registry.route(dirty_dev[vn], host):
            end_moved[hop] = end_moved.get(hop, 0.0) + nbytes
    for pair, b in end_moved.items():
        sched._add(pair, b)
        sched._add_event(pair, 1.0)
    return sched
