"""N-memory transfer scheduling for mixed-destination placements.

Generalizes :func:`repro.core.transfer.build_schedule`'s BULK mode — the
source paper's program-wide data region with host/device validity
tracking — from one device memory to N. The residency state per variable
is the SET of memories holding a valid copy (an MSI-like protocol):

- a loop reading ``v`` on destination ``d`` with no valid copy at ``d``
  copies it in from the host if the host copy is valid, else from the
  (sorted-first) device that owns it — routed through the host when no
  direct link exists, which also leaves a valid staged copy in host RAM;
- a loop writing ``v`` on ``d`` invalidates every other copy (only ``d``
  is valid afterwards);
- program end flushes device-dirty variables back to the host once.

Transfers coalesce per (loop execution, link) into one latency-bearing
batch, exactly like BULK's multi-file coalescing. The dynamic execution
order (first + weighted steady-state iteration per region) is replayed
from :func:`repro.core.transfer.dynamic_events`.

Costs are counted per directed link (bytes + batch events) and priced by
the :class:`~repro.destinations.profiles.Registry`'s topology, so
asymmetric H2D/D2H links and routed device->device hops fall out of the
same accounting.

Capacity-aware residency (``Destination.memory_bytes > 0``): residency
at a bounded destination is no longer free. Before a loop executes on
``d``, its working set must fit next to what already lives there:

- **eviction** — when live tensors at ``d`` plus the loop's working set
  exceed the capacity, resident variables the loop does not touch are
  evicted by *furthest next use* on ``d`` over the linearized event
  sequence (ties broken by name, so the plan is deterministic). A victim
  for which ``d`` holds the only valid copy is written back through the
  topology first (the extra device->host leg the unbounded model never
  paid); a re-read later re-fetches it (host->device), so thrash shows
  up as priced transfer traffic.
- **streaming fallback** — a loop whose own working set exceeds the
  capacity can never become resident (evicting everything would not
  help, and must not loop forever). It executes in streaming mode: reads
  staged host->device and writes returned device->host on EVERY
  execution, nothing cached. Host RAM is the backing store and is never
  bounded.

Both effects reuse the existing per-link accounting, so the
``MixedEvaluator`` prices them with zero extra plumbing. With every
capacity unset the simulation follows the exact pre-capacity code path:
schedules (and therefore searches) are byte-identical to the unbounded
model — regression-tested against a verbatim copy in
tests/test_capacity.py.

Steady-state caveat: like the unbounded protocol, the weighted replay is
exact when the residency state is periodic after one region iteration.
Eviction decisions are deterministic functions of that state, so a
thrash cycle (evict at loop L, re-fetch at loop M, every iteration) is
charged once per iteration — exactly what a real run pays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Set, Tuple

from repro.core.loopir import LoopProgram
from repro.core.transfer import dynamic_events
from repro.destinations.profiles import Registry

Pair = Tuple[str, str]  # (src memory, dst memory), a directed link


@dataclasses.dataclass
class MixedSchedule:
    """Per-link totals of the scheduled copies across all memories."""

    bytes_by_link: Dict[Pair, float] = dataclasses.field(default_factory=dict)
    events_by_link: Dict[Pair, float] = dataclasses.field(default_factory=dict)
    # capacity-pressure accounting (empty when every capacity is unset):
    # bytes forced out of each bounded destination (whether or not the
    # eviction needed a writeback), and bytes streamed per execution by
    # loops whose working set exceeds their destination's capacity
    evict_bytes_by_dest: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )
    spill_bytes_by_dest: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )
    oversubscribed: List[str] = dataclasses.field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_link.values())

    @property
    def total_events(self) -> float:
        return sum(self.events_by_link.values())

    @property
    def total_evicted_bytes(self) -> float:
        return sum(self.evict_bytes_by_dest.values())

    @property
    def total_spilled_bytes(self) -> float:
        return sum(self.spill_bytes_by_dest.values())

    def _add(self, pair: Pair, nbytes: float) -> None:
        self.bytes_by_link[pair] = self.bytes_by_link.get(pair, 0.0) + nbytes

    def _add_event(self, pair: Pair, times: float) -> None:
        self.events_by_link[pair] = (
            self.events_by_link.get(pair, 0.0) + times
        )

    def seconds(self, registry: Registry) -> float:
        """Price the per-link totals through the registry's topology."""
        t = 0.0
        for pair, b in self.bytes_by_link.items():
            link = registry.link(*pair)
            assert link is not None, pair
            t += b / link.bw
        for pair, n in self.events_by_link.items():
            link = registry.link(*pair)
            assert link is not None, pair
            t += n * link.latency
        return t

    def describe(self) -> str:
        rows = []
        for pair in sorted(self.bytes_by_link):
            rows.append(
                f"{pair[0]}->{pair[1]} "
                f"{self.bytes_by_link[pair]/1e6:.1f} MB"
                f"/{self.events_by_link.get(pair, 0.0):.0f} batches"
            )
        out = ", ".join(rows) if rows else "no transfers"
        if self.total_evicted_bytes:
            out += (
                f"; evicted {self.total_evicted_bytes/1e6:.1f} MB ["
                + ", ".join(f"{d} {b/1e6:.1f}" for d, b in
                            sorted(self.evict_bytes_by_dest.items()))
                + "]"
            )
        if self.total_spilled_bytes:
            out += (
                f"; streamed {self.total_spilled_bytes/1e6:.1f} MB "
                f"(oversubscribed: {', '.join(self.oversubscribed)})"
            )
        return out


def build_mixed_schedule(
    prog: LoopProgram,
    placement: Mapping[str, str],
    registry: Registry,
) -> MixedSchedule:
    """Residency simulation over N memories.

    ``placement`` maps every loop name to a destination name (the host
    for CPU-resident and non-offloadable loops).
    """
    host = registry.host.name
    sched = MixedSchedule()
    valid: Dict[str, Set[str]] = {v.name: {host} for v in prog.vars}
    dirty_dev: Dict[str, str] = {}  # var -> device holding the only copy

    # bounded device memories; the host's RAM is the backing store and
    # never participates in eviction
    caps: Dict[str, float] = {
        d.name: d.memory_bytes
        for d in registry.destinations
        if d.bounded and d.kind != "host"
    }
    events = list(dynamic_events(prog, boundaries=False))
    # placement-independent lookups, hoisted out of the per-genome hot
    # path (LoopProgram.var rebuilds its name->Var dict on every call)
    nbytes_of: Dict[str, float] = {v.name: float(v.nbytes)
                                   for v in prog.vars}
    touched_of = {l.name: l.touched() for l in prog.loops}
    ws_bytes: Dict[str, float] = {
        l.name: sum(nbytes_of[vn] for vn in touched_of[l.name])
        for l in prog.loops
    }

    def next_use(vn: str, dest: str, idx: int) -> int:
        """Index of the next RESIDENT loop event on ``dest`` touching
        ``vn`` (len(events) = never again = evicted first). Streaming
        (oversubscribed) loops don't count: they stage from the host on
        every execution and never read the device copy, so keeping a
        variable resident for them would protect it for nothing."""
        cap = caps[dest]
        for j in range(idx + 1, len(events)):
            l2 = events[j][1]
            if l2 is not None and placement[l2.name] == dest \
                    and vn in touched_of[l2.name] \
                    and ws_bytes[l2.name] <= cap:
                return j
        return len(events)

    def make_room(dest: str, cap: float, need: Set[str], idx: int,
                  times: float, moved: Dict[Pair, float]) -> None:
        """Evict furthest-next-use residents until ``need`` fits next to
        what stays. Terminates: victims come from resident-minus-need,
        and need alone fits (the caller checked)."""
        while True:
            resident = {vn for vn, mems in valid.items() if dest in mems}
            projected = sum(nbytes_of[vn] for vn in resident | need)
            if projected <= cap:
                return
            candidates = sorted(resident - need)
            if not candidates:  # need alone fits; defensive only
                return
            victim = max(
                candidates, key=lambda vn: (next_use(vn, dest, idx), vn)
            )
            nbytes = nbytes_of[victim]
            if valid[victim] == {dest}:
                # only valid copy lives here: write it back before
                # dropping it (the transfer the unbounded model never
                # paid); a later re-read re-fetches host->device
                for hop in registry.route(dest, host):
                    moved[hop] = moved.get(hop, 0.0) + nbytes
                    valid[victim].add(hop[1])
                dirty_dev.pop(victim, None)
            valid[victim].discard(dest)
            if dirty_dev.get(victim) == dest:
                # other memories still hold the copy (a direct
                # device-device link spread it without staging a host
                # copy): the end-of-program flush must route from one
                # that still has it
                rest = valid[victim]
                if host in rest:
                    dirty_dev.pop(victim, None)
                else:
                    dirty_dev[victim] = sorted(rest)[0]
            sched.evict_bytes_by_dest[dest] = (
                sched.evict_bytes_by_dest.get(dest, 0.0) + nbytes * times
            )

    def stream(loop, dest: str, times: float,
               moved: Dict[Pair, float]) -> None:
        """Working set larger than the device: execute in streaming
        mode — reads staged in and writes returned home on EVERY
        execution, no residency established (and none disturbed)."""
        streamed = 0.0
        for vn in sorted(loop.reads):
            nbytes = nbytes_of[vn]
            if host not in valid[vn]:
                # materialize a host copy from the current owner. The
                # ``times`` scaling at the flush is exact under the
                # first+steady replay: a var owned by a device BEFORE
                # the region materializes during the first-iteration
                # event (times=1) and host validity persists into the
                # steady event; only a writer re-invalidating it every
                # iteration re-triggers this, and then per-iteration
                # re-materialization is what a real run pays
                src = sorted(valid[vn])[0]
                for hop in registry.route(src, host):
                    moved[hop] = moved.get(hop, 0.0) + nbytes
                    valid[vn].add(hop[1])
            for hop in registry.route(host, dest):
                moved[hop] = moved.get(hop, 0.0) + nbytes
            streamed += nbytes
        for vn in sorted(loop.writes):
            nbytes = nbytes_of[vn]
            for hop in registry.route(dest, host):
                moved[hop] = moved.get(hop, 0.0) + nbytes
            valid[vn] = {host}
            dirty_dev.pop(vn, None)
            streamed += nbytes
        sched.spill_bytes_by_dest[dest] = (
            sched.spill_bytes_by_dest.get(dest, 0.0) + streamed * times
        )
        if loop.name not in sched.oversubscribed:
            sched.oversubscribed.append(loop.name)

    for idx, (kind, loop, times) in enumerate(events):
        if kind != "loop":
            continue
        assert loop is not None
        dest = placement[loop.name]
        moved: Dict[Pair, float] = {}
        cap = caps.get(dest)
        if cap is not None:
            need = set(touched_of[loop.name])
            if ws_bytes[loop.name] > cap:
                stream(loop, dest, times, moved)
                for pair, b in moved.items():
                    sched._add(pair, b * times)
                    sched._add_event(pair, times)
                continue
            make_room(dest, cap, need, idx, times, moved)
        for vn in sorted(loop.reads):
            if dest in valid[vn]:
                continue
            src = host if host in valid[vn] else sorted(valid[vn])[0]
            nbytes = nbytes_of[vn]
            for hop in registry.route(src, dest):
                moved[hop] = moved.get(hop, 0.0) + nbytes
                # a routed transfer stages a valid copy at each hop's end
                valid[vn].add(hop[1])
        for vn in sorted(loop.writes):
            valid[vn] = {dest}
            if dest == host:
                dirty_dev.pop(vn, None)
            else:
                dirty_dev[vn] = dest
        for pair, b in moved.items():
            sched._add(pair, b * times)
            sched._add_event(pair, times)  # coalesced per loop execution

    # program end: device-dirty results return to the host once
    end_moved: Dict[Pair, float] = {}
    for vn in sorted(dirty_dev):
        if host in valid[vn]:
            continue
        nbytes = nbytes_of[vn]
        for hop in registry.route(dirty_dev[vn], host):
            end_moved[hop] = end_moved.get(hop, 0.0) + nbytes
    for pair, b in end_moved.items():
        sched._add(pair, b)
        sched._add_event(pair, 1.0)
    return sched
