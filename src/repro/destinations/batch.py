"""Vectorized population pricing for the mixed-destination evaluator.

:class:`BatchMixedEvaluator` is a drop-in :class:`~repro.destinations.
mixed.MixedEvaluator` that additionally exposes
``evaluate_batch(list_of_genomes) -> list_of_seconds``: the whole
population priced in one numpy pass instead of one Python schedule
simulation per genome. The :class:`~repro.core.evalpool.EvalPool`
already routes cache misses through ``evaluate_batch`` when the
evaluator provides it, so merely constructing this class (the
``OffloadSpec.ga.batch`` knob) switches a search onto the fast path with
zero pipeline changes.

**The scalar path stays the oracle.** ``__call__`` is inherited
unchanged, ``verify`` re-measures the winner through it, and the parity
property tests (tests/test_batch_evaluator.py) hold the batch numbers to
the scalar ones within round-off (the only difference is floating-point
summation order — well under the pipeline's ``_REMEASURE_RTOL``).
``fingerprint()``/``cache_key()`` are inherited too, so batch and scalar
searches share one persistent fitness cache and the knob can never
poison cached times.

How the vectorization works:

- **compute + setup** — a ``(loops, k)`` table of per-destination nest
  seconds (execs and setup folded in) built once; a population prices as
  one fancy-indexed gather + row sum. Admissibility clamping is a
  precomputed ``(loops, k)`` index table (gene ``g`` -> ``g`` or 0).
- **transfer** — the N-memory residency protocol of
  :func:`~repro.destinations.schedule.build_mixed_schedule`, replayed
  once over the event stream with the per-variable residency state held
  as *bitmask arrays over the whole population* (``valid[pop, var]``:
  bit ``m`` set = memory ``m`` holds a valid copy). Each event groups
  the population by (source, destination) memory pair — at most
  ``M * M`` groups, M the registry's memory count — and applies every
  route hop to the whole group at once. Per-link byte/batch totals
  accumulate into ``(pop, links)`` arrays and price through the
  registry's bandwidth/latency constants with two matrix-vector
  products.

**Bounded capacities fall back to the scalar loop.** Furthest-next-use
eviction makes every genome's residency state depend on its own event
history in a way that has no useful population-wide grouping, so when
any *searched* destination is capacity-bounded ``evaluate_batch``
degrades to per-genome scalar calls — trivially exact, just not faster.
The default machine (``quadro-p4000``) and every unbounded registry take
the vectorized path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.loopir import LoopProgram
from repro.core.transfer import dynamic_events
from repro.destinations.mixed import MixedEvaluator, mixed_loop_time
from repro.destinations.profiles import Registry

Genes = Tuple[int, ...]


class BatchMixedEvaluator(MixedEvaluator):
    """:class:`MixedEvaluator` + a vectorized ``evaluate_batch``.

    Construction cost is one scalar-path table build (lazy, on the first
    batch call); per-population cost is O(events * vars) numpy work
    independent of the population size's Python overhead.
    """

    def __init__(
        self,
        prog: LoopProgram,
        destinations: Sequence[str] = ("cpu", "gpu", "fpga"),
        registry: Optional[Registry] = None,
    ):
        super().__init__(prog, destinations, registry=registry)
        self._tables_built = False
        # any bounded searched destination -> scalar fallback (see
        # module docstring); the host never bounds
        self._scalar_only = any(
            d.bounded for d in self.dests if d.kind != "host"
        )

    # -- table construction (lazy; once per evaluator) ----------------------

    def _build_tables(self) -> None:
        prog, reg = self.prog, self.registry
        k = self.k
        # memory universe = the registry's destinations (routes only
        # ever stage through these); indices are registry order
        mems = [d.name for d in reg.destinations]
        self._M = M = len(mems)
        mem_idx = {n: i for i, n in enumerate(mems)}
        self._host = host = mem_idx[reg.host.name]
        self._host_bit = 1 << host
        # searched-subset gene value -> registry memory index
        self._mem_of_allele = np.array(
            [mem_idx[d.name] for d in self.dests], dtype=np.int64
        )

        # links: per-directed-link bandwidth/latency vectors
        self._link_idx = {
            (a, b): i for i, (a, b, _) in enumerate(reg.links)
        }
        self._L = max(1, len(reg.links))
        inv_bw = np.zeros(self._L)
        lat = np.zeros(self._L)
        for i, (_, _, link) in enumerate(reg.links):
            inv_bw[i] = 1.0 / link.bw
            lat[i] = link.latency
        self._inv_bw, self._lat = inv_bw, lat

        # route cache: (src mem, dst mem) -> ((link idx, hop-end mem),...)
        self._routes: Dict[Tuple[int, int], Tuple[Tuple[int, int], ...]] = {}
        # scalar read protocol: source = host when the host copy is
        # valid, else the name-sorted-first valid memory. Both baked
        # into one LUT over the validity bitmask.
        by_name = sorted(range(M), key=lambda i: mems[i])
        src_lut = np.zeros(1 << M, dtype=np.int64)
        for mask in range(1, 1 << M):
            src_lut[mask] = host if mask >> host & 1 else next(
                i for i in by_name if mask >> i & 1
            )
        self._src_lut = src_lut

        # compute + setup: (offloadable loop, allele) -> seconds, with
        # the non-offloadable remainder as one host-priced constant
        offl = list(prog.offloadable_loops)
        offl_names = {l.name for l in offl}
        n = len(offl)
        cost = np.zeros((max(1, n), k))
        clamp = np.zeros((max(1, n), k), dtype=np.int64)
        for i, loop in enumerate(offl):
            execs = prog.region_trip(loop.parent_seq)
            for j, d in enumerate(self.dests):
                cost[i, j] = (
                    mixed_loop_time(prog, loop, d) * execs
                    + d.setup_latency
                )
                clamp[i, j] = j if d.accepts(loop.klass) else 0
        self._cost, self._clamp = cost, clamp
        host_dest = self.dests[0]
        self._base = sum(
            mixed_loop_time(prog, l, host_dest)
            * prog.region_trip(l.parent_seq)
            + host_dest.setup_latency
            for l in prog.loops if l.name not in offl_names
        )

        # the replayed event stream, with per-loop read/write var lists
        # (name-sorted, exactly the scalar iteration order) resolved to
        # (var index, nbytes) pairs once
        self._vars = sorted(v.name for v in prog.vars)
        vidx = {n_: i for i, n_ in enumerate(self._vars)}
        nbytes = {v.name: float(v.nbytes) for v in prog.vars}
        gi_of = {l.name: i for i, l in enumerate(offl)}
        self._nV = len(self._vars)
        self._events: List[Tuple[Optional[int], float, list, list]] = []
        for kind, loop, times in dynamic_events(prog, boundaries=False):
            if kind != "loop":
                continue
            assert loop is not None
            self._events.append((
                gi_of.get(loop.name),  # None = host-pinned
                float(times),
                [(vidx[v], nbytes[v]) for v in sorted(loop.reads)],
                [(vidx[v], nbytes[v]) for v in sorted(loop.writes)],
            ))
        self._flush_vars = [(vidx[v], nbytes[v]) for v in self._vars]
        self._tables_built = True

    def _route(self, src: int, dst: int) -> Tuple[Tuple[int, int], ...]:
        hops = self._routes.get((src, dst))
        if hops is None:
            mems = [d.name for d in self.registry.destinations]
            hops = tuple(
                (self._link_idx[pair],
                 mems.index(pair[1]))
                for pair in self.registry.route(mems[src], mems[dst])
            )
            self._routes[(src, dst)] = hops
        return hops

    # -- the vectorized pass ------------------------------------------------

    def evaluate_batch(self, genomes: Sequence[Sequence[int]]) -> List[float]:
        """Predicted seconds for every genome, in input order."""
        if not len(genomes):
            return []
        if self._scalar_only:
            # capacity-bounded searched subset: exact by construction
            return [float(self(g)) for g in genomes]
        if not self._tables_built:
            self._build_tables()
        n = self.prog.gene_length
        G = np.asarray([[int(g) for g in ind] for ind in genomes],
                       dtype=np.int64)
        assert G.shape == (len(genomes), n), (G.shape, n)
        pop = G.shape[0]

        if n:
            rows = np.arange(n)[None, :]
            Gc = self._clamp[rows, G]  # admissibility clamping
            total = self._base + self._cost[rows, Gc].sum(axis=1)
        else:
            Gc = G
            total = np.full(pop, self._base)

        # residency state over the whole population
        valid = np.full((pop, self._nV), self._host_bit, dtype=np.int64)
        dirty = np.full((pop, self._nV), -1, dtype=np.int64)
        link_bytes = np.zeros((pop, self._L))
        link_events = np.zeros((pop, self._L))
        host = self._host
        host_pinned = np.full(pop, host, dtype=np.int64)

        for gi, times, reads, writes in self._events:
            dmem = self._mem_of_allele[Gc[:, gi]] if gi is not None \
                else host_pinned
            dbit = np.left_shift(1, dmem)
            moved = np.zeros((pop, self._L))
            batched = np.zeros((pop, self._L), dtype=bool)
            for vi, nb in reads:
                v = valid[:, vi]
                need = (v & dbit) == 0
                if not need.any():
                    continue
                code = self._src_lut[v] * self._M + dmem
                for c in np.unique(code[need]):
                    sel = need & (code == c)
                    s, d = divmod(int(c), self._M)
                    for lidx, end in self._route(s, d):
                        moved[sel, lidx] += nb
                        batched[sel, lidx] = True
                        # a routed transfer stages a valid copy at each
                        # hop's end, exactly like the scalar protocol
                        valid[sel, vi] = valid[sel, vi] | (1 << end)
            for vi, _nb in writes:
                valid[:, vi] = dbit
                dirty[:, vi] = np.where(dmem == host, -1, dmem)
            link_bytes += moved * times
            link_events += batched * times

        # program end: device-dirty results return to the host once
        moved = np.zeros((pop, self._L))
        batched = np.zeros((pop, self._L), dtype=bool)
        for vi, nb in self._flush_vars:
            d = dirty[:, vi]
            flush = (d >= 0) & ((valid[:, vi] & self._host_bit) == 0)
            if not flush.any():
                continue
            for dv in np.unique(d[flush]):
                sel = flush & (d == dv)
                for lidx, end in self._route(int(dv), host):
                    moved[sel, lidx] += nb
                    batched[sel, lidx] = True
        link_bytes += moved
        link_events += batched

        total = total + link_bytes @ self._inv_bw + link_events @ self._lat
        return [float(t) for t in total]
