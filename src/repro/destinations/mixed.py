"""Mixed-destination evaluator: k-ary genes -> predicted seconds.

The genome generalizes the paper's binary offload genome (gene = 0/1)
to destination indices: gene i places offloadable loop i on
``destinations[gene i]``, where index 0 is always the host CPU. The
evaluator composes

- per-destination loop times (each :class:`Destination` profile's
  class-dependent effective rates + launch latency),
- the cross-destination transfer schedule
  (:func:`~repro.destinations.schedule.build_mixed_schedule`'s N-memory
  residency tracking, priced through the registry topology — including,
  on destinations with a bounded ``memory_bytes``, the eviction
  writebacks/re-fetches and per-execution streaming traffic of
  capacity-aware residency, so the GA learns to split working sets
  across destinations or retreat to the host), and
- one-time per-kernel setup costs (the FPGA configuration charge).

Caching: ``fingerprint()`` identifies the program + the WHOLE modeled
machine (every profile + link constant, memory capacities included — a
constrained machine never shares cached times with its unbounded twin)
but deliberately not the searched
destination subset, and ``cache_key()`` renders a genome as the
destination *names* of its admissible placement. Together these make the
PR-1 persistent JSONL fitness cache shareable across searches over
different destination subsets of one machine: a CPU+GPU search and a
CPU+GPU+FPGA search hit the same entries for every genome whose placement
uses only the shared destinations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.core.evaluator import loop_bytes
from repro.core.loopir import Loop, LoopProgram
from repro.destinations.profiles import (
    Destination,
    Registry,
    default_registry,
)
from repro.destinations.schedule import MixedSchedule, build_mixed_schedule

Genes = Tuple[int, ...]


def mixed_loop_time(
    prog: LoopProgram, loop: Loop, dest: Destination
) -> float:
    """Time for ONE execution of the full nest on ``dest`` (generalizes
    :func:`repro.core.evaluator.loop_time` to any destination profile)."""
    flops = loop.total_flops
    byts = loop_bytes(prog, loop)
    t = max(flops / dest.rate_for(loop), byts / dest.membw)
    return t + dest.launch_latency


@dataclasses.dataclass
class MixedBreakdown:
    """Where the predicted seconds go, per destination."""

    compute_s: Dict[str, float]  # destination name -> compute seconds
    transfer_s: float
    setup_s: float
    schedule: MixedSchedule

    @property
    def total_s(self) -> float:
        return sum(self.compute_s.values()) + self.transfer_s + self.setup_s

    def describe(self) -> str:
        comp = ", ".join(
            f"{n} {t:.3g}s" for n, t in sorted(self.compute_s.items())
        )
        return (
            f"compute[{comp}] transfer {self.transfer_s:.3g}s "
            f"setup {self.setup_s:.3g}s = {self.total_s:.3g}s "
            f"({self.schedule.describe()})"
        )


class MixedEvaluator:
    """k-ary genes -> predicted seconds over a destination subset.

    ``destinations`` names the searched subset (order = gene value
    meaning); the first entry must be the registry's host. Gene length and
    admissibility follow the LoopProgram exactly as in the binary search:
    one gene per offloadable loop, and a placement the destination's
    compiler rejects (inadmissible LoopClass) falls back to the host —
    the mixed analogue of ``MiniappEvaluator.admissible``'s masking.
    """

    def __init__(
        self,
        prog: LoopProgram,
        destinations: Sequence[str] = ("cpu", "gpu", "fpga"),
        registry: Optional[Registry] = None,
    ):
        self.prog = prog
        self.registry = registry if registry is not None else \
            default_registry()
        self.dests: Tuple[Destination, ...] = tuple(
            self.registry.get(n) for n in destinations
        )
        assert self.dests, "need at least the host destination"
        assert self.dests[0].kind == "host", \
            "destinations[0] must be the host (gene value 0 = stay on CPU)"

    @property
    def k(self) -> int:
        """Gene alphabet size (pass as ``GAParams.alleles``)."""
        return len(self.dests)

    def allele_names(self) -> Tuple[str, ...]:
        """Destination name per allele value, host first — what a gene
        value *means* (surfaced in trace/report tooling so telemetry
        stays readable without the registry at hand)."""
        return tuple(d.name for d in self.dests)

    # -- genome -> placement ------------------------------------------------

    def admissible(self, genes: Sequence[int]) -> Genes:
        """Clamp inadmissible placements to the host (index 0)."""
        out = []
        for g, loop in zip(genes, self.prog.offloadable_loops):
            g = int(g)
            assert 0 <= g < self.k, (g, self.k)
            out.append(g if self.dests[g].accepts(loop.klass) else 0)
        return tuple(out)

    def placement(self, genes: Sequence[int]) -> Dict[str, str]:
        """{loop name: destination name} for ALL loops (non-offloadable
        and inadmissible ones on the host)."""
        host = self.dests[0].name
        out = {l.name: host for l in self.prog.loops}
        for g, loop in zip(self.admissible(genes), self.prog.offloadable_loops):
            out[loop.name] = self.dests[g].name
        return out

    def cache_key(self, genes: Sequence[int]) -> str:
        """Canonical, destination-SET-independent key: the admissible
        placement as destination names, one per gene. Adopted by
        :class:`repro.core.evalpool.EvalPool` in place of the digit
        string, so searches over different subsets share cache entries
        for placements within their overlap."""
        return ",".join(
            self.dests[g].name for g in self.admissible(genes)
        )

    # -- scoring ------------------------------------------------------------

    def breakdown(self, genes: Sequence[int]) -> MixedBreakdown:
        place = self.placement(genes)
        by_name = {d.name: d for d in self.dests}
        compute: Dict[str, float] = {d.name: 0.0 for d in self.dests}
        setup_s = 0.0
        for loop in self.prog.loops:
            dest = by_name[place[loop.name]]
            execs = self.prog.region_trip(loop.parent_seq)
            compute[dest.name] += mixed_loop_time(
                self.prog, loop, dest
            ) * execs
            setup_s += dest.setup_latency  # one-time per placed kernel
        sched = build_mixed_schedule(self.prog, place, self.registry)
        return MixedBreakdown(
            compute_s=compute,
            transfer_s=sched.seconds(self.registry),
            setup_s=setup_s,
            schedule=sched,
        )

    def __call__(self, genes: Sequence[int]) -> float:
        return self.breakdown(genes).total_s

    def host_only_time(self) -> float:
        return self((0,) * self.prog.gene_length)

    # -- caching ------------------------------------------------------------

    def fingerprint(self) -> str:
        """Program (structural digest, not just the name — another grid
        size must not share times) + whole-machine identity; NOT the
        searched subset (see module docstring — subset-independence is
        what lets searches over different destination subsets share one
        cache file)."""
        return (
            f"mixed:{self.prog.fingerprint()}:{self.registry.fingerprint()}"
        )
