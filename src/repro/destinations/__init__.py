"""Mixed-destination offload search (arXiv:2011.12431 direction).

The source paper searches binary CPU/GPU placements for application loop
statements; its successors extend the same GA to FPGAs and to *mixed
offloading destination environments* where every loop may land on CPU,
GPU or FPGA in one search. This subsystem layers that on the existing
core without changing binary-search behavior:

- profiles: :class:`Destination` registry — per-backend
  ``HardwareModel``-style profiles with admissibility rules (which
  ``LoopClass`` each backend's compiler accepts) and the transfer
  topology between memories (device->device routes through the host).
- schedule: N-memory residency tracking (the BULK mode of
  ``core.transfer`` generalized from one device to N), per-link byte and
  batch accounting priced by the topology. Destinations with a bounded
  ``memory_bytes`` get capacity-aware residency: furthest-next-use
  eviction with writeback traffic, and a per-execution streaming
  fallback for loops whose working set exceeds the device.
- mixed: :class:`MixedEvaluator` — k-ary genes (destination indices,
  ``core.genome``'s generalized operators with ``GAParams.alleles=k``)
  -> predicted seconds, with a destination-set-independent
  ``fingerprint()``/``cache_key()`` pair so the persistent evalpool
  fitness cache is shared across searches over different destination
  subsets of one machine.
"""
from repro.destinations import batch, mixed, profiles, schedule
from repro.destinations.batch import BatchMixedEvaluator
from repro.destinations.mixed import (
    MixedBreakdown,
    MixedEvaluator,
    mixed_loop_time,
)
from repro.destinations.profiles import (
    REGISTRIES,
    Destination,
    Link,
    Registry,
    calibrated_registry,
    constrained_registry,
    default_registry,
    fpga_destination,
    get_registry,
    gpu_destination,
    host_destination,
    register_registry,
    tpu_destination,
    tpu_host_registry,
)
from repro.destinations.schedule import MixedSchedule, build_mixed_schedule

__all__ = [
    "BatchMixedEvaluator",
    "Destination",
    "Link",
    "MixedBreakdown",
    "MixedEvaluator",
    "MixedSchedule",
    "REGISTRIES",
    "Registry",
    "batch",
    "build_mixed_schedule",
    "calibrated_registry",
    "constrained_registry",
    "default_registry",
    "fpga_destination",
    "get_registry",
    "gpu_destination",
    "host_destination",
    "mixed",
    "mixed_loop_time",
    "profiles",
    "register_registry",
    "schedule",
    "tpu_destination",
    "tpu_host_registry",
]
