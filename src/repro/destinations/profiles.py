"""Per-backend destination profiles + transfer topology.

The mixed-destination follow-up to the source paper (arXiv:2011.12431)
searches CPU, GPU and FPGA placements in ONE genome. This module holds the
pieces that make a backend a first-class *destination*:

- :class:`Destination` — a ``HardwareModel``-style profile (effective
  per-``LoopClass`` compute rates, memory bandwidth, launch latency, a
  one-time per-kernel setup cost) plus its admissibility rule: which loop
  classes the backend's compiler accepts at all. The host CPU is itself a
  destination (``kind="host"``), always index 0 of a search: it is the
  fallback for inadmissible placements and the home of every variable.

- :class:`Registry` — the destinations plus the transfer topology between
  their memories: per directed pair (bandwidth, latency) links. Only
  host<->device links exist physically in the modeled machines; a
  device->device transfer (GPU->FPGA) routes through the host, paying both
  legs (and leaving a staged copy in host RAM, which the residency
  simulation credits).

Calibration notes: the GPU numbers are the paper verification machine's
Quadro P4000 constants frozen in :mod:`repro.core.evaluator`. The FPGA
profile models a mid-range PCIe accelerator card compiled through an
HLS-style flow: a ~10x lower clock-derived peak than the GPU on parallel
nests, but deeply pipelined loop bodies (II=1 pipelines make
sequential-carry/vectorizable-only loops run near peak instead of
collapsing to a lane rate as on the GPU), a high one-time per-kernel
configuration cost, and a narrower host link.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core.evaluator import QUADRO_P4000, TPU_V5E_HOST, HardwareModel
from repro.core.loopir import Loop, LoopClass


@dataclasses.dataclass(frozen=True)
class Destination:
    """One offload destination: admissibility + effective rates.

    ``rates`` maps an admissible :class:`LoopClass` to the effective
    flop/s the backend sustains on loops of that class. ``degraded_rates``
    lists classes the backend's compiler accepts only through a painful
    fallback (e.g. an HLS flow sequentializing a ragged-tile loop instead
    of rejecting it): the placement is LEGAL — the GA may choose it and
    prices the degraded rate — instead of the old boolean rejection that
    silently re-homed the loop to the host. A class absent from both is
    inadmissible (a hard compile error): the evaluator re-homes the loop
    to the host, the GA's analogue of a pgcc compile error that doesn't
    kill the whole individual.
    """

    name: str
    kind: str  # "host" | "gpu" | "fpga" | ...
    rates: Tuple[Tuple[LoopClass, float], ...]
    sequential_rate: float  # rate when loop.sequential_carry is set
    membw: float
    launch_latency: float = 0.0  # per kernel launch
    setup_latency: float = 0.0  # ONE-TIME per distinct loop placed here
    degraded_rates: Tuple[Tuple[LoopClass, float], ...] = ()
    # device memory capacity in bytes; 0.0 = unbounded (the pre-capacity
    # model, and the host's backing store). When set, the N-memory
    # residency schedule evicts (furthest-next-use) once live tensors
    # exceed it, and a loop whose own working set does not fit streams
    # from the host instead of becoming resident.
    memory_bytes: float = 0.0

    @property
    def bounded(self) -> bool:
        return self.memory_bytes > 0.0

    def accepts(self, klass: LoopClass) -> bool:
        return any(k == klass for k, _ in self.rates) or self.degraded(klass)

    def degraded(self, klass: LoopClass) -> bool:
        """True when ``klass`` compiles only through the degraded path."""
        return any(k == klass for k, _ in self.degraded_rates)

    def rate_for(self, loop: Loop) -> float:
        # the degraded fallback governs its classes outright (the
        # sequentialized datapath IS the carry handling — no II=1 bonus)
        for k, r in self.degraded_rates:
            if k == loop.klass:
                return r
        if loop.sequential_carry:
            return self.sequential_rate
        for k, r in self.rates:
            if k == loop.klass:
                return r
        raise KeyError(f"{self.name} does not accept {loop.klass}")

    def fingerprint(self) -> str:
        rates = ",".join(f"{k.value}={r:.6g}" for k, r in self.rates)
        deg = ",".join(f"{k.value}={r:.6g}" for k, r in self.degraded_rates)
        # the capacity term appears only when bounded, so every
        # pre-capacity fingerprint (and the persistent fitness caches
        # keyed on it) stays byte-identical for unbounded profiles
        mem = f"|mem={self.memory_bytes:.6g}" if self.bounded else ""
        return (
            f"{self.name}[{self.kind}|{rates}|seq={self.sequential_rate:.6g}"
            f"|bw={self.membw:.6g}|launch={self.launch_latency:.6g}"
            f"|setup={self.setup_latency:.6g}"
            f"{'|deg=' + deg if deg else ''}{mem}]"
        )


def host_destination(
    hw: HardwareModel = QUADRO_P4000, name: str = "cpu"
) -> Destination:
    """The host CPU as a destination: accepts everything (it is where
    loops already live), no launch or setup cost. Host RAM is the
    backing store of the residency protocol and stays unbounded."""
    return Destination(
        name=name,
        kind="host",
        rates=(
            (LoopClass.TIGHT, hw.cpu_flops),
            (LoopClass.NON_TIGHT, hw.cpu_flops),
            (LoopClass.VECTOR_ONLY, hw.cpu_flops),
            (LoopClass.NOT_OFFLOADABLE, hw.cpu_flops),
        ),
        sequential_rate=hw.cpu_flops,
        membw=hw.cpu_membw,
    )


def gpu_destination(
    hw: HardwareModel = QUADRO_P4000, name: str = "gpu",
    memory_bytes: float = 0.0,
) -> Destination:
    """The paper's GPU path as a destination (same class->directive->rate
    mapping as :func:`repro.core.evaluator.loop_time`)."""
    return Destination(
        name=name,
        kind="gpu",
        rates=(
            (LoopClass.TIGHT, hw.accel_flops_kernels),
            (LoopClass.NON_TIGHT, hw.accel_flops_parallel),
            (LoopClass.VECTOR_ONLY, hw.accel_flops_vector),
        ),
        sequential_rate=hw.accel_flops_vector,
        membw=hw.accel_membw,
        launch_latency=hw.launch_latency,
        memory_bytes=memory_bytes,
    )


def fpga_destination(name: str = "fpga",
                     memory_bytes: float = 0.0) -> Destination:
    """FPGA-like profile (HLS flow on a mid-range PCIe card).

    - TIGHT nests: clock-limited, ~10x below the GPU's kernels rate.
    - NON_TIGHT (ragged tile bounds): admissible only through a DEGRADED
      fallback — dynamic inner trip counts don't map to a static pipeline,
      so the HLS flow sequentializes the loop body behind a handshake,
      landing below even the host's scalar rate. The placement is legal
      (the GA may take it and pay for it) but never profitable unless
      residency savings outweigh the compute loss.
    - VECTOR_ONLY / sequential-carry loops: the FPGA's win — a deeply
      pipelined datapath (II=1) keeps the dependence chain at full rate
      where the GPU collapses to its lane (VPU) rate.
    - High one-time setup per distinct kernel (partial-reconfiguration
      region load + datapath handshake), so sprinkling many trivial loops
      onto the fabric is penalized.
    - Memory: on-card DDR, below the GPU's GDDR; residency is what makes
      it cheap (tracked by the schedule, not a rate here).
    """
    return Destination(
        name=name,
        kind="fpga",
        rates=(
            (LoopClass.TIGHT, 5.6e10),
            (LoopClass.VECTOR_ONLY, 8.9e10),
        ),
        # sequentialized ragged-tile fallback: below the host's ~3.3e9
        # scalar rate, so the GA only ever picks it when residency savings
        # beat the compute loss
        degraded_rates=((LoopClass.NON_TIGHT, 1.0e9),),
        sequential_rate=8.9e10,
        membw=4.3e10,
        launch_latency=1.2e-5,
        setup_latency=1.8e-3,
        memory_bytes=memory_bytes,
    )


def tpu_destination(
    hw: HardwareModel = TPU_V5E_HOST, name: str = "tpu0",
    memory_bytes: float = 0.0,
) -> Destination:
    """One TPU-like device fed from host RAM.

    XLA compiles every loop class, but the paper's classification still
    maps onto the chip: tight nests hit the MXU rate, ragged-tile nests
    a bit below it, and vectorizable-only / sequential-carry loops run at
    the VPU lane rate (the chip has no II=1 pipeline trick — a carried
    dependence serializes it just like on the GPU)."""
    return Destination(
        name=name,
        kind="tpu",
        rates=(
            (LoopClass.TIGHT, hw.accel_flops_kernels),
            (LoopClass.NON_TIGHT, hw.accel_flops_parallel),
            (LoopClass.VECTOR_ONLY, hw.accel_flops_vector),
        ),
        sequential_rate=hw.accel_flops_vector,
        membw=hw.accel_membw,
        launch_latency=hw.launch_latency,
        memory_bytes=memory_bytes,
    )


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed memory-to-memory link."""

    bw: float  # bytes/s
    latency: float  # seconds per transfer batch


@dataclasses.dataclass(frozen=True)
class Registry:
    """Destination set + transfer topology for one modeled machine.

    ``links`` holds the physical directed links (host<->device). Routes
    between two devices go through the host: :meth:`route` returns the hop
    list and the schedule prices every hop.
    """

    name: str
    destinations: Tuple[Destination, ...]
    links: Tuple[Tuple[str, str, Link], ...]

    def __post_init__(self):
        names = [d.name for d in self.destinations]
        assert len(set(names)) == len(names), "duplicate destination names"
        assert any(d.kind == "host" for d in self.destinations), \
            "a registry needs a host destination"

    def get(self, name: str) -> Destination:
        for d in self.destinations:
            if d.name == name:
                return d
        raise KeyError(
            f"unknown destination {name!r}; have "
            f"{[d.name for d in self.destinations]}"
        )

    @property
    def host(self) -> Destination:
        return next(d for d in self.destinations if d.kind == "host")

    def link(self, src: str, dst: str) -> Optional[Link]:
        for a, b, l in self.links:
            if (a, b) == (src, dst):
                return l
        return None

    def route(self, src: str, dst: str) -> Tuple[Tuple[str, str], ...]:
        """Hop list from ``src`` memory to ``dst`` memory. Direct when a
        physical link exists; otherwise staged through the host."""
        if src == dst:
            return ()
        if self.link(src, dst) is not None:
            return ((src, dst),)
        h = self.host.name
        if src != h and dst != h \
                and self.link(src, h) and self.link(h, dst):
            return ((src, h), (h, dst))
        raise KeyError(f"no route {src} -> {dst} in registry {self.name}")

    def fingerprint(self) -> str:
        """Stable digest of every profile + link constant. Part of the
        mixed evaluator's cache fingerprint: searches share measurements
        only when the whole modeled machine is identical — note the
        *searched subset* is deliberately NOT part of this, so searches
        over different subsets of one machine share their overlap."""
        parts = [self.name]
        parts += [d.fingerprint() for d in self.destinations]
        parts += [
            f"{a}->{b}:bw={l.bw:.6g},lat={l.latency:.6g}"
            for a, b, l in self.links
        ]
        digest = hashlib.sha256("|".join(parts).encode()).hexdigest()[:16]
        return f"{self.name}-{digest}"


def default_registry(hw: HardwareModel = QUADRO_P4000) -> Registry:
    """The paper's verification machine extended with the FPGA card:
    i5-7500 host + Quadro P4000 (PCIe3 x16) + FPGA (PCIe3 x8)."""
    pcie_gpu = Link(bw=hw.link_bw, latency=hw.link_latency)
    pcie_fpga = Link(bw=3.8e9, latency=5.0e-5)  # x8 + driver overhead
    return Registry(
        name="p4000-fpga",
        destinations=(
            host_destination(hw),
            gpu_destination(hw),
            fpga_destination(),
        ),
        links=(
            ("cpu", "gpu", pcie_gpu),
            ("gpu", "cpu", pcie_gpu),
            ("cpu", "fpga", pcie_fpga),
            ("fpga", "cpu", pcie_fpga),
        ),
    )


# device capacities of the CONSTRAINED variant of the paper machine: the
# GPU gets a card so small (45 MB) that even one hetero stencil's working
# set (three 16.8 MB planes) cannot sit resident — stencils placed there
# fall into the per-execution streaming fallback — while the FPGA's
# on-card DDR is slower but spacious. Under these capacities the TRUE
# optimum (verified exhaustively over all 3^12 placements) moves the
# stencil pipeline off the GPU: eviction pressure, not compute rate,
# decides placement (arXiv:2004.08548's small-memory-destination
# motivation). benchmarks/fig_capacity.py is the divergence demo.
CONSTRAINED_GPU_BYTES = 4.5e7
CONSTRAINED_FPGA_BYTES = 1.28e8


def constrained_registry(hw: HardwareModel = QUADRO_P4000) -> Registry:
    """The paper machine with *bounded* device memories: identical rates
    and links to :func:`default_registry`, but the schedule must now fit
    live tensors into each card (evicting when they don't)."""
    base = default_registry(hw)
    caps = {"gpu": CONSTRAINED_GPU_BYTES, "fpga": CONSTRAINED_FPGA_BYTES}
    return Registry(
        name="p4000-constrained",
        destinations=tuple(
            dataclasses.replace(d, memory_bytes=caps[d.name])
            if d.name in caps else d
            for d in base.destinations
        ),
        links=base.links,
    )


# per-device capacity of the TPU-host machine: two accelerator devices
# whose individual memory is TIGHT (below the hetero working set), so a
# capacity-aware search learns to SPLIT the working set across devices
# where the unbounded model would happily pile everything onto one.
TPU_DEVICE_BYTES = 6.4e7


def tpu_host_registry(hw: HardwareModel = TPU_V5E_HOST) -> Registry:
    """Second machine registry: a TPU host with two small-memory devices.

    Both devices share the host link bandwidth class (each fed from host
    RAM over its own PCIe-style path); device->device traffic stages
    through the host. Same search, different machine: on this registry
    the capacity pressure — not the compute rates — decides placement."""
    pcie = Link(bw=hw.link_bw, latency=hw.link_latency)
    return Registry(
        name="tpu-v5e-host",
        destinations=(
            host_destination(hw),
            tpu_destination(hw, "tpu0", memory_bytes=TPU_DEVICE_BYTES),
            tpu_destination(hw, "tpu1", memory_bytes=TPU_DEVICE_BYTES),
        ),
        links=(
            ("cpu", "tpu0", pcie),
            ("tpu0", "cpu", pcie),
            ("cpu", "tpu1", pcie),
            ("tpu1", "cpu", pcie),
        ),
    )


# named machine registries, selectable as ``OffloadSpec.hw`` in mixed
# mode — capacities are profile constants, so naming the registry in the
# frozen spec makes them part of the artifact/cache identity.
# "quadro-p4000" doubles as the HardwareModel name (binary mode) and the
# unbounded default machine (mixed mode), preserving pre-capacity specs.
REGISTRIES: Dict[str, Callable[[], Registry]] = {
    "quadro-p4000": default_registry,
    "p4000-constrained": constrained_registry,
    "tpu-v5e-host": tpu_host_registry,
}

# the modeled machines above are frozen; register_registry refuses to
# shadow them (calibrations land under their own entry names)
_BUILTIN_REGISTRIES = frozenset(REGISTRIES)


def get_registry(name: str) -> Registry:
    if name not in REGISTRIES:
        raise ValueError(
            f"unknown machine registry {name!r}; have {sorted(REGISTRIES)}"
        )
    return REGISTRIES[name]()


def register_registry(name: str, factory: Callable[[], Registry],
                      replace: bool = False) -> None:
    """Add a machine registry at runtime — the plumbing calibrated
    machines (``repro.offload calibrate``) use to become selectable by
    name via ``OffloadSpec.hw``. The three built-in machines cannot be
    replaced: a calibration lands under its own entry name, and every
    constant is fingerprinted anyway, so replacing a built-in could only
    ever silently shadow the modeled machine."""
    if name in _BUILTIN_REGISTRIES:
        raise ValueError(f"cannot replace built-in registry {name!r}")
    if name in REGISTRIES and not replace:
        raise ValueError(
            f"registry {name!r} already registered; pass replace=True "
            "to re-register (e.g. after a re-calibration)"
        )
    REGISTRIES[name] = factory


def calibrated_registry(base: Registry, hw: HardwareModel,
                        name: str) -> Registry:
    """``base`` with its host and GPU/TPU-kind destinations rebuilt from
    the *measured* constants of a calibrated ``HardwareModel``.

    Per-destination memory capacities and every destination the
    calibration could not observe (FPGA-kind: this container has no HLS
    flow to time — a real one would contribute its own probe set) are
    carried over from the base unchanged, so a calibrated
    ``p4000-constrained`` stays capacity-constrained. Links that touch a
    calibrated device take the fitted ``link_bw``/``link_latency``;
    uncalibrated links keep the base constants. ``Registry.fingerprint``
    digests all of it, so a re-calibration under the same entry name
    still invalidates caches (by design)."""
    factories = {"gpu": gpu_destination, "tpu": tpu_destination}
    dests = []
    calibrated_names = set()
    for d in base.destinations:
        if d.kind == "host":
            dests.append(host_destination(hw, name=d.name))
            calibrated_names.add(d.name)
        elif d.kind in factories:
            dests.append(factories[d.kind](
                hw, name=d.name, memory_bytes=d.memory_bytes
            ))
            calibrated_names.add(d.name)
        else:
            dests.append(d)  # e.g. FPGA: stays at the modeled constants
    cal_link = Link(bw=hw.link_bw, latency=hw.link_latency)
    links = tuple(
        (a, b, cal_link)
        if (a in calibrated_names and b in calibrated_names) else (a, b, l)
        for a, b, l in base.links
    )
    return Registry(name=name, destinations=tuple(dests), links=links)
