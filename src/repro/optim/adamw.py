"""Sharded optimizers: AdamW and Adafactor (factored, for 100B+ params).

Functional optax-style API, but with a ``state_specs`` method so optimizer
state inherits the parameter PartitionSpecs (ZeRO: states sharded like
params). No optax dependency — everything is built here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]  # (grads, state, params) -> (new_params, new_state)
    state_specs: Callable[[Any], Any]  # param_specs -> state_specs


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params
    )


def adamw(
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
    mu_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        return {
            "mu": _tree_zeros_like(params, mu_dtype),
            "nu": _tree_zeros_like(params, jnp.float32),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)
        # global-norm clip
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32) * scale
            mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
            nu_n = b2 * nu + (1 - b2) * jnp.square(g)
            mu_hat = mu_n / (1 - b1**count.astype(jnp.float32))
            nu_hat = nu_n / (1 - b2**count.astype(jnp.float32))
            step = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr_t * step).astype(p.dtype),
                mu_n.astype(mu_dtype),
                nu_n,
            )

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda v: isinstance(v, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda v: isinstance(v, tuple))
        new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda v: isinstance(v, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu, "count": count}

    def state_specs(param_specs):
        return {
            "mu": param_specs,
            "nu": param_specs,
            "count": P(),
        }

    return Optimizer(init, update, state_specs)


def adafactor(
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3,
    decay: float = 0.99,
    eps: float = 1e-30,
    weight_decay: float = 0.0,
    grad_clip: float = 1.0,
    mu_dtype=jnp.bfloat16,
) -> Optimizer:
    """Factored second moment over the last two dims; bf16 first moment.
    ~2.x bytes/param of optimizer state instead of AdamW's 8."""

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def vr(p):
            return (
                jnp.zeros(p.shape[:-1], jnp.float32)
                if _factored(p)
                else jnp.zeros(p.shape, jnp.float32)
            )

        def vc(p):
            return (
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p)
                else jnp.zeros((1,), jnp.float32)
            )

        return {
            "mu": _tree_zeros_like(params, mu_dtype),
            "vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

        def upd(g, mu, vr, vc, p):
            g = g.astype(jnp.float32) * scale
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr_n = decay * vr + (1 - decay) * g2.mean(axis=-1)
                vc_n = decay * vc + (1 - decay) * g2.mean(axis=-2)
                denom = jnp.sqrt(
                    vr_n[..., None] * vc_n[..., None, :]
                    / jnp.maximum(vr_n.mean(axis=-1, keepdims=True)[..., None], eps)
                )
            else:
                vr_n = decay * vr + (1 - decay) * g2
                vc_n = vc
                denom = jnp.sqrt(vr_n)
            u = g / jnp.maximum(denom, 1e-12)
            mu_n = 0.9 * mu.astype(jnp.float32) + 0.1 * u
            step = mu_n + weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr_t * step).astype(p.dtype),
                mu_n.astype(mu_dtype),
                vr_n,
                vc_n,
            )

        out = jax.tree.map(upd, grads, state["mu"], state["vr"], state["vc"], params)
        pick = lambda i: jax.tree.map(
            lambda o: o[i], out, is_leaf=lambda v: isinstance(v, tuple)
        )
        return pick(0), {
            "mu": pick(1), "vr": pick(2), "vc": pick(3), "count": count,
        }

    def state_specs(param_specs):
        def vr_spec(s):
            ent = tuple(s)
            return P(*ent[:-1]) if len(ent) >= 2 else s

        def vc_spec(s):
            ent = tuple(s)
            return P(*(ent[:-2] + ent[-1:])) if len(ent) >= 2 else P(None)

        return {
            "mu": param_specs,
            "vr": jax.tree.map(vr_spec, param_specs),
            "vc": jax.tree.map(vc_spec, param_specs),
            "count": P(),
        }

    return Optimizer(init, update, state_specs)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(c < warmup, warm, cos)

    return lr
