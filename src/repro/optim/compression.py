"""Gradient compression: int8 quantization with error feedback.

Two pieces:
- ``ef_compress_tree`` / error-feedback state: numerics-faithful int8
  round-trip applied to gradients before the optimizer, with the residual
  carried to the next step (Seide et al. 1-bit SGD generalization). This is
  what training uses; on a real multi-host network the quantized tensor is
  what crosses DCN.
- ``compressed_psum_mean``: an explicit shard_map demonstration of the
  4x-bytes-cheaper collective (int8 all-gather + local dequant-mean instead
  of f32 all-reduce); used by the transfer-ablation benchmark to show the
  HLO byte reduction.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantize(x: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree(grads, ef_state):
    """Returns (compressed-dequantized grads, new error-feedback state)."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = _quantize(x)
        deq = _dequantize(q, s)
        return deq, x - deq

    out = jax.tree.map(one, grads, ef_state)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda v: isinstance(v, tuple))
    new_ef = jax.tree.map(lambda o: o[1], out, is_leaf=lambda v: isinstance(v, tuple))
    return deq, new_ef


def compressed_psum_mean(x: jnp.ndarray, mesh, axis: str = "data"):
    """Mean over a mesh axis moving int8 instead of f32 (4x byte cut).

    shard_map over `axis`: quantize locally, all_gather int8 + scales,
    dequantize and average locally.
    """

    def body(xs):
        q, s = _quantize(xs)
        qs = jax.lax.all_gather(q, axis)  # int8 — the cheap collective
        ss = jax.lax.all_gather(s, axis)
        return jnp.mean(
            qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * xs.ndim), axis=0
        )

    rest = P(*([None] * x.ndim))
    return jax.shard_map(
        body, mesh=mesh, in_specs=rest, out_specs=rest, check_vma=False
    )(x)
