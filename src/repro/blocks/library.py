"""Kernel library registry: tuned accelerator implementations as
substitution targets for whole loop groups (function blocks).

The source paper places individual loop statements; its lineage's next
step (PAPERS.md: arXiv:2004.09883, evaluated in arXiv:2005.04174) is to
recognize whole *function blocks* and substitute a tuned library
implementation instead. This module is the library side of that step:

- :class:`KernelEntry` names a real implementation in
  ``repro.kernels.ops``, its reference oracle in ``repro.kernels.ref``,
  the destination kinds it can run on, and a structural
  :class:`BlockSignature` a loop chain must match.
- :class:`KernelLibrary` is an ordered, fingerprinted collection of
  entries. The fingerprint covers every field an evaluator prices from
  (signatures, destination kinds, gains), so block-enabled fitness-cache
  entries are keyed on the exact library that produced them.
- :func:`oracle_check` runs an entry's implementation (Pallas kernel
  body via ``interpret=True``) against its ``ref.py`` oracle on a tiny
  seeded input — the verify stage calls this for every substitution the
  search placed in a winner, the same way PCAST validates loop
  placements.

Signatures are derived from the same per-loop fields that
``LoopProgram.fingerprint()`` digests: :func:`loop_atom` renders the
(klass, sequential_carry) pair of one loop exactly as the fingerprint
does, and an entry matches a maximal run of consecutive dataflow-chained
loops whose atoms all equal the entry's (see ``repro.blocks.match``).

Calibration hook: ``fidelity="calibrated"`` fits a per-kernel *gain*
(speedup of the library implementation over the fused-roofline estimate)
from kernel probes (``repro.offload.calibrate``); ``install()``
registers those constants here under the calibration's hardware name so
``default_library(hw=...)`` prices with them. The modeled fallback is
gain 1.0 — the kernel is priced as a perfectly fused TIGHT nest.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, Optional, Tuple

from repro.core.loopir import Loop, LoopClass


def loop_atom(loop: Loop) -> str:
    """One loop's structural atom, rendered from the same fields (and in
    the same ``{klass.value}:{int(sequential_carry)}`` form) that
    ``LoopProgram.fingerprint()`` digests per loop."""
    return f"{loop.klass.value}:{int(loop.sequential_carry)}"


@dataclasses.dataclass(frozen=True)
class BlockSignature:
    """Structural shape a loop chain must have to match an entry: every
    loop in the chain carries ``atom``, and the chain spans at least
    ``min_len`` consecutive dataflow-linked loops."""

    atom: str
    min_len: int = 2

    def __post_init__(self):
        assert self.min_len >= 1, self.min_len


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One library kernel: implementation + oracle + match signature."""

    name: str
    impl: str  # callable name in repro.kernels.ops
    oracle: str  # reference callable name in repro.kernels.ref
    signature: BlockSignature
    dest_kinds: Tuple[str, ...]  # destination kinds that can host it
    # Speedup of the library implementation over the fused-roofline
    # estimate (sum of covered flops at the destination's TIGHT rate).
    # 1.0 = modeled fallback; calibration fits a per-hw constant.
    gain: float = 1.0
    description: str = ""

    def __post_init__(self):
        assert self.gain > 0, self.gain

    def eligible(self, dest) -> bool:
        """Can ``dest`` host this kernel? Kind must be listed and the
        destination must accept a TIGHT nest (the fused kernel's class)."""
        return dest.kind in self.dest_kinds and dest.accepts(LoopClass.TIGHT)


class KernelLibrary:
    """Ordered, fingerprinted kernel collection (order = match priority)."""

    def __init__(self, entries: Tuple[KernelEntry, ...]):
        names = [e.name for e in entries]
        assert len(set(names)) == len(names), "duplicate entry names"
        self.entries: Tuple[KernelEntry, ...] = tuple(entries)

    def get(self, name: str) -> KernelEntry:
        for e in self.entries:
            if e.name == name:
                return e
        raise KeyError(name)

    def fingerprint(self) -> str:
        """Digest of every field the evaluator prices from. Two libraries
        with different gains (e.g. modeled vs calibrated) never share
        block-enabled fitness-cache entries."""
        parts = [
            f"{e.name}:{e.impl}:{e.oracle}:{e.signature.atom}"
            f":{e.signature.min_len}:{','.join(e.dest_kinds)}:{e.gain:.6g}"
            for e in self.entries
        ]
        digest = hashlib.sha256("|".join(parts).encode()).hexdigest()[:12]
        return f"kernlib-{digest}"


# -- per-hardware calibrated gains ------------------------------------------

# hw name (e.g. a calibration's digest-named registry) -> {kernel: gain}.
# Populated by repro.offload.calibrate.install(); read by default_library.
_HW_GAINS: Dict[str, Dict[str, float]] = {}


def register_kernel_gains(hw: str, gains: Dict[str, float]) -> None:
    """Install calibrated per-kernel gains under a hardware name."""
    _HW_GAINS[hw] = {k: float(v) for k, v in gains.items()}


def kernel_gains(hw: Optional[str]) -> Dict[str, float]:
    return dict(_HW_GAINS.get(hw, {})) if hw else {}


# -- the default library ----------------------------------------------------

_ENTRIES = (
    KernelEntry(
        name="flash_attention",
        impl="flash_attention",
        oracle="attention_ref",
        # a chain of tightly-nested carry-free stencil/attention-shaped
        # nests: each stage reads the previous stage's output
        signature=BlockSignature(atom="tight:0", min_len=2),
        dest_kinds=("gpu", "tpu"),
        description="fused attention-style pipeline (Pallas flash kernel)",
    ),
    KernelEntry(
        name="ssd_scan",
        impl="ssd_scan",
        oracle="ssd_ref",
        # a chain of vectorizable-only loops with sequential carries:
        # the chunked SSD scan fuses the whole recurrence
        signature=BlockSignature(atom="vector_only:1", min_len=2),
        dest_kinds=("gpu", "tpu", "fpga"),
        description="fused sequential-scan chain (Pallas chunked SSD)",
    ),
)


def default_library(hw: Optional[str] = None) -> KernelLibrary:
    """The stock library, with any calibrated gains for ``hw`` applied."""
    gains = kernel_gains(hw)
    entries = tuple(
        dataclasses.replace(e, gain=gains[e.name]) if e.name in gains else e
        for e in _ENTRIES
    )
    return KernelLibrary(entries)


# -- oracle checks ----------------------------------------------------------

# Tiny seeded shapes: the verify stage runs these on every block-enabled
# run (CI smoke included), so they must stay interpret-mode-on-CPU cheap.
_ORACLE_TOL = {"rtol": 2e-5, "atol": 2e-5}


def _attention_case(seed: int):
    import numpy as np

    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    B, S, H, D = 1, 64, 2, 32
    q = rng.standard_normal((B, S, H, D)).astype("float32")
    k = rng.standard_normal((B, S, H, D)).astype("float32")
    v = rng.standard_normal((B, S, H, D)).astype("float32")
    impl = lambda: ops.flash_attention(  # noqa: E731
        q, k, v, causal=True, impl="pallas", interpret=True
    )
    oracle = lambda: ref.attention_ref(q, k, v, causal=True)  # noqa: E731
    return impl, oracle, f"q{q.shape}"


def _ssd_case(seed: int):
    import numpy as np

    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    B, S, H, P, N, chunk = 1, 64, 2, 8, 8, 16
    x = rng.standard_normal((B, S, H, P)).astype("float32")
    dt = np.log1p(np.exp(rng.standard_normal((B, S, H)))).astype("float32")
    A = (-np.exp(rng.standard_normal(H))).astype("float32")
    Bm = rng.standard_normal((B, S, N)).astype("float32")
    Cm = rng.standard_normal((B, S, N)).astype("float32")
    impl = lambda: ops.ssd_scan(  # noqa: E731
        x, dt, A, Bm, Cm, chunk=chunk, impl="pallas", interpret=True
    )
    oracle = lambda: ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)  # noqa: E731
    return impl, oracle, f"x{x.shape}"


# entry name -> seeded case builder: (run_impl, run_oracle, shape label)
_ORACLE_HARNESSES: Dict[str, Callable] = {
    "flash_attention": _attention_case,
    "ssd_scan": _ssd_case,
}


def oracle_check(entry: KernelEntry, seed: int = 0) -> Dict[str, object]:
    """Run ``entry``'s implementation (real kernel body, interpret mode)
    against its reference oracle on a tiny seeded input. Returns a
    JSON-able verdict row for the verify stage's ``block_oracles``."""
    import numpy as np

    impl, oracle, shape = _ORACLE_HARNESSES[entry.name](seed)
    got = np.asarray(impl())
    want = np.asarray(oracle())
    err = float(np.max(np.abs(got - want))) if got.size else 0.0
    tol = _ORACLE_TOL["atol"] + _ORACLE_TOL["rtol"] * float(
        np.max(np.abs(want))
    )
    return {
        "kernel": entry.name,
        "impl": f"ops.{entry.impl}",
        "oracle": f"ref.{entry.oracle}",
        "shape": shape,
        "max_abs_err": err,
        "tol": tol,
        "ok": bool(err <= tol),
    }


def time_kernel(
    entry: KernelEntry, repeats: int = 1, seed: int = 0
) -> Tuple[float, float]:
    """(oracle seconds, implementation seconds) at the oracle-check
    shape: min over ``repeats`` timed runs after one warm-up each. The
    calibration's kernel probes fit per-kernel gains from the ratio."""
    import time

    import numpy as np

    impl, oracle, _ = _ORACLE_HARNESSES[entry.name](seed)

    def best(fn) -> float:
        np.asarray(fn())  # warm-up (traces/compiles)
        t = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            np.asarray(fn())  # block until the value is materialized
            t = min(t, time.perf_counter() - t0)
        return t

    return best(oracle), best(impl)
