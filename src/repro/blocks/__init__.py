"""Function-block offloading (PAPERS.md: arXiv:2004.09883 / 2005.04174):
match whole loop chains against a library of tuned kernels
(``repro.kernels``) and let the genome substitute the library
implementation instead of placing the loops individually.

- ``library``    — :class:`KernelLibrary` of :class:`KernelEntry` rows
  (implementation + ``ref.py`` oracle + structural signature +
  calibratable gain) and the oracle-check harness.
- ``match``      — deterministic, non-overlapping matching of maximal
  dataflow-chained loop runs against library signatures.
- ``substitute`` — :class:`BlockMixedEvaluator`: the per-block genome
  dimension, priced through a fused-nest variant program.

Enabled per run via ``OffloadSpec.blocks`` (mixed mode only; off =
byte-identical to the loop-level search). See docs/blocks.md.
"""
from repro.blocks.library import (  # noqa: F401
    BlockSignature,
    KernelEntry,
    KernelLibrary,
    default_library,
    kernel_gains,
    loop_atom,
    oracle_check,
    register_kernel_gains,
    time_kernel,
)
from repro.blocks.match import BlockMatch, match_blocks  # noqa: F401
from repro.blocks.substitute import (  # noqa: F401
    BatchBlockMixedEvaluator,
    BlockMixedEvaluator,
    fused_loop,
    internal_vars,
    substituted_program,
)
