"""Block substitution: the genome dimension that swaps a matched loop
chain for a library kernel, and its pricing.

:class:`BlockMixedEvaluator` wraps a :class:`~repro.destinations.mixed.
MixedEvaluator` and extends the genome with one gene per
:class:`~repro.blocks.match.BlockMatch`:

    genes = (loop gene per offloadable loop) + (block gene per match)

A block gene of 0 keeps the status quo — every covered loop is placed
individually by its own loop gene. A block gene of v >= 1 substitutes
the library kernel for the whole chain on ``destinations[v]`` (clamped
back to 0 when that destination cannot host the kernel), making the
covered loops' own genes irrelevant. Block genes share the loop genes'
alphabet, so the GA's k-ary operators and the warm-start
``reexpress`` mapping apply to the whole genome unchanged.

Pricing builds a *substituted program*: the chain collapses into one
synthetic TIGHT, carry-free nest whose flops are the chain's total
divided by the entry's (calibratable) gain, and whose read/write sets
drop the chain's internal temporaries — so a substitution wins exactly
where a fused kernel wins on real hardware: one launch instead of N,
intermediate traffic eliminated, and (for sequential-carry chains) MXU
rates instead of the lane-bound sequential rate. The substituted
program is priced by a plain ``MixedEvaluator`` over the same registry,
so transfer/residency/capacity accounting is identical to loop-level
placement.

Cache soundness: ``fingerprint()`` prefixes the base evaluator's with
``blocks:`` and appends the library fingerprint, and ``cache_key()``
canonicalizes covered loops to the substituting destination and appends
a ``|blocks=`` rendering of every block decision — block-enabled
searches never share fitness-cache entries with loop-level ones, and two
genomes that differ only in a dead (inactive-block) covered-loop gene
share one entry.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.loopir import Loop, LoopClass, LoopProgram
from repro.destinations.batch import BatchMixedEvaluator
from repro.destinations.mixed import MixedEvaluator
from repro.destinations.profiles import Registry
from repro.blocks.library import KernelEntry, KernelLibrary, default_library
from repro.blocks.match import BlockMatch, match_blocks

Genes = Tuple[int, ...]


def internal_vars(prog: LoopProgram, match: BlockMatch) -> frozenset:
    """Chain-internal temporaries: written inside the chain and touched
    by no loop outside it. A fused kernel keeps these in registers/VMEM,
    so the substituted nest drops them from its read/write sets (and the
    residency schedule stops moving them)."""
    chain = set(match.loops)
    writes = set()
    for l in prog.loops:
        if l.name in chain:
            writes |= l.writes
    out = set()
    for v in writes:
        touchers = {l.name for l in prog.loops if v in l.touched()}
        if touchers <= chain:
            out.add(v)
    return frozenset(out)


def fused_loop(
    prog: LoopProgram, match: BlockMatch, entry: KernelEntry
) -> Loop:
    """The synthetic nest a substitution is priced as: one TIGHT,
    carry-free launch covering the chain's arithmetic (divided by the
    entry's gain), reading the chain's external inputs and writing its
    external outputs."""
    by_name = {l.name: l for l in prog.loops}
    chain = [by_name[n] for n in match.loops]
    internal = internal_vars(prog, match)
    reads = frozenset().union(*(l.reads for l in chain)) - internal
    writes = frozenset().union(*(l.writes for l in chain)) - internal
    flops = sum(l.total_flops for l in chain) / entry.gain
    return Loop(
        name=f"block:{entry.name}:{chain[0].name}",
        klass=LoopClass.TIGHT,
        trip=1,
        inner_trip=1,
        flops_per_iter=flops,
        reads=reads,
        writes=writes,
        file=chain[0].file,
        parent_seq=chain[0].parent_seq,
        sequential_carry=False,
    )


def substituted_program(
    prog: LoopProgram,
    active: Sequence[Tuple[BlockMatch, KernelEntry]],
) -> LoopProgram:
    """``prog`` with each active chain collapsed into its fused nest (at
    the chain's first loop's position; the rest of the chain dropped)."""
    first_of = {m.loops[0]: (m, e) for m, e in active}
    covered_rest = {n for m, _ in active for n in m.loops[1:]}
    loops: List[Loop] = []
    for l in prog.loops:
        if l.name in first_of:
            loops.append(fused_loop(prog, *first_of[l.name]))
        elif l.name not in covered_rest:
            loops.append(l)
    return LoopProgram(
        name=prog.name,
        loops=tuple(loops),
        vars=prog.vars,
        seq_regions=prog.seq_regions,
        description=prog.description,
    )


class BlockMixedEvaluator:
    """Mixed-destination evaluator with per-block substitution genes.

    Drop-in for :class:`MixedEvaluator` where the genome is ``n + m``
    genes (n offloadable loops, m matched blocks) over the same
    ``k = len(destinations)`` alphabet. With zero matches the caller
    should use a plain ``MixedEvaluator`` instead (the adapter does) —
    this class assumes ``matches`` is non-empty only for clarity of the
    cache-key contract, and degrades gracefully either way.
    """

    def __init__(
        self,
        prog: LoopProgram,
        destinations: Sequence[str] = ("cpu", "gpu", "fpga"),
        registry: Optional[Registry] = None,
        library: Optional[KernelLibrary] = None,
        matches: Optional[Tuple[BlockMatch, ...]] = None,
    ):
        self.base = MixedEvaluator(prog, destinations, registry=registry)
        self.prog = prog
        self.registry = self.base.registry
        self.dests = self.base.dests
        self.library = library if library is not None else default_library()
        self.matches: Tuple[BlockMatch, ...] = (
            matches if matches is not None
            else match_blocks(prog, self.library)
        )
        self._entries = tuple(
            self.library.get(m.entry) for m in self.matches
        )
        # loop name -> (block index, is chain head) for covered loops
        self._covered: Dict[str, Tuple[int, bool]] = {}
        for bi, m in enumerate(self.matches):
            for li, name in enumerate(m.loops):
                self._covered[name] = (bi, li == 0)
        self._n = prog.gene_length
        # substitution combo (sorted (block, dest) pairs) -> variant evaluator
        self._variants: Dict[Tuple[Tuple[int, int], ...], MixedEvaluator] = {}

    # -- genome layout ------------------------------------------------------

    @property
    def k(self) -> int:
        return self.base.k

    @property
    def gene_length(self) -> int:
        return self._n + len(self.matches)

    def allele_names(self) -> Tuple[str, ...]:
        return self.base.allele_names()

    def split(self, genes: Sequence[int]) -> Tuple[Genes, Genes]:
        assert len(genes) == self.gene_length, \
            (len(genes), self.gene_length)
        return (
            tuple(int(g) for g in genes[: self._n]),
            tuple(int(g) for g in genes[self._n:]),
        )

    # -- admissibility ------------------------------------------------------

    def _clamp_blocks(self, block_genes: Sequence[int]) -> Genes:
        """A block gene falls back to 0 (no substitution) when the chosen
        destination cannot host the kernel — the block analogue of the
        loop-gene host fallback."""
        out = []
        for g, entry in zip(block_genes, self._entries):
            g = int(g)
            assert 0 <= g < self.k, (g, self.k)
            out.append(g if g and entry.eligible(self.dests[g]) else 0)
        return tuple(out)

    def admissible(self, genes: Sequence[int]) -> Genes:
        loop_genes, block_genes = self.split(genes)
        return self.base.admissible(loop_genes) + \
            self._clamp_blocks(block_genes)

    def _active(
        self, block_genes: Genes
    ) -> Tuple[Tuple[int, int], ...]:
        """Sorted (block index, destination index) pairs of the
        substitutions this genome activates."""
        return tuple(
            (bi, g) for bi, g in enumerate(block_genes) if g
        )

    # -- substitution -> variant program ------------------------------------

    def _variant(self, active: Tuple[Tuple[int, int], ...]) -> MixedEvaluator:
        key = active
        ev = self._variants.get(key)
        if ev is None:
            pairs = [
                (self.matches[bi], self._entries[bi]) for bi, _ in active
            ]
            vprog = substituted_program(self.prog, pairs)
            ev = MixedEvaluator(
                vprog,
                tuple(d.name for d in self.dests),
                registry=self.registry,
            )
            self._variants[key] = ev
        return ev

    def _variant_genes(
        self, loop_genes: Genes, active: Tuple[Tuple[int, int], ...]
    ) -> Genes:
        """Genes for the variant program: uncovered loops keep their
        gene; each fused nest takes its block's destination."""
        dest_of = dict(active)
        active_blocks = set(dest_of)
        out = []
        gi = 0
        for l in self.prog.offloadable_loops:
            g = loop_genes[gi]
            gi += 1
            cov = self._covered.get(l.name)
            if cov is not None and cov[0] in active_blocks:
                if cov[1]:  # chain head -> the fused nest's gene
                    out.append(dest_of[cov[0]])
                # covered non-head loops vanish from the variant
            else:
                out.append(g)
        return tuple(out)

    # -- scoring ------------------------------------------------------------

    def breakdown(self, genes: Sequence[int]):
        loop_genes, block_genes = self.split(genes)
        active = self._active(self._clamp_blocks(block_genes))
        if not active:
            return self.base.breakdown(loop_genes)
        ev = self._variant(active)
        return ev.breakdown(self._variant_genes(loop_genes, active))

    def __call__(self, genes: Sequence[int]) -> float:
        return self.breakdown(genes).total_s

    def host_only_time(self) -> float:
        return self.base.host_only_time()

    # -- placement / reporting ----------------------------------------------

    def placement(self, genes: Sequence[int]) -> Dict[str, str]:
        """{loop name: destination name} for ALL ORIGINAL loops: loops
        covered by an active substitution run on the block's
        destination (inside the library kernel)."""
        loop_genes, block_genes = self.split(genes)
        out = self.base.placement(loop_genes)
        for bi, g in self._active(self._clamp_blocks(block_genes)):
            for name in self.matches[bi].loops:
                out[name] = self.dests[g].name
        return out

    def substitutions(self, genes: Sequence[int]) -> List[Dict]:
        """One row per matched block: the genome's decision for it."""
        _, block_genes = self.split(genes)
        rows = []
        for m, g in zip(self.matches, self._clamp_blocks(block_genes)):
            rows.append({
                "entry": m.entry,
                "loops": list(m.loops),
                "destination": self.dests[g].name if g else None,
                "active": bool(g),
            })
        return rows

    # -- caching ------------------------------------------------------------

    def cache_key(self, genes: Sequence[int]) -> str:
        """Loop-level part: one destination name per ORIGINAL offloadable
        loop, with loops covered by an active substitution canonicalized
        to the substituting destination (their own genes are dead). Block
        part: every block decision, rendered even when inactive, so the
        key never aliases a different decision vector."""
        loop_genes, block_genes = self.split(genes)
        clamped_loops = self.base.admissible(loop_genes)
        clamped_blocks = self._clamp_blocks(block_genes)
        block_dest: Dict[str, str] = {}
        for bi, g in self._active(clamped_blocks):
            for name in self.matches[bi].loops:
                block_dest[name] = self.dests[g].name
        names = [
            block_dest.get(l.name, self.dests[g].name)
            for g, l in zip(clamped_loops, self.prog.offloadable_loops)
        ]
        blocks = ",".join(
            f"{m.entry}@{self.dests[g].name if g else '-'}"
            for m, g in zip(self.matches, clamped_blocks)
        )
        return ",".join(names) + "|blocks=" + blocks

    def fingerprint(self) -> str:
        """Base machine identity + library identity under a ``blocks:``
        prefix: block-enabled searches never share cache entries with
        loop-level searches, and a library change (entry set, gains)
        invalidates block-enabled entries."""
        return f"blocks:{self.base.fingerprint()}:{self.library.fingerprint()}"


class BatchBlockMixedEvaluator(BlockMixedEvaluator):
    """:class:`BlockMixedEvaluator` + a vectorized ``evaluate_batch``.

    A population partitions by its active-substitution combo (the same
    key the scalar variant memoization uses); each partition prices as
    one :class:`~repro.destinations.batch.BatchMixedEvaluator` pass over
    the combo's variant program. Scalar ``__call__`` (the oracle),
    ``cache_key`` and ``fingerprint`` are inherited unchanged, so the
    knob shares caches with — and is parity-tested against — the scalar
    block evaluator.
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._batch_variants: Dict[
            Tuple[Tuple[int, int], ...], BatchMixedEvaluator
        ] = {}

    def _batch_variant(
        self, active: Tuple[Tuple[int, int], ...]
    ) -> BatchMixedEvaluator:
        ev = self._batch_variants.get(active)
        if ev is None:
            if active:
                pairs = [
                    (self.matches[bi], self._entries[bi])
                    for bi, _ in active
                ]
                vprog = substituted_program(self.prog, pairs)
            else:
                vprog = self.prog
            ev = BatchMixedEvaluator(
                vprog,
                tuple(d.name for d in self.dests),
                registry=self.registry,
            )
            self._batch_variants[active] = ev
        return ev

    def evaluate_batch(
        self, genomes: Sequence[Sequence[int]]
    ) -> List[float]:
        out = [0.0] * len(genomes)
        groups: Dict[
            Tuple[Tuple[int, int], ...], List[Tuple[int, Genes]]
        ] = {}
        for i, genes in enumerate(genomes):
            loop_genes, block_genes = self.split(genes)
            active = self._active(self._clamp_blocks(block_genes))
            vg = self._variant_genes(loop_genes, active) if active \
                else loop_genes
            groups.setdefault(active, []).append((i, vg))
        for active, members in groups.items():
            ts = self._batch_variant(active).evaluate_batch(
                [vg for _, vg in members]
            )
            for (i, _), t in zip(members, ts):
                out[i] = float(t)
        return out
