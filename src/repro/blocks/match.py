"""Block matching: find maximal loop groups shaped like a library kernel.

A :class:`BlockMatch` is a candidate *function block*: a maximal run of
consecutive offloadable loops (in program order) that

- all carry the entry's structural atom (:func:`repro.blocks.library.loop_atom`,
  the same (klass, sequential_carry) rendering ``LoopProgram.fingerprint()``
  digests),
- share one enclosing sequential region (``parent_seq``), and
- form a dataflow chain: each loop reads something the previous loop
  wrote — the shape of a fusable pipeline stage.

Matching is deterministic and greedy in program order, entries in
library order; a loop consumed by one match never joins another, so
matches are non-overlapping by construction. The matcher only proposes
candidates — whether a block is *substituted*, and on which destination,
is a genome decision (``repro.blocks.substitute``), priced like any
other placement and validated by the kernel's oracle in the verify
stage.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.loopir import LoopProgram
from repro.blocks.library import KernelLibrary, loop_atom


@dataclasses.dataclass(frozen=True)
class BlockMatch:
    """One matched candidate region (loops in program order)."""

    entry: str  # library entry name
    loops: Tuple[str, ...]  # covered loop names
    parent_seq: Optional[str]
    atom: str

    def describe(self) -> str:
        return f"[{self.entry}] {'+'.join(self.loops)}"


def match_blocks(
    prog: LoopProgram, library: KernelLibrary
) -> Tuple[BlockMatch, ...]:
    """All non-overlapping maximal matches of ``library`` in ``prog``,
    ordered by (library entry order, program order)."""
    consumed: set = set()  # loop indices already covered
    matches = []
    loops = prog.loops
    for entry in library.entries:
        atom = entry.signature.atom
        i = 0
        while i < len(loops):
            first = loops[i]
            if (
                i in consumed
                or not first.offloadable
                or loop_atom(first) != atom
            ):
                i += 1
                continue
            run = [i]
            j = i + 1
            while j < len(loops):
                nxt = loops[j]
                if (
                    j in consumed
                    or not nxt.offloadable
                    or loop_atom(nxt) != atom
                    or nxt.parent_seq != first.parent_seq
                    or not (nxt.reads & loops[j - 1].writes)
                ):
                    break
                run.append(j)
                j += 1
            if len(run) >= entry.signature.min_len:
                matches.append(
                    BlockMatch(
                        entry=entry.name,
                        loops=tuple(loops[x].name for x in run),
                        parent_seq=first.parent_seq,
                        atom=atom,
                    )
                )
                consumed.update(run)
                i = j
            else:
                i += 1
    return tuple(matches)
