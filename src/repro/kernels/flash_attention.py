"""Flash attention Pallas TPU kernel (online softmax, GQA, masks, softcap).

TPU-native design notes (HW adaptation of the paper's `kernels` directive):
- grid = (batch, q_heads, Sq/block_q, Sk/block_k); the LAST grid dim is
  sequential on TPU, so the online-softmax running stats (m, l, acc) live in
  VMEM scratch and are carried across k-blocks.
- BlockSpec tiles: q (1, 1, block_q, D), k/v (1, 1, block_k, D) — D is padded
  to a lane multiple (128) by ``ops.flash_attention``; block_q/block_k default
  to 512 so q,k,v tiles + f32 acc fit comfortably in ~16 MB VMEM while keeping
  MXU dims at 128 multiples (512x128 tiles, 512x512 score blocks).
- GQA is expressed in the k/v index_map (q-head h reads kv-head h // group) —
  no KV replication in HBM.
- causal/local masking uses block-level iota compares; fully masked blocks
  still run (TPU grids are static), the mask makes them no-ops.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, block_q, D)
    k_ref,  # (1, 1, block_k, D)
    v_ref,  # (1, 1, block_k, D)
    o_ref,  # (1, 1, block_q, D)
    m_scr,  # (block_q, 128) f32
    l_scr,  # (block_q, 128) f32
    acc_scr,  # (block_q, D) f32
    *,
    scale: float,
    causal: bool,
    local_window: int,
    logit_softcap: float,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_q, block_k)

    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < kv_len  # KV padding mask
    if causal:
        ok &= q_pos >= k_pos
    if local_window > 0:
        ok &= (q_pos - k_pos) < local_window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_cur = l_scr[:, 0] * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p,
        v_ref[0, 0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[:, 0] = m_cur
    l_scr[:, 0] = l_cur

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, 0], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, H, Sq, D) — D must be a 128 multiple (ops pads)
    k: jnp.ndarray,  # (B, K, Sk, D)
    v: jnp.ndarray,  # (B, K, Sk, D)
    *,
    causal: bool = True,
    local_window: int = 0,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    kv_len: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    _, K, Sk, _ = k.shape
    assert H % K == 0
    group = H // K
    scale = (1.0 / D**0.5) if scale is None else scale
    kv_len = Sk if kv_len is None else kv_len
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        local_window=local_window,
        logit_softcap=logit_softcap,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
