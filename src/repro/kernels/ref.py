"""Pure-jnp reference oracles for every Pallas kernel.

``attention_ref`` / ``ssd_ref`` are the ground truth used by the kernel
allclose tests. ``attention_chunked`` is a mathematically identical
online-softmax formulation built on ``lax.scan`` — it is the non-TPU dispatch
target of ``ops.flash_attention`` (same FLOPs, no S x S materialization), so
dry-run roofline terms match the kernel path.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _mask_bias(
    q_pos: jnp.ndarray,
    k_pos: jnp.ndarray,
    causal: bool,
    local_window: int,
) -> jnp.ndarray:
    """Additive mask bias (q_len, k_len) from position vectors."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if local_window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < local_window
    return jnp.where(ok, 0.0, NEG_INF)


def attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, K, D)
    v: jnp.ndarray,  # (B, Sk, K, D)
    *,
    causal: bool = True,
    local_window: int = 0,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Naive GQA attention (materializes scores). Oracle only."""
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    assert H % K == 0, (H, K)
    G = H // K
    scale = (1.0 / D**0.5) if scale is None else scale
    qq = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    kk = k.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qq, kk) * scale
    if logit_softcap > 0.0:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    scores = scores + _mask_bias(q_pos, k_pos, causal, local_window)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention_chunked(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, K, D)
    v: jnp.ndarray,  # (B, Sk, K, D)
    *,
    causal: bool = True,
    local_window: int = 0,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    q_offset: int = 0,
    chunk: int = 512,
) -> jnp.ndarray:
    """Online-softmax attention: lax.scan over key chunks, O(S*chunk) memory.

    This is the flash-attention recurrence expressed in pure jnp; it is the
    compile target on non-TPU backends and the shape-agnostic fallback.
    """
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = (1.0 / D**0.5) if scale is None else scale
    if Sk <= chunk:
        return attention_ref(
            q, k, v, causal=causal, local_window=local_window,
            logit_softcap=logit_softcap, scale=scale, q_offset=q_offset,
        )
    n = Sk // chunk
    rem = Sk - n * chunk
    qq = (q.reshape(B, Sq, K, G, D) * scale).astype(jnp.float32)
    q_pos = jnp.arange(Sq) + q_offset

    def step(carry, inputs):
        m, l, acc = carry
        kc, vc, k0 = inputs  # (B, c, K, D), (B, c, K, D), scalar chunk start
        s = jnp.einsum("bqkgd,bskd->bkgqs", qq, kc.astype(jnp.float32))
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        k_pos = k0 + jnp.arange(kc.shape[1])
        ok = jnp.ones((Sq, kc.shape[1]), dtype=bool)
        if causal:
            ok &= q_pos[:, None] >= k_pos[None, :]
        if local_window > 0:
            ok &= (q_pos[:, None] - k_pos[None, :]) < local_window
        s = s + jnp.where(ok, 0.0, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), dtype=jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, D), dtype=jnp.float32)
    ks = k[:, : n * chunk].reshape(B, n, chunk, K, D).swapaxes(0, 1)
    vs = v[:, : n * chunk].reshape(B, n, chunk, K, D).swapaxes(0, 1)
    starts = jnp.arange(n) * chunk
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (ks, vs, starts))
    if rem:
        (m, l, acc), _ = step(
            (m, l, acc), (k[:, n * chunk :], v[:, n * chunk :], n * chunk)
        )
    out = acc / jnp.maximum(l, 1e-37)[..., None]  # (B, K, G, Sq, D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular pairwise sums: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    T = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    out = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_ref(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)      (post-softplus, positive)
    A: jnp.ndarray,  # (H,)            (negative)
    Bm: jnp.ndarray,  # (B, S, N)      (single group)
    Cm: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 64,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    return_state: bool = False,
):
    """Mamba-2 SSD (state-space duality) chunked scan, pure jnp oracle.

    Follows ssd_minimal_discrete from the Mamba-2 paper: intra-chunk
    quadratic term + inter-chunk recurrent state carry.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32
    xb = (x * dt[..., None]).astype(f32)  # dt-weighted input
    dA = (dt * A[None, None, :]).astype(f32)  # (B, S, H) log-decay increments

    # chunked views: (B, nc, cs, ...)
    xc = xb.reshape(B, nc, chunk, H, P)
    dAc = dA.reshape(B, nc, chunk, H)
    Bc = Bm.reshape(B, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(B, nc, chunk, N).astype(f32)

    # 1. intra-chunk (diagonal blocks): Y = (C B^T * L) X
    L = jnp.exp(_segsum(dAc.transpose(0, 1, 3, 2)))  # (B, nc, H, cs, cs)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B, nc, cs, cs)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, L, xc)

    # 2. per-chunk final states: sum_i exp(cum[-1]-cum[i]) * x_i B_i^T
    cum = jnp.cumsum(dAc, axis=2)  # (B, nc, cs, H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B, nc, cs, H)
    chunk_states = jnp.einsum("bcihp,bcih,bcin->bchpn", xc, decay_to_end, Bc)

    # 3. inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B, nc, H)
    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((B, H, P, N), f32)
    )

    def scan_fn(state, inp):
        cs_, cd_ = inp  # (B,H,P,N), (B,H)
        prev = state
        state = state * cd_[..., None, None] + cs_
        return state, prev

    cs_seq = chunk_states.swapaxes(0, 1)  # (nc, B, H, P, N)
    cd_seq = chunk_decay.swapaxes(0, 1)  # (nc, B, H)
    final_state, prev_states = lax.scan(scan_fn, s0, (cs_seq, cd_seq))
    prev_states = prev_states.swapaxes(0, 1)  # (B, nc, H, P, N)

    # 4. inter-chunk output: C_i decayed against incoming state
    state_decay = jnp.exp(cum)  # (B, nc, cs, H)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(B, S, H, P).astype(x.dtype)
    if return_state:
        return y, final_state.astype(f32)
    return y


def ssd_decode_ref(
    x: jnp.ndarray,  # (B, H, P) single token
    dt: jnp.ndarray,  # (B, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, N)
    Cm: jnp.ndarray,  # (B, N)
    state: jnp.ndarray,  # (B, H, P, N)
):
    """Single-token SSD recurrence: state' = e^{dtA} state + dt x B^T."""
    f32 = jnp.float32
    dA = jnp.exp((dt * A[None, :]).astype(f32))  # (B, H)
    upd = jnp.einsum(
        "bhp,bn->bhpn", (x * dt[..., None]).astype(f32), Bm.astype(f32)
    )
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(f32))
    return y.astype(x.dtype), new_state
