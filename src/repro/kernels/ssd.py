"""Mamba-2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

TPU-native design (HW adaptation): the GPU SSD kernel in the Mamba-2 paper
leans on warp-level shuffles for the intra-chunk scan; on TPU we instead
express the intra-chunk term as two MXU matmuls (C B^T masked by the decay
matrix L, then @ X) and carry the inter-chunk recurrent state (P x N, f32) in
VMEM scratch across the sequential chunk grid dimension — the TPU grid's
last-dim sequential guarantee replaces the GPU's inter-block atomics.

grid = (B, H, S/chunk); chunk dim sequential.
BlockSpec tiles per step: x (1, chunk, 1, P), dt (1, chunk, 1),
B/C (1, chunk, N) — with chunk=256, P=64..128, N=64..128 everything
(inputs + L matrix (chunk x chunk f32) + state scratch) is « 1 MB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, chunk, 1, P)  — dt-weighted input block
    dt_ref,  # (1, chunk, 1)
    a_ref,  # (1, 1)            — A value for this head (SMEM)
    b_ref,  # (1, chunk, N)
    c_ref,  # (1, chunk, N)
    y_ref,  # (1, chunk, 1, P)
    state_scr,  # (P, N) f32 VMEM scratch — inter-chunk recurrent state
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (cs, P) — already dt-weighted
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (cs,)
    a = a_ref[0, 0]
    bm = b_ref[0].astype(jnp.float32)  # (cs, N)
    cm = c_ref[0].astype(jnp.float32)  # (cs, N)

    dA = dt * a  # (cs,) log-decay increments (negative)
    cum = jnp.cumsum(dA)  # (cs,)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = cum[:, None] - cum[None, :]
    L = jnp.where(li >= lj, jnp.exp(seg), 0.0)  # (cs, cs)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (cs, cs) = C B^T
    y_intra = jax.lax.dot_general(
        scores * L, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (cs, P)

    # inter-chunk: contribution of carried state
    state_decay = jnp.exp(cum)  # (cs,)
    y_inter = (
        jax.lax.dot_general(
            cm, state_scr[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        * state_decay[:, None]
    )  # (cs, P)

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: state' = e^{sum dA} state + X^T (B * decay_to_end)
    total = cum[chunk - 1]
    decay_to_end = jnp.exp(total - cum)  # (cs,)
    upd = jax.lax.dot_general(
        x, bm * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    state_scr[...] = state_scr[...] * jnp.exp(total) + upd


def ssd_pallas(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    A: jnp.ndarray,  # (H,) negative
    Bm: jnp.ndarray,  # (B, S, N)
    Cm: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xw = (x * dt[..., None]).astype(x.dtype)  # dt-weighted input
    a2d = A.reshape(H, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec(
                (1, 1), lambda b, h, c: (h, 0), memory_space=pltpu.SMEM
            ),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xw, dt, a2d, Bm, Cm)
