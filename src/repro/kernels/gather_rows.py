"""Row-gather Pallas TPU kernel (the MoE dispatch/combine primitive).

``gather_rows(src (N, d), idx (M,)) -> (M, d)`` where ``idx[i] == -1``
yields a zero row. This one primitive implements all four MoE data
movements (each is a permutation-with-drops because capacity slots are
unique):

  dispatch fwd    buf[slot]   = x[src_tok]          gather(x, src_row)
  dispatch bwd    dx[t]       = sum_k dbuf[slot]    gather(dbuf, tok_slots) + sum
  combine  fwd    y[t]        = sum_k g yb[slot]    gather(yb, tok_slots) * g + sum
  combine  bwd    dyb[slot]   = g dy[src_tok]       gather(dy, src_row) * g

TPU-native design: the row index array rides in scalar-prefetch (SMEM) so
each grid step can issue a dynamic-slice DMA from the source (kept in
ANY/HBM memory space) into its VMEM output block — the canonical TPU
sparse-row-copy pattern (same shape as embedding gathers / megablocks
dispatch). The MXU is not involved; the kernel is a DMA engine, which is
exactly why the XLA scatter/gather lowering (and its f32-promoted
scatter-add transpose) is worth replacing on the target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, src_ref, out_ref, *, block_rows: int):
    """One grid step copies ``block_rows`` source rows into the out block."""
    base = pl.program_id(0) * block_rows
    for i in range(block_rows):  # static unroll; rows fetched by dynamic ds
        r = idx_ref[base + i]
        safe = jnp.maximum(r, 0)
        row = src_ref[pl.ds(safe, 1), :]
        out_ref[pl.ds(i, 1), :] = jnp.where(r >= 0, row, 0).astype(
            out_ref.dtype
        )


def gather_rows_pallas(
    src: jnp.ndarray,  # (N, d)
    idx: jnp.ndarray,  # (M,) int32, -1 -> zero row
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    N, d = src.shape
    (M,) = idx.shape
    pad = (-M) % block_rows
    idx_p = jnp.pad(idx, (0, pad), constant_values=-1)
    grid = (idx_p.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_gather_kernel, block_rows=block_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,  # idx rides in SMEM
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],  # src in HBM
            out_specs=pl.BlockSpec(
                (block_rows, d), lambda i, idx_ref: (i, 0)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((idx_p.shape[0], d), src.dtype),
        interpret=interpret,
    )(idx_p, src)
    return out[:M]
