"""Public kernel ops: TPU -> Pallas kernel, elsewhere -> jnp reference.

The model layer code calls these; the dispatch keeps the TPU kernel as the
*target* while remaining lowerable/testable on CPU (interpret=True exercises
the actual kernel body; the default CPU path is the mathematically identical
chunked reference so dry-run FLOPs match the kernel path).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd import ssd_pallas

_LANE = 128


def _use_pallas(force: Optional[str]) -> bool:
    if force == "pallas":
        return True
    if force in ("ref", "chunked"):
        return False
    if os.environ.get("REPRO_FORCE_REF"):
        return False
    return jax.default_backend() == "tpu"


def _pad_lane(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    d = x.shape[axis]
    pad = (-d) % _LANE
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, K, D)
    v: jnp.ndarray,  # (B, Sk, K, D)
    *,
    causal: bool = True,
    local_window: int = 0,
    logit_softcap: float = 0.0,
    q_offset: int = 0,
    impl: Optional[str] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """GQA attention in BSHD layout; scale fixed at rsqrt(true head dim)."""
    D = q.shape[-1]
    scale = 1.0 / D**0.5
    if _use_pallas(impl) or interpret:
        qp = _pad_lane(q).transpose(0, 2, 1, 3)  # (B, H, Sq, Dp)
        kp = _pad_lane(k).transpose(0, 2, 1, 3)
        vp = _pad_lane(v).transpose(0, 2, 1, 3)
        Sq = qp.shape[2]
        bq = min(block_q, Sq) if Sq % min(block_q, Sq) == 0 else Sq
        Sk = kp.shape[2]
        bk = min(block_k, Sk) if Sk % min(block_k, Sk) == 0 else Sk
        if q_offset != 0:
            # decode path with offset positions is served by the ref kernel
            # on CPU; on TPU the kv_len mask covers right-padding only.
            pass
        out = flash_attention_pallas(
            qp,
            kp,
            vp,
            causal=causal,
            local_window=local_window,
            logit_softcap=logit_softcap,
            scale=scale,
            block_q=bq,
            block_k=bk,
            interpret=interpret,
        )
        return out.transpose(0, 2, 1, 3)[..., :D]
    # Non-TPU compile target: mathematically identical chunked reference.
    # The named scope lets the roofline parser substitute the Pallas kernel's
    # true HBM traffic for the reference's materialized intermediates.
    with jax.named_scope("KERNEL_flash_attention"):
        return ref.attention_chunked(
            q,
            k,
            v,
            causal=causal,
            local_window=local_window,
            logit_softcap=logit_softcap,
            scale=scale,
            q_offset=q_offset,
        )


def ssd_scan(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, S, N)
    Cm: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 256,
    impl: Optional[str] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    S = x.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # zero-dt padding is inert: decay 1, no state update, outputs dropped
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    if _use_pallas(impl) or interpret:
        y = ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    else:
        with jax.named_scope("KERNEL_ssd_scan"):
            y = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    return y[:, :S] if pad else y


def ssd_decode(x, dt, A, Bm, Cm, state):
    """Single-token SSD recurrence (pure jnp; trivially vector-bound)."""
    return ref.ssd_decode_ref(x, dt, A, Bm, Cm, state)


# ---------------------------------------------------------------------------
# MoE dispatch/combine row permutation (gather-only in BOTH directions)
# ---------------------------------------------------------------------------


def _rows(src, idx, interpret, impl):
    """(G, N, d) gathered by (G, M) -> (G, M, d); idx -1 -> zero row."""
    if _use_pallas(impl) or interpret:
        from repro.kernels.gather_rows import gather_rows_pallas

        return jax.vmap(
            lambda s, i: gather_rows_pallas(s, i, interpret=interpret)
        )(src, idx)
    with jax.named_scope("KERNEL_moe_permute"):
        safe = jnp.maximum(idx, 0)
        out = jnp.take_along_axis(
            src, safe[..., None], axis=1, mode="clip"
        )
        return jnp.where(idx[..., None] >= 0, out, 0).astype(src.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def moe_permute(src, out_idx, inv_idx, k_inv: int, interpret: bool = False,
                impl=None):
    """out[g, i] = src[g, out_idx[g, i]] (-1 -> zeros).

    The transpose is ALSO a row gather (``inv_idx`` (G, N*k_inv) lists, for
    each source row, the k_inv output rows that read it): no scatter-add
    appears in fwd or bwd HLO — the XLA lowering of the scatter transpose
    is what promotes to f32 on host and serializes on TPU; the Pallas
    gather kernel replaces both directions with row-copy DMAs.
    """
    return _rows(src, out_idx, interpret, impl)


def _moe_permute_fwd(src, out_idx, inv_idx, k_inv, interpret, impl):
    return _rows(src, out_idx, interpret, impl), (inv_idx, src.shape)


def _moe_permute_bwd(k_inv, interpret, impl, res, dout):
    inv_idx, src_shape = res
    G, N, d = src_shape
    g = _rows(dout, inv_idx, interpret, impl)  # (G, N*k_inv, d)
    dsrc = g.reshape(G, N, k_inv, d).sum(axis=2).astype(dout.dtype)
    return dsrc, None, None


moe_permute.defvjp(_moe_permute_fwd, _moe_permute_bwd)
