"""Transformer layer primitives: norms, RoPE, GQA attention, gated MLP.

Every layer is a triple of pure functions:
  ``*_init(rng, cfg) -> params``          (fp32 params)
  ``*_specs(cfg, mctx, unit) -> spec tree``  (plan-aware PartitionSpecs)
  ``*_apply(params, x, ...) -> y``        (bf16 compute, f32 accumulation)

Plan semantics (paper mapping):
- ``unit.offload`` (gene=1): weights/compute use the model axis (TP).
  gene=0: compute replicated over the model axis — the "CPU loop" baseline.
- ``unit.staged``: internal ``with_sharding_constraint`` on q/k/v and FFN
  intermediates — the temp-area analogue that stops the partitioner from
  choosing implicit reshards.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.plan import Directive, UnitPlan
from repro.kernels import ops, ref
from repro.models.sharding import MODEL_AXIS, MeshCtx, attn_tp_mode

COMPUTE_DTYPE = jnp.bfloat16


def cast(x):
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def norm_init(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def norm_specs():
    return {"scale": P(None)}


# ---------------------------------------------------------------------------
# RoPE (fractional / 2d-style partial rotary)
# ---------------------------------------------------------------------------


def apply_rope(x, positions, fraction: float = 1.0, base: float = 10000.0):
    """x: (B, S, H, D); positions: (B, S) int32. Rotates first fraction of D."""
    D = x.shape[-1]
    rot = int(D * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, :, None, None].astype(jnp.float32) * freq  # (B,S,1,half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype), x_pass], -1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attention_init(rng, cfg: ArchConfig):
    d, H, K = cfg.d_model, cfg.n_heads, cfg.kv_heads
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = d**-0.5
    return {
        "wq": jax.random.normal(k1, (d, H, hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, K, hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, K, hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (H, hd, d), jnp.float32) * (H * hd) ** -0.5,
    }


def attention_specs(cfg: ArchConfig, mctx: MeshCtx, unit: UnitPlan):
    """At-rest specs: always TP-shard where divisible (memory), regardless of
    gene — gene=0 gathers at use (see _weight_entry)."""
    fsdp = mctx.fsdp()
    mode = attn_tp_mode(cfg.n_heads, cfg.kv_heads, mctx)
    qh = MODEL_AXIS if mode in ("heads", "qheads") else None
    kh = MODEL_AXIS if mode == "heads" else None
    return {
        "wq": P(fsdp, qh, None),
        "wk": P(fsdp, kh, None),
        "wv": P(fsdp, kh, None),
        "wo": P(qh, None, fsdp),
    }


def _use_weight(mctx: MeshCtx, w, spec: P, unit: UnitPlan):
    """Gather a weight for use according to the gene.

    gene=1: gather the FSDP dims only (keep TP sharding) — the offloaded path.
    gene=0: gather everything (model-axis replicated compute) — the baseline.
    The constraint placement implements bulk/per-layer transfer batching.
    """
    if mctx.mesh is None:
        return cast(w)
    if unit.offload:
        gathered = P(*[e if e == MODEL_AXIS else None for e in spec])
    else:
        gathered = P(*([None] * len(spec)))
    return mctx.wsc(cast(w), *gathered)


def attention_apply(
    params,
    x,  # (B, S, d) bf16
    cfg: ArchConfig,
    mctx: MeshCtx,
    unit: UnitPlan,
    positions,  # (B, S) int32
    *,
    is_local: bool = False,
    cache=None,  # dict with k/v (+ ring) for decode, or None
    return_kv: bool = False,  # prefill: hand back (k, v) for cache assembly
    interpret: bool = False,
):
    """Returns (y, new_cache). Train: cache None -> new_cache None.
    Prefill (return_kv): new_cache = {"k","v"} post-RoPE full-seq tensors."""
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    mode = attn_tp_mode(H, K, mctx)
    bspec = mctx.batch_entry(B)
    qh = MODEL_AXIS if (unit.offload and mode in ("heads", "qheads")) else None
    kh = MODEL_AXIS if (unit.offload and mode == "heads") else None
    seq_sh = MODEL_AXIS if (unit.offload and mode == "seq") else None

    wq = _use_weight(mctx, params["wq"], attention_specs(cfg, mctx, unit)["wq"], unit)
    wk = _use_weight(mctx, params["wk"], attention_specs(cfg, mctx, unit)["wk"], unit)
    wv = _use_weight(mctx, params["wv"], attention_specs(cfg, mctx, unit)["wv"], unit)
    wo = _use_weight(mctx, params["wo"], attention_specs(cfg, mctx, unit)["wo"], unit)

    # §Perf: bf16 einsum outputs halve activation HBM traffic and halve the
    # bytes of any partial-sum all-reduce (MXU still accumulates f32/shard).
    acc = COMPUTE_DTYPE if unit.bf16_intermediates else jnp.float32
    q = jnp.einsum("bsd,dhk->bshk", x, wq, preferred_element_type=acc)
    k = jnp.einsum("bsd,dhk->bshk", x, wk, preferred_element_type=acc)
    v = jnp.einsum("bsd,dhk->bshk", x, wv, preferred_element_type=acc)
    q = mctx.wsc(cast(q), bspec, seq_sh, qh, None, enabled=unit.staged)
    k = mctx.wsc(cast(k), bspec, None, kh, None, enabled=unit.staged)
    v = mctx.wsc(cast(v), bspec, None, kh, None, enabled=unit.staged)

    if cfg.causal:
        q = apply_rope(q, positions, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_fraction)

    window = cfg.local_window if is_local else 0
    new_cache = None
    if cache is None:
        o = ops.flash_attention(
            q, k, v,
            causal=cfg.causal,
            local_window=window,
            logit_softcap=cfg.attn_logit_softcap,
            interpret=interpret,
        )
        if return_kv:
            new_cache = {"k": k, "v": v}
    else:
        rotating = (
            window > 0 and cache["k"].shape[1] == window
        )  # sliding-window cache indexed mod window
        o, new_cache = decode_attention(
            q, k, v, cache, positions,
            local_window=window,
            logit_softcap=cfg.attn_logit_softcap,
            rotating=rotating,
        )
    o = mctx.wsc(o, bspec, seq_sh, qh, None, enabled=unit.staged)
    y = jnp.einsum("bshk,hkd->bsd", o, wo, preferred_element_type=acc)
    return cast(y), new_cache


# ---------------------------------------------------------------------------
# Decode attention over a KV cache (direct or ring-buffered)
# ---------------------------------------------------------------------------


def _merge_softmax(m1, l1, a1, m2, l2, a2):
    """Merge two online-softmax partials (m, l, acc)."""
    m = jnp.maximum(m1, m2)
    e1 = jnp.exp(m1 - m)
    e2 = jnp.exp(m2 - m)
    return m, l1 * e1 + l2 * e2, a1 * e1[..., None] + a2 * e2[..., None]


def _partial_attn(q, k, v, valid, scale, logit_softcap):
    """q (B,1,H,D) vs k/v (B,T,K,D) with validity mask (B,T) -> partials."""
    B, _, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qq = (q.reshape(B, K, G, D) * scale).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qq, k.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    s = jnp.where(valid[:, None, None, :], s, ref.NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return m, l, acc


def decode_attention(
    q, k_new, v_new, cache, positions, *, local_window, logit_softcap,
    rotating: bool = False,
):
    """One-token attention against cache; returns (out (B,1,H,D), new_cache).

    cache layouts:
    - direct: {"k","v": (B, Smax, K, D)} — new token written at its position
    - rotating (sliding window): same keys, written at pos % window
    - ring: {"k","v": (B, S_main, K, D)} seq-sharded read-only main +
      {"k_ring","v_ring": (B, R, K, D)} replicated ring for new tokens
    positions: (B, 1) absolute position of the new token.
    """
    B, _, H, D = q.shape
    scale = 1.0 / D**0.5
    pos = positions[:, 0]  # (B,)
    kq = k_new[:, 0]  # (B, K, D)
    vq = v_new[:, 0]

    return _decode_attention_scoped(
        q, cache, pos, kq, vq, scale, local_window, logit_softcap, rotating
    )


def _decode_attention_scoped(
    q, cache, pos, kq, vq, scale, local_window, logit_softcap, rotating
):
    """Body of decode attention inside a KERNEL_ scope: on TPU this region is
    a fused flash-decode computation reading the cache once; the roofline
    parser substitutes that traffic for the reference's intermediates."""
    import jax as _jax

    with _jax.named_scope("KERNEL_decode_attention"):
        return _decode_attention_impl(
            q, cache, pos, kq, vq, scale, local_window, logit_softcap, rotating
        )


def _decode_attention_impl(
    q, cache, pos, kq, vq, scale, local_window, logit_softcap, rotating
):
    B, _, H, D = q.shape
    if "k_ring" in cache:
        main_len = cache["k"].shape[1]
        R = cache["k_ring"].shape[1]
        slot = (pos - main_len) % R
        k_ring = jax.vmap(
            lambda c, t, p: jax.lax.dynamic_update_slice(c, t[None], (p, 0, 0))
        )(cache["k_ring"], kq, slot)
        v_ring = jax.vmap(
            lambda c, t, p: jax.lax.dynamic_update_slice(c, t[None], (p, 0, 0))
        )(cache["v_ring"], vq, slot)
        t_main = jnp.arange(cache["k"].shape[1])
        valid_main = (t_main[None, :] < jnp.minimum(pos[:, None] + 1, main_len))
        if local_window > 0:
            valid_main &= (pos[:, None] - t_main[None, :]) < local_window
        m1, l1, a1 = _partial_attn(q, cache["k"], cache["v"], valid_main,
                                   scale, logit_softcap)
        # Ring slot i holds absolute position main_len + i. The serving engine
        # flushes the ring into the (seq-sharded) main cache before it wraps,
        # so the no-wrap validity test is exact during a decode segment.
        t_ring = jnp.arange(R)
        valid_ring = (main_len + t_ring[None, :]) <= pos[:, None]
        if local_window > 0:
            valid_ring &= (pos[:, None] - (main_len + t_ring[None, :])) < local_window
        m2, l2, a2 = _partial_attn(q, k_ring, v_ring, valid_ring, scale, logit_softcap)
        m, l, acc = _merge_softmax(m1, l1, a1, m2, l2, a2)
        new_cache = dict(cache, k_ring=k_ring, v_ring=v_ring)
    else:
        W = cache["k"].shape[1]
        slot = pos % W if rotating else pos
        kc = jax.vmap(
            lambda c, t, p: jax.lax.dynamic_update_slice(c, t[None], (p, 0, 0))
        )(cache["k"], kq, slot)
        vc = jax.vmap(
            lambda c, t, p: jax.lax.dynamic_update_slice(c, t[None], (p, 0, 0))
        )(cache["v"], vq, slot)
        t_idx = jnp.arange(W)
        if rotating:
            # slot t holds absolute position pos - ((pos - t) mod W)
            abs_t = pos[:, None] - ((pos[:, None] - t_idx[None, :]) % W)
            valid = abs_t >= 0
        else:
            valid = t_idx[None, :] <= pos[:, None]
            if local_window > 0:
                valid &= (pos[:, None] - t_idx[None, :]) < local_window
        m, l, acc = _partial_attn(q, kc, vc, valid, scale, logit_softcap)
        new_cache = dict(cache, k=kc, v=vc)

    out = acc / jnp.maximum(l, 1e-37)[..., None]  # (B, K, G, D)
    out = out.reshape(B, 1, H, D).astype(q.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ArchConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "wi_gate": jax.random.normal(k1, (d, f), jnp.float32) * d**-0.5,
        "wi_up": jax.random.normal(k2, (d, f), jnp.float32) * d**-0.5,
        "wo": jax.random.normal(k3, (f, d), jnp.float32) * f**-0.5,
    }


def mlp_specs(cfg: ArchConfig, mctx: MeshCtx, unit: UnitPlan):
    fsdp = mctx.fsdp()
    f = cfg.d_ff
    fe = mctx.model_entry(f) if f else None
    return {
        "wi_gate": P(fsdp, fe),
        "wi_up": P(fsdp, fe),
        "wo": P(fe, fsdp),
    }


def _act(h, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(h, approximate=True)
    return jax.nn.silu(h)


def mlp_apply(params, x, cfg: ArchConfig, mctx: MeshCtx, unit: UnitPlan,
              act: str = "silu"):
    B, S, d = x.shape
    specs = mlp_specs(cfg, mctx, unit)
    wi_g = _use_weight(mctx, params["wi_gate"], specs["wi_gate"], unit)
    wi_u = _use_weight(mctx, params["wi_up"], specs["wi_up"], unit)
    wo = _use_weight(mctx, params["wo"], specs["wo"], unit)
    bspec = mctx.batch_entry(B)
    fe = MODEL_AXIS if (unit.offload and mctx.shardable(wi_g.shape[-1])) else None
    acc = COMPUTE_DTYPE if unit.bf16_intermediates else jnp.float32
    h = jnp.einsum("bsd,df->bsf", x, wi_g, preferred_element_type=acc)
    u = jnp.einsum("bsd,df->bsf", x, wi_u, preferred_element_type=acc)
    h = mctx.wsc(cast(_act(h, act) * u), bspec, None, fe, enabled=unit.staged)
    y = jnp.einsum("bsf,fd->bsd", h, wo, preferred_element_type=acc)
    return cast(y)
