"""Mesh context, partition-spec construction, and sharding-constraint helpers.

All sharding decisions flow through ``MeshCtx`` so that:
- smoke tests run with ``mesh=None`` (every constraint is a no-op),
- the dry-run runs the identical model code on the 256/512-chip meshes,
- the plan's transfer flags (`bulk_gather`/`keep_sharded`/`staged`) decide
  *where* constraints are placed, which is exactly how the paper's transfer
  directives decide where CPU-GPU copies happen.

Axis convention: ``model`` is the tensor/expert/sequence-parallel axis; every
other mesh axis (``data``, and ``pod`` when present) is a data-parallel /
FSDP axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Optional[jax.sharding.Mesh]

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in self.mesh.axis_names if a != MODEL_AXIS)

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[MODEL_AXIS]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    # -- spec builders -------------------------------------------------------
    def fsdp(self) -> Tuple[str, ...]:
        """The (possibly multi-axis) FSDP sharding entry for a weight dim."""
        return self.dp_axes

    def batch_entry(self, batch: int):
        """DP sharding entry for a batch dim (None when not divisible)."""
        if self.mesh is None or self.dp_size == 0:
            return None
        if batch % max(self.dp_size, 1) == 0 and self.dp_size > 1:
            return self.dp_axes
        return None

    def model_entry(self, dim: int):
        """Model-axis entry for a dim (None when not divisible)."""
        if self.mesh is None:
            return None
        return MODEL_AXIS if dim % self.model_size == 0 else None

    def shardable(self, dim: int) -> bool:
        return self.mesh is not None and dim % self.model_size == 0

    # -- constraint application ----------------------------------------------
    def wsc(self, x, *entries, enabled: bool = True):
        """with_sharding_constraint(x, P(*entries)) when a mesh is active."""
        if self.mesh is None or not enabled:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*entries))
        )

    def sharding(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


def attn_tp_mode(n_heads: int, kv_heads: int, mctx: MeshCtx) -> str:
    """Directive-applicability analysis for attention tensor parallelism.

    Mirrors the paper's pgcc loop classification: try the strongest directive
    first, fall back when the structure doesn't admit it.
    - "heads":   q and kv heads both shard over the model axis
    - "qheads":  only q heads shard; kv weights/cache replicated (small kv)
    - "seq":     neither shards -> sequence-parallel attention
    """
    m = mctx.model_size
    if m == 1:
        return "heads"
    if n_heads % m == 0 and kv_heads % m == 0:
        return "heads"
    if n_heads % m == 0:
        return "qheads"
    return "seq"


def spec_tree_to_shardings(mctx: MeshCtx, spec_tree):
    return jax.tree.map(lambda s: mctx.sharding(s), spec_tree)


def shaped_params(shape_tree, spec_tree, mctx: MeshCtx):
    """ShapeDtypeStructs with shardings attached (AOT lowering stand-ins).

    PartitionSpec is a pytree leaf in jax>=0.4, so a plain two-tree map works.
    """

    def mk(sd, spec):
        return jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=mctx.sharding(spec))

    return jax.tree.map(mk, shape_tree, spec_tree)
