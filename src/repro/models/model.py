"""Model assembly: groups of scanned layers driven by an ExecutionPlan.

One ``Model`` serves every assigned architecture family:
- dense / vlm / encoder: [attn + mlp] layer groups
- moe:                   [attn + moe] layer groups
- ssm:                   [ssd] layer groups
- hybrid:                [ssd] groups + a SHARED attn+mlp block applied
                         between groups (Zamba2-style, weights reused)
- gemma2 local/global:   layers scanned as (local, global) PAIRS so the
                         local layers can keep windowed KV caches

Layers inside a group are stacked and executed with ``lax.scan`` (compile
time independent of depth); the plan's remat policy wraps the scan body.

Modes: ``train`` (loss-ready logits), ``prefill`` (logits + assembled decode
cache), ``decode`` (one token against the cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan, UnitPlan
from repro.models import layers as L
from repro.models import mamba
from repro.models import moe as moe_mod
from repro.models.sharding import MODEL_AXIS, MeshCtx

RING_SIZE = 128  # decode ring length for seq-sharded-main caches
DECODE_MARGIN = 128  # extra slots past the prefilled context


def padded_vocab(cfg: ArchConfig) -> int:
    return -(-cfg.vocab // 512) * 512


@dataclasses.dataclass(frozen=True)
class GroupDef:
    name: str
    kind: str  # "attn_mlp" | "attn_moe" | "ssd" | "pair_local_global"
    n_layers: int  # layers (or layer-pairs) stacked in this group
    unit_names: Tuple[str, ...]


def make_groups(cfg: ArchConfig, plan: ExecutionPlan) -> List[GroupDef]:
    """Derive group structure from the plan's unit names."""
    names = [u.name for u in plan.units]
    g_ids = sorted({int(n.split("/")[0][1:]) for n in names if n.startswith("g")})
    G = len(g_ids)
    groups: List[GroupDef] = []
    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "hybrid":
            per = cfg.hybrid_attn_every
            sizes = []
            left = cfg.n_layers
            while left > 0:
                sizes.append(min(per, left))
                left -= per
            assert len(sizes) == G, (sizes, G)
        else:
            sizes = [
                cfg.n_layers // G + (1 if i < cfg.n_layers % G else 0)
                for i in range(G)
            ]
        for i, sz in enumerate(sizes):
            groups.append(GroupDef(f"g{i}", "ssd", sz, (f"g{i}/ssd",)))
        return groups

    pairs = cfg.local_global_pattern
    total = cfg.n_layers // 2 if pairs else cfg.n_layers
    kind = (
        "pair_local_global"
        if pairs
        else ("attn_moe" if cfg.moe is not None else "attn_mlp")
    )
    sizes = [total // G + (1 if i < total % G else 0) for i in range(G)]
    ffn_tag = "moe" if cfg.moe is not None else "ffn"
    for i, sz in enumerate(sizes):
        groups.append(GroupDef(f"g{i}", kind, sz, (f"g{i}/attn", f"g{i}/{ffn_tag}")))
    return groups


class Model:
    def __init__(
        self,
        cfg: ArchConfig,
        plan: ExecutionPlan,
        mesh=None,
        interpret: bool = False,
    ):
        self.cfg = cfg
        self.plan = plan
        self.mctx = MeshCtx(mesh)
        self.interpret = interpret
        self.groups = make_groups(cfg, plan)
        self.vp = padded_vocab(cfg)

    def _units(self, g: GroupDef) -> Tuple[UnitPlan, UnitPlan]:
        ua = self.plan.get(f"{g.name}/attn") or self.plan.get(f"{g.name}/ssd")
        uf = self.plan.get(f"{g.name}/ffn") or self.plan.get(f"{g.name}/moe") or ua
        return ua, uf

    # ------------------------------------------------------------------
    # parameter init / specs
    # ------------------------------------------------------------------
    def _layer_init(self, rng, kind: str):
        cfg = self.cfg
        if kind == "ssd":
            return {"ssd": mamba.ssd_init(rng, cfg)}
        k1, k2 = jax.random.split(rng, 2)
        p: Dict[str, Any] = {
            "norm_attn": L.norm_init(cfg.d_model),
            "norm_ffn": L.norm_init(cfg.d_model),
            "attn": L.attention_init(k1, cfg),
        }
        if cfg.sandwich_norms:
            p["norm_attn_post"] = L.norm_init(cfg.d_model)
            p["norm_ffn_post"] = L.norm_init(cfg.d_model)
        if kind == "attn_moe":
            p["moe"] = moe_mod.moe_init(k2, cfg)
        else:
            p["mlp"] = L.mlp_init(k2, cfg)
        return p

    def _layer_specs(self, kind: str, ua: UnitPlan, uf: UnitPlan):
        cfg, mctx = self.cfg, self.mctx
        if kind == "ssd":
            return {"ssd": mamba.ssd_specs(cfg, mctx, ua)}
        s: Dict[str, Any] = {
            "norm_attn": L.norm_specs(),
            "norm_ffn": L.norm_specs(),
            "attn": L.attention_specs(cfg, mctx, ua),
        }
        if cfg.sandwich_norms:
            s["norm_attn_post"] = L.norm_specs()
            s["norm_ffn_post"] = L.norm_specs()
        if kind == "attn_moe":
            s["moe"] = moe_mod.moe_specs(cfg, mctx, uf)
        else:
            s["mlp"] = L.mlp_specs(cfg, mctx, uf)
        return s

    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(rng, len(self.groups) + 4)
        params: Dict[str, Any] = {}
        if cfg.family != "encoder":
            params["embed"] = {
                "table": jax.random.normal(keys[-1], (self.vp, cfg.d_model), jnp.float32)
                * cfg.d_model**-0.5
            }
        for gi, g in enumerate(self.groups):
            n = g.n_layers
            subs = (
                [("local", "attn_mlp"), ("global", "attn_mlp")]
                if g.kind == "pair_local_global"
                else [("layers", g.kind)]
            )
            sub = {}
            for which, kind in subs:
                lrngs = jax.random.split(
                    jax.random.fold_in(keys[gi], hash(which) % 2**31), n
                )
                sub[which] = jax.vmap(lambda r: self._layer_init(r, kind))(lrngs)
            params[g.name] = sub
        if cfg.family == "hybrid":
            k1, k2 = jax.random.split(keys[-2])
            params["shared"] = {
                "norm_attn": L.norm_init(cfg.d_model),
                "norm_ffn": L.norm_init(cfg.d_model),
                "attn": L.attention_init(k1, cfg),
                "mlp": L.mlp_init(k2, cfg),
            }
        params["final_norm"] = L.norm_init(cfg.d_model)
        if cfg.family == "encoder" or not cfg.tie_embeddings:
            params["unembed"] = {
                "kernel": jax.random.normal(keys[-3], (cfg.d_model, self.vp), jnp.float32)
                * cfg.d_model**-0.5
            }
        return params

    def param_specs(self) -> Dict[str, Any]:
        cfg, mctx = self.cfg, self.mctx
        specs: Dict[str, Any] = {}
        if cfg.family != "encoder":
            specs["embed"] = {"table": P(mctx.model_entry(self.vp), None)}
        for g in self.groups:
            ua, uf = self._units(g)
            kind = "attn_mlp" if g.kind == "pair_local_global" else g.kind
            ls = self._layer_specs(kind, ua, uf)
            stacked = jax.tree.map(lambda s: P(None, *s), ls)
            if g.kind == "pair_local_global":
                specs[g.name] = {"local": stacked, "global": stacked}
            else:
                specs[g.name] = {"layers": stacked}
        if cfg.family == "hybrid":
            ua = self.plan.unit("shared/attn")
            uf = self.plan.unit("shared/ffn")
            specs["shared"] = {
                "norm_attn": L.norm_specs(),
                "norm_ffn": L.norm_specs(),
                "attn": L.attention_specs(cfg, mctx, ua),
                "mlp": L.mlp_specs(cfg, mctx, uf),
            }
        specs["final_norm"] = L.norm_specs()
        if cfg.family == "encoder" or not cfg.tie_embeddings:
            ue = self.plan.get("unembed")
            off = ue.offload if ue else True
            specs["unembed"] = {
                "kernel": P(None, mctx.model_entry(self.vp) if off else None)
            }
        return specs

    # ------------------------------------------------------------------
    # forward pieces
    # ------------------------------------------------------------------
    def _embed(self, params, batch):
        cfg, mctx = self.cfg, self.mctx
        if cfg.family == "encoder":
            x = batch["frames"].astype(L.COMPUTE_DTYPE)
        else:
            table = params["embed"]["table"]
            ue = self.plan.get("embed")
            if ue is not None and not ue.offload:
                table = mctx.wsc(table, None, None)
            x = jnp.take(table, batch["tokens"], axis=0).astype(L.COMPUTE_DTYPE)
            if cfg.family == "vlm" and "vision" in batch:
                x = jnp.concatenate(
                    [batch["vision"].astype(L.COMPUTE_DTYPE), x], axis=1
                )
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model**0.5, L.COMPUTE_DTYPE)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.family != "encoder" and cfg.tie_embeddings:
            w = params["embed"]["table"]
            logits = jnp.einsum(
                "bsd,vd->bsv", x, w.astype(L.COMPUTE_DTYPE),
                preferred_element_type=jnp.float32,
            )
        else:
            w = params["unembed"]["kernel"]
            logits = jnp.einsum(
                "bsd,dv->bsv", x, w.astype(L.COMPUTE_DTYPE),
                preferred_element_type=jnp.float32,
            )
        if cfg.final_logit_softcap > 0:
            c = cfg.final_logit_softcap
            logits = c * jnp.tanh(logits / c)
        if self.vp > cfg.vocab:
            mask = jnp.arange(self.vp) < cfg.vocab
            logits = jnp.where(mask[None, None, :], logits, -1e30)
        b = self.mctx.batch_entry(x.shape[0])
        ue = self.plan.get("unembed")
        ve = self.mctx.model_entry(self.vp) if (ue is None or ue.offload) else None
        return self.mctx.wsc(logits, b, None, ve)

    def _res_entries(self, batch_size: int, seq: int):
        """Residual stream constraint ('data present' analogue)."""
        b = self.mctx.batch_entry(batch_size)
        sharded = all(u.offload and u.keep_sharded for u in self.plan.units)
        seq_e = MODEL_AXIS if (sharded and self.mctx.shardable(seq)) else None
        return (b, seq_e, None)

    def _apply_block(self, lp, x, ua, uf, positions, is_local, cache, kind, mode):
        """One (attention + ffn) or ssd layer. Returns (x, new_cache, aux)."""
        cfg, mctx = self.cfg, self.mctx
        aux = jnp.zeros((), jnp.float32)
        if kind == "ssd":
            h, new_cache = mamba.ssd_apply(
                lp["ssd"], x, cfg, mctx, ua, cache=cache,
                return_cache=(mode == "prefill"), interpret=self.interpret,
            )
            return x + h, new_cache, aux
        h = L.rms_norm(x, lp["norm_attn"]["scale"], cfg.norm_eps)
        a, new_cache = L.attention_apply(
            lp["attn"], h, cfg, mctx, ua, positions,
            is_local=is_local, cache=cache,
            return_kv=(mode == "prefill"), interpret=self.interpret,
        )
        if cfg.sandwich_norms:
            a = L.rms_norm(a, lp["norm_attn_post"]["scale"], cfg.norm_eps)
        x = x + a
        h = L.rms_norm(x, lp["norm_ffn"]["scale"], cfg.norm_eps)
        if kind == "attn_moe":
            f, aux = moe_mod.moe_apply(lp["moe"], h, cfg, mctx, uf)
        else:
            f = L.mlp_apply(lp["mlp"], h, cfg, mctx, uf, act=cfg.act)
        if cfg.sandwich_norms:
            f = L.rms_norm(f, lp["norm_ffn_post"]["scale"], cfg.norm_eps)
        return x + f, new_cache, aux

    def _bulk_gather(self, gp, gspecs, ua: UnitPlan, uf: UnitPlan):
        """Coalesced FSDP gather of a whole group's stacked weights
        (multi-file bulk `data copy` analogue)."""
        if self.mctx.mesh is None:
            return gp

        def gather(tree, specs, unit):
            if not unit.bulk_gather:
                return tree

            def g(w, s):
                if unit.offload:
                    ent = [e if e == MODEL_AXIS else None for e in s]
                else:
                    ent = [None] * len(s)
                # gather in compute dtype: halves the collective bytes
                w = w.astype(L.COMPUTE_DTYPE) if w.dtype == jnp.float32 else w
                return self.mctx.wsc(w, *ent)

            return jax.tree.map(g, tree, specs)

        out = {}
        for key in gp:
            unit = uf if key in ("mlp", "moe") else ua
            out[key] = gather(gp[key], gspecs[key], unit)
        return out

    def gather_params(self, params):
        """Hoisted bulk 'data copy' (§Perf): gather every offloaded group's
        weights to compute dtype ONCE — called inside the differentiated
        step but OUTSIDE the microbatch loop, so the FSDP all-gather runs
        once per step and its transpose (the gradient reduce-scatter) also
        runs once, instead of once per microbatch. The exact framework-level
        analogue of the paper hoisting CPU-GPU copies out of inner loops."""
        out = dict(params)
        for g in self.groups:
            ua, uf = self._units(g)
            kind = "attn_mlp" if g.kind == "pair_local_global" else g.kind
            gspecs = jax.tree.map(
                lambda s: P(None, *s), self._layer_specs(kind, ua, uf)
            )
            if g.kind == "pair_local_global":
                out[g.name] = {
                    w: self._bulk_gather(params[g.name][w], gspecs, ua, uf)
                    for w in ("local", "global")
                }
            else:
                out[g.name] = {
                    "layers": self._bulk_gather(
                        params[g.name]["layers"], gspecs, ua, uf
                    )
                }
        return out

    def _run_group(self, g: GroupDef, params, x, positions, cache_g, mode):
        ua, uf = self._units(g)
        kind = "attn_mlp" if g.kind == "pair_local_global" else g.kind
        gspecs = jax.tree.map(
            lambda s: P(None, *s), self._layer_specs(kind, ua, uf)
        )

        def one(xc, lp, is_local, cache_l):
            return self._apply_block(
                lp, xc, ua, uf, positions, is_local, cache_l, kind, mode
            )

        remat = max(
            (u.remat for u in (ua, uf)),
            key=lambda r: ["none", "dots", "full"].index(r),
        )

        def wrap(fn):
            if remat == "none" or mode != "train":
                return fn
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if remat == "dots"
                else None
            )
            return jax.checkpoint(fn, policy=policy)

        if g.kind == "pair_local_global":
            loc_p = self._bulk_gather(params[g.name]["local"], gspecs, ua, uf)
            glo_p = self._bulk_gather(params[g.name]["global"], gspecs, ua, uf)
            if mode == "decode":

                def body(xc, xs):
                    lp_l, lp_g, c_l, c_g = xs
                    x1, nc_l, a1 = one(xc, lp_l, True, c_l)
                    x2, nc_g, a2 = one(x1, lp_g, False, c_g)
                    return x2, ({"local": nc_l, "global": nc_g}, a1 + a2)

                x, (nc, auxs) = jax.lax.scan(
                    body, x, (loc_p, glo_p, cache_g["local"], cache_g["global"])
                )
                return x, nc, auxs.sum()

            def body(xc, xs):
                lp_l, lp_g = xs
                x1, nc_l, a1 = one(xc, lp_l, True, None)
                x2, nc_g, a2 = one(x1, lp_g, False, None)
                kv = (
                    {"local": nc_l, "global": nc_g}
                    if mode == "prefill"
                    else 0.0
                )
                return x2, (kv, a1 + a2)

            x, (kvs, auxs) = jax.lax.scan(wrap(body), x, (loc_p, glo_p))
            return x, (kvs if mode == "prefill" else None), auxs.sum()

        gp = self._bulk_gather(params[g.name]["layers"], gspecs, ua, uf)

        if mode == "decode":

            def body(xc, xs):
                lp, c_l = xs
                x2, nc, a = one(xc, lp, False, c_l)
                return x2, (nc, a)

            x, (ncache, auxs) = jax.lax.scan(body, x, (gp, cache_g))
            return x, ncache, auxs.sum()

        def body(xc, lp):
            x2, nc, a = one(xc, lp, False, None)
            return x2, ((nc if mode == "prefill" else 0.0), a)

        x, (kvs, auxs) = jax.lax.scan(wrap(body), x, gp)
        return x, (kvs if mode == "prefill" else None), auxs.sum()

    def _shared_block(self, params, x, positions, cache, mode):
        """Hybrid (Zamba2) shared attention+MLP block; weights reused."""
        cfg, mctx = self.cfg, self.mctx
        ua = self.plan.unit("shared/attn")
        uf = self.plan.unit("shared/ffn")
        sp = params["shared"]
        h = L.rms_norm(x, sp["norm_attn"]["scale"], cfg.norm_eps)
        a, new_cache = L.attention_apply(
            sp["attn"], h, cfg, mctx, ua, positions, cache=cache,
            return_kv=(mode == "prefill"), interpret=self.interpret,
        )
        x = x + a
        h = L.rms_norm(x, sp["norm_ffn"]["scale"], cfg.norm_eps)
        x = x + L.mlp_apply(sp["mlp"], h, cfg, mctx, uf, act=cfg.act)
        return x, new_cache

    # ------------------------------------------------------------------
    # public entry points
    # ------------------------------------------------------------------
    def forward(self, params, batch, cache=None, mode: str = "train"):
        """Returns (logits, raw_caches, aux)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        if mode == "decode":
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        res = self._res_entries(B, S)
        x = self.mctx.wsc(x, *res)

        aux_total = jnp.zeros((), jnp.float32)
        caches: Dict[str, Any] = {}
        shared_i = 0
        for g in self.groups:
            cg = cache.get(g.name) if cache is not None else None
            x, ncg, aux = self._run_group(g, params, x, positions, cg, mode)
            x = self.mctx.wsc(x, *res)
            aux_total = aux_total + aux
            if ncg is not None:
                caches[g.name] = ncg
            if cfg.family == "hybrid":
                key = f"shared{shared_i}"
                sc = cache.get(key) if cache is not None else None
                x, nsc = self._shared_block(params, x, positions, sc, mode)
                x = self.mctx.wsc(x, *res)
                if nsc is not None:
                    caches[key] = nsc
                shared_i += 1
        x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, caches, aux_total

    def loss(self, params, batch):
        cfg = self.cfg
        logits, _, aux = self.forward(params, batch, mode="train")
        targets = batch["targets"]
        if cfg.family == "vlm" and cfg.frontend_positions:
            pad = jnp.full(
                (targets.shape[0], cfg.frontend_positions), -1, targets.dtype
            )
            targets = jnp.concatenate([pad, targets], axis=1)
        mask = (targets >= 0).astype(jnp.float32)
        tclip = jnp.maximum(targets, 0)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gather-free label pick: GSPMD-friendly on vocab-sharded logits
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        picked = jnp.sum(
            jnp.where(iota == tclip[..., None], logits, 0.0), axis=-1
        )
        nll = (lse - picked) * mask
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = nll.sum() / denom + 0.01 * aux
        return loss, {"nll": nll.sum() / denom, "aux": aux}

    def prefill(self, params, batch, ctx_len: Optional[int] = None):
        """Full-context forward; returns (last_logits, assembled cache)."""
        logits, raw, _ = self.forward(params, batch, mode="prefill")
        S = logits.shape[1]
        ctx_len = ctx_len or S
        cache = self._assemble_cache(raw, ctx_len)
        return logits[:, -1], cache

    def decode_step(self, params, cache, tokens, positions):
        """tokens (B,1), positions (B,1) -> (logits (B, vp), new cache)."""
        batch = {"tokens": tokens, "positions": positions}
        logits, ncache, _ = self.forward(params, batch, cache=cache, mode="decode")
        return logits[:, -1], ncache

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def _attn_cache_kind(self) -> str:
        """direct | ring — the ring holds new tokens when kv heads cannot
        shard over the model axis and the main cache is seq-sharded."""
        if self.mctx.mesh is None or self.mctx.shardable(self.cfg.kv_heads):
            return "direct"
        return "ring"

    def _attn_cache_template(self, n: int, batch: int, ctx_len: int, window: int):
        cfg, mctx = self.cfg, self.mctx
        K, hd = cfg.kv_heads, cfg.resolved_head_dim
        b = mctx.batch_entry(batch)
        lead = (n,) if n else ()
        lp = (None,) if n else ()

        def kv(slen, seq_entry, head_entry):
            shape = lead + (batch, slen, K, hd)
            return (shape, L.COMPUTE_DTYPE, P(*lp, b, seq_entry, head_entry, None))

        he = mctx.model_entry(K)
        if window > 0 and ctx_len >= window:
            t = kv(window, None, he)  # rotating sliding-window cache
            return {"k": t, "v": t}
        if self._attn_cache_kind() == "direct":
            t = kv(ctx_len + DECODE_MARGIN, None, he)
            return {"k": t, "v": t}
        main = kv(ctx_len, MODEL_AXIS if mctx.shardable(ctx_len) else None, None)
        ring = kv(RING_SIZE, None, None)
        return {"k": main, "v": main, "k_ring": ring, "v_ring": ring}

    def cache_template(self, batch: int, ctx_len: int):
        """Pytree of (shape, dtype, spec) leaves describing the decode cache."""
        cfg, mctx = self.cfg, self.mctx
        tmpl: Dict[str, Any] = {}
        for g in self.groups:
            if g.kind == "ssd":
                shapes = mamba.ssd_cache_shapes(cfg, batch)
                specs = mamba.ssd_cache_specs(cfg, mctx, batch)
                tmpl[g.name] = {
                    k: (
                        (g.n_layers,) + shapes[k][0],
                        shapes[k][1],
                        P(None, *specs[k]),
                    )
                    for k in shapes
                }
            elif g.kind == "pair_local_global":
                tmpl[g.name] = {
                    "local": self._attn_cache_template(
                        g.n_layers, batch, ctx_len, cfg.local_window
                    ),
                    "global": self._attn_cache_template(
                        g.n_layers, batch, ctx_len, 0
                    ),
                }
            else:
                tmpl[g.name] = self._attn_cache_template(
                    g.n_layers, batch, ctx_len, 0
                )
        if cfg.family == "hybrid":
            for i in range(len(self.groups)):
                tmpl[f"shared{i}"] = self._attn_cache_template(
                    0, batch, ctx_len, 0
                )
        return tmpl

    @staticmethod
    def _is_tmpl_leaf(v):
        return isinstance(v, tuple) and len(v) == 3 and isinstance(v[0], tuple)

    def cache_specs(self, batch: int, ctx_len: int):
        return jax.tree.map(
            lambda leaf: leaf[2],
            self.cache_template(batch, ctx_len),
            is_leaf=self._is_tmpl_leaf,
        )

    def cache_shape_structs(self, batch: int, ctx_len: int):
        def mk(leaf):
            shape, dt, spec = leaf
            return jax.ShapeDtypeStruct(shape, dt, sharding=self.mctx.sharding(spec))

        return jax.tree.map(
            mk, self.cache_template(batch, ctx_len), is_leaf=self._is_tmpl_leaf
        )

    def init_cache(self, batch: int, ctx_len: int):
        def mk(leaf):
            shape, dt, spec = leaf
            return self.mctx.wsc(jnp.zeros(shape, dt), *tuple(spec))

        return jax.tree.map(
            mk, self.cache_template(batch, ctx_len), is_leaf=self._is_tmpl_leaf
        )

    def _assemble_attn_cache(self, kv, tmpl):
        """kv: {"k","v"} stacked (n?, B, S, K, hd) from prefill; tmpl leaves."""

        def fill(src, leaf):
            shape, dt, spec = leaf
            slen = shape[-3]
            S = src.shape[-3]
            if slen == S:
                out = src
            elif slen > S:
                pad = [(0, 0)] * src.ndim
                pad[-3] = (0, slen - S)
                out = jnp.pad(src, pad)
            else:  # sliding window: keep last `slen`, rotated to slot = pos % W
                tail = jax.lax.slice_in_dim(src, S - slen, S, axis=src.ndim - 3)
                slots = np.arange(S - slen, S) % slen
                inv = np.argsort(slots)
                out = jnp.take(tail, jnp.asarray(inv), axis=src.ndim - 3)
            return self.mctx.wsc(out.astype(dt), *tuple(spec))

        out = {}
        for key in tmpl:
            if key.endswith("_ring"):
                shape, dt, spec = tmpl[key]
                out[key] = self.mctx.wsc(jnp.zeros(shape, dt), *tuple(spec))
            else:
                out[key] = fill(kv[key], tmpl[key])
        return out

    def _assemble_cache(self, raw, ctx_len: int):
        """Map prefill-collected kv/state trees into the decode cache layout."""
        tmpl = self.cache_template(self._raw_batch(raw), ctx_len)
        cache: Dict[str, Any] = {}
        for g in self.groups:
            rg = raw[g.name]
            tg = tmpl[g.name]
            if g.kind == "ssd":
                cache[g.name] = {
                    k: self.mctx.wsc(
                        rg[k].astype(tg[k][1]), *tuple(tg[k][2])
                    )
                    for k in tg
                }
            elif g.kind == "pair_local_global":
                cache[g.name] = {
                    "local": self._assemble_attn_cache(rg["local"], tg["local"]),
                    "global": self._assemble_attn_cache(rg["global"], tg["global"]),
                }
            else:
                cache[g.name] = self._assemble_attn_cache(rg, tg)
        for key in raw:
            if key.startswith("shared"):
                cache[key] = self._assemble_attn_cache(raw[key], tmpl[key])
        return cache

    def _raw_batch(self, raw) -> int:
        leaves = jax.tree.leaves(raw)
        g0 = self.groups[0]
        # stacked leaves are (n, B, S, K, hd) or ssd (n, B, ...)
        return leaves[0].shape[1]
