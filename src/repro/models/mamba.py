"""Mamba-2 SSD block (and its decode recurrence).

The block is one offload unit (`Directive.KERNELS`): in/out projections +
causal depthwise conv + the SSD chunked scan. Head dim layout is chosen so
the model axis shards SSD heads (TPU-native: heads are embarrassingly
parallel in SSD; B/C projections are per-group (G=1) and stay replicated).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.plan import UnitPlan
from repro.kernels import ops
from repro.models.layers import cast, rms_norm
from repro.models.sharding import MODEL_AXIS, MeshCtx

COMPUTE_DTYPE = jnp.bfloat16


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    nheads = inner // s.head_dim
    return inner, nheads, s.head_dim, s.state_dim, s.conv_width


def ssd_init(rng, cfg: ArchConfig):
    d = cfg.d_model
    inner, H, Pd, N, W = _dims(cfg)
    ks = jax.random.split(rng, 8)
    sc = d**-0.5
    return {
        "w_z": jax.random.normal(ks[0], (d, inner), jnp.float32) * sc,
        "w_x": jax.random.normal(ks[1], (d, inner), jnp.float32) * sc,
        "w_bc": jax.random.normal(ks[2], (d, 2 * N), jnp.float32) * sc,
        "w_dt": jax.random.normal(ks[3], (d, H), jnp.float32) * sc,
        "conv_x": jax.random.normal(ks[4], (W, inner), jnp.float32) * 0.1,
        "conv_bc": jax.random.normal(ks[5], (W, 2 * N), jnp.float32) * 0.1,
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        "A_log": jnp.log(jnp.linspace(1.0, 8.0, H).astype(jnp.float32)),
        "Dskip": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((inner,), jnp.float32),
        "w_out": jax.random.normal(ks[6], (inner, d), jnp.float32) * inner**-0.5,
    }


def ssd_specs(cfg: ArchConfig, mctx: MeshCtx, unit: UnitPlan):
    fsdp = mctx.fsdp()
    inner, H, Pd, N, W = _dims(cfg)
    ie = mctx.model_entry(inner)
    he = mctx.model_entry(H)
    return {
        "w_z": P(fsdp, ie),
        "w_x": P(fsdp, ie),
        "w_bc": P(fsdp, None),
        "w_dt": P(fsdp, he),
        "conv_x": P(None, ie),
        "conv_bc": P(None, None),
        "dt_bias": P(None),
        "A_log": P(None),
        "Dskip": P(None),
        "norm": P(ie),
        "w_out": P(ie, fsdp),
    }


def _gather(mctx: MeshCtx, w, spec: P, unit: UnitPlan):
    if mctx.mesh is None:
        return cast(w)
    if unit.offload:
        g = P(*[e if e == MODEL_AXIS else None for e in spec])
    else:
        g = P(*([None] * len(spec)))
    return mctx.wsc(cast(w), *g)


def _causal_conv(x, w, cache: Optional[jnp.ndarray]):
    """Depthwise causal conv. x (B,S,C), w (W,C); cache (B,W-1,C) or None.

    Returns (y (B,S,C), new_cache (B,W-1,C))."""
    B, S, C = x.shape
    W = w.shape[0]
    if cache is None:
        ctx = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        y = y + ctx[:, i : i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_cache = ctx[:, -(W - 1) :, :] if W > 1 else ctx[:, :0, :]
    return y.astype(x.dtype), new_cache


def ssd_apply(
    params,
    x,  # (B, S, d)
    cfg: ArchConfig,
    mctx: MeshCtx,
    unit: UnitPlan,
    *,
    cache=None,  # {"conv_x","conv_bc","state"} for decode
    return_cache: bool = False,  # prefill: return final state + conv tails
    interpret: bool = False,
):
    """Returns (y, new_cache)."""
    B, S, d = x.shape
    inner, H, Pd, N, W = _dims(cfg)
    specs = ssd_specs(cfg, mctx, unit)
    bspec = mctx.batch_entry(B)
    ie = MODEL_AXIS if (unit.offload and mctx.shardable(inner)) else None
    he = MODEL_AXIS if (unit.offload and mctx.shardable(H)) else None

    w_z = _gather(mctx, params["w_z"], specs["w_z"], unit)
    w_x = _gather(mctx, params["w_x"], specs["w_x"], unit)
    w_bc = _gather(mctx, params["w_bc"], specs["w_bc"], unit)
    w_dt = _gather(mctx, params["w_dt"], specs["w_dt"], unit)
    w_out = _gather(mctx, params["w_out"], specs["w_out"], unit)

    acc = COMPUTE_DTYPE if unit.bf16_intermediates else jnp.float32
    z = jnp.einsum("bsd,di->bsi", x, w_z, preferred_element_type=acc)
    xi = jnp.einsum("bsd,di->bsi", x, w_x, preferred_element_type=acc)
    bc = jnp.einsum("bsd,dn->bsn", x, w_bc, preferred_element_type=jnp.float32)
    dt_raw = jnp.einsum("bsd,dh->bsh", x, w_dt, preferred_element_type=jnp.float32)
    z, xi, bc = cast(z), cast(xi), cast(bc)
    xi = mctx.wsc(xi, bspec, None, ie, enabled=unit.staged)
    z = mctx.wsc(z, bspec, None, ie, enabled=unit.staged)

    new_cache = None
    cx = cache["conv_x"] if cache is not None else None
    cb = cache["conv_bc"] if cache is not None else None
    xi, ncx = _causal_conv(xi, params["conv_x"], cx)
    bc, ncb = _causal_conv(bc, params["conv_bc"], cb)
    xi = jax.nn.silu(xi)
    bc = jax.nn.silu(bc)
    Bm, Cm = bc[..., :N], bc[..., N:]

    dt = jax.nn.softplus(dt_raw + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, S, H, Pd)
    xh = mctx.wsc(xh, bspec, None, he, None, enabled=unit.staged)

    if cache is None and return_cache:
        from repro.kernels import ref  # prefill uses the state-returning oracle

        chunk = min(cfg.ssm.chunk, S)
        pad = (-S) % chunk
        padded = [
            jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
            for a in (xh, dt, Bm, Cm)
        ] if pad else [xh, dt, Bm, Cm]
        y, final_state = ref.ssd_ref(
            *[padded[0], padded[1]], A, padded[2], padded[3],
            chunk=chunk, return_state=True,
        )
        y = y[:, :S] if pad else y
        new_cache = {
            "conv_x": ncx.astype(COMPUTE_DTYPE),
            "conv_bc": ncb.astype(COMPUTE_DTYPE),
            "state": final_state,
        }
    elif cache is None:
        y = ops.ssd_scan(
            xh, dt, A, Bm, Cm, chunk=cfg.ssm.chunk, interpret=interpret
        )
    else:
        y1, new_state = ops.ssd_decode(
            xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], cache["state"]
        )
        y = y1[:, None]
        new_cache = {"conv_x": ncx, "conv_bc": ncb, "state": new_state}

    y = y + params["Dskip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, inner)
    y = rms_norm(cast(y) * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    y = mctx.wsc(y, bspec, None, ie, enabled=unit.staged)
    out = jnp.einsum("bsi,id->bsd", y, w_out, preferred_element_type=acc)
    return cast(out), new_cache


def ssd_cache_shapes(cfg: ArchConfig, batch: int):
    inner, H, Pd, N, W = _dims(cfg)
    return {
        "conv_x": ((batch, W - 1, inner), COMPUTE_DTYPE),
        "conv_bc": ((batch, W - 1, 2 * N), COMPUTE_DTYPE),
        "state": ((batch, H, Pd, N), jnp.float32),
    }


def ssd_cache_specs(cfg: ArchConfig, mctx: MeshCtx, batch: int):
    inner, H, Pd, N, W = _dims(cfg)
    b = mctx.batch_entry(batch)
    return {
        "conv_x": P(b, None, mctx.model_entry(inner)),
        "conv_bc": P(b, None, None),
        "state": P(b, mctx.model_entry(H), None, None),
    }
