"""Mixture-of-Experts block: top-k routing, capacity dispatch, EP sharding.

Two execution paths, selected by the plan:
- gene=1 (Directive.PARALLEL / expert parallelism): sort-based capacity
  dispatch into an (E, C, d) buffer sharded E-over-model; the expert GEMM is
  a local batched einsum per expert shard; GSPMD materializes the token
  routing as collectives (the measured "transfer" of this unit).
- gene=0 (baseline / VECTOR): experts replicated over the model axis, same
  dispatch math — per-chip FLOPs are ~model_size x higher, exactly the
  paper's un-offloaded loop.

The router always runs in the pjit world (outside any manual collectives) so
autodiff of replicated router weights stays correct.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.plan import UnitPlan
from repro.kernels import ops
from repro.models.sharding import MODEL_AXIS, MeshCtx

CAPACITY_FACTOR = 1.25
COMPUTE_DTYPE = jnp.bfloat16


def moe_init(rng, cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    E = cfg.moe.num_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * d**-0.5,
        "wi_gate": jax.random.normal(ks[1], (E, d, f), jnp.float32) * d**-0.5,
        "wi_up": jax.random.normal(ks[2], (E, d, f), jnp.float32) * d**-0.5,
        "wo": jax.random.normal(ks[3], (E, f, d), jnp.float32) * f**-0.5,
    }
    if cfg.moe.shared_experts:
        fs = f * cfg.moe.shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi_gate": jax.random.normal(k1, (d, fs), jnp.float32) * d**-0.5,
            "wi_up": jax.random.normal(k2, (d, fs), jnp.float32) * d**-0.5,
            "wo": jax.random.normal(k3, (fs, d), jnp.float32) * fs**-0.5,
        }
    return p


def moe_specs(cfg: ArchConfig, mctx: MeshCtx, unit: UnitPlan):
    fsdp = mctx.fsdp()
    E = cfg.moe.num_experts
    ee = mctx.model_entry(E)
    specs = {
        "router": P(fsdp, None),
        "wi_gate": P(ee, fsdp, None),
        "wi_up": P(ee, fsdp, None),
        "wo": P(ee, None, fsdp),
    }
    if cfg.moe.shared_experts:
        fs = cfg.d_ff * cfg.moe.shared_experts
        fe = mctx.model_entry(fs)
        specs["shared"] = {
            "wi_gate": P(fsdp, fe),
            "wi_up": P(fsdp, fe),
            "wo": P(fe, fsdp),
        }
    return specs


def _gather_for_use(mctx: MeshCtx, w, spec: P, unit: UnitPlan):
    if mctx.mesh is None:
        return w.astype(COMPUTE_DTYPE)
    if unit.offload:
        g = P(*[e if e == MODEL_AXIS else None for e in spec])
    else:
        g = P(*([None] * len(spec)))
    return mctx.wsc(w.astype(COMPUTE_DTYPE), *g)


def _dispatch_combine_local(xt, eids, gate_vals, E, k, cap, yb_fn):
    """Sort-based capacity dispatch + combine over ONE token group.

    xt (T, d); eids/gate_vals (T, k). ``yb_fn`` maps the dispatch buffer
    (E, cap, d) -> expert outputs (E, cap, d). Returns (T, d).
    """
    T, d = xt.shape
    flat_e = eids.reshape(-1)  # (Tk,)
    order = jnp.argsort(flat_e, stable=True)  # (Tk,)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, E * cap)  # overflow bin
    src_tok = order // k

    buf = jnp.zeros((E * cap + 1, d), COMPUTE_DTYPE).at[slot].set(xt[src_tok])
    yb = yb_fn(buf[: E * cap].reshape(E, cap, d))
    yb = yb.reshape(E * cap, d)
    yb = jnp.concatenate([yb, jnp.zeros((1, d), yb.dtype)], axis=0)

    slot_of_flat = jnp.zeros((T * k,), jnp.int32).at[order].set(slot)
    y_flat = yb[slot_of_flat].astype(jnp.float32)  # (Tk, d)
    return (y_flat.reshape(T, k, d) * gate_vals[..., None]).sum(axis=1)


def moe_apply(
    params,
    x,  # (B, S, d) bf16
    cfg: ArchConfig,
    mctx: MeshCtx,
    unit: UnitPlan,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    specs = moe_specs(cfg, mctx, unit)
    T = B * S
    xt = x.reshape(T, d)
    tok_spec = mctx.batch_entry(B)  # token dim inherits the batch sharding
    acc_dtype = (
        COMPUTE_DTYPE if unit.bf16_intermediates else jnp.float32
    )

    # ---- routing (pjit world; replicated router weights -> correct grads) --
    router = params["router"].astype(jnp.float32)
    logits = xt.astype(jnp.float32) @ router  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,)).at[eids.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # ---- expert weights (stay E-sharded when offloaded) --------------------
    wi_g = _gather_for_use(mctx, params["wi_gate"], specs["wi_gate"], unit)
    wi_u = _gather_for_use(mctx, params["wi_up"], specs["wi_up"], unit)
    wo = _gather_for_use(mctx, params["wo"], specs["wo"], unit)
    ee = MODEL_AXIS if (unit.offload and mctx.shardable(E)) else None

    def experts_fn(buf):  # (..., E, cap, d) -> (..., E, cap, d)
        h = jnp.einsum("...ecd,edf->...ecf", buf, wi_g,
                       preferred_element_type=acc_dtype)
        u = jnp.einsum("...ecd,edf->...ecf", buf, wi_u,
                       preferred_element_type=acc_dtype)
        h = (jax.nn.silu(h) * u).astype(COMPUTE_DTYPE)
        return jnp.einsum("...ecf,efd->...ecd", h, wo,
                          preferred_element_type=acc_dtype)

    # ---- dispatch + expert compute + combine --------------------------------
    G = mctx.dp_size if (unit.grouped_dispatch and mctx.mesh is not None) else 1
    if G > 1 and T % G == 0:
        # §Perf beyond-paper path: routing indices are computed LOCALLY per
        # data-shard group; the token payload moves through the
        # ``ops.moe_permute`` row-gather kernel (gather-only in fwd AND bwd,
        # no scatter-add), and the (G,E,cap,d) buffer reshards G-sharded ->
        # E-sharded as one all-to-all — the GShard/Switch EP pattern.
        Tg = T // G
        cap = int(CAPACITY_FACTOR * Tg * k / E) + 1
        dp = mctx.dp_axes

        xg = mctx.wsc(xt.reshape(G, Tg, d).astype(COMPUTE_DTYPE),
                      dp, None, None)
        eg = mctx.wsc(eids.reshape(G, Tg, k), dp, None, None)
        gg = gate_vals.reshape(G, Tg, k)

        def route(eids_g):
            """Local index computation (int32 only, no payload movement):
            returns (buf_src (E*cap,), tok_slots (Tg*k,), flat_of_slot)."""
            flat_e = eids_g.reshape(-1)
            order = jnp.argsort(flat_e, stable=True)
            sorted_e = flat_e[order]
            counts = jnp.bincount(flat_e, length=E)
            starts = jnp.cumsum(counts) - counts
            pos_in_e = jnp.arange(Tg * k) - starts[sorted_e]
            keep = pos_in_e < cap
            slot = jnp.where(keep, sorted_e * cap + pos_in_e, E * cap)
            src_tok = order // k
            # buf_src[slot] = source token (int scatter, payload untouched)
            buf_src = jnp.full((E * cap + 1,), -1, jnp.int32)
            buf_src = buf_src.at[slot].set(src_tok.astype(jnp.int32))
            buf_src = buf_src[: E * cap]
            # tok_slots[t*k+j] = slot holding copy j of token t (-1 dropped)
            slot_of_flat = jnp.zeros((Tg * k,), jnp.int32).at[order].set(slot)
            tok_slots = jnp.where(
                slot_of_flat < E * cap, slot_of_flat, -1
            ).astype(jnp.int32)
            # flat_of_slot[s] = flat (t,k) index written into slot s:
            # slot[] is in SORTED order, so the flat id at position p is
            # order[p] (NOT p)
            flat_of_slot = jnp.full((E * cap + 1,), -1, jnp.int32)
            flat_of_slot = flat_of_slot.at[slot].set(order.astype(jnp.int32))
            return buf_src, tok_slots, flat_of_slot[: E * cap]

        buf_src, tok_slots, flat_of_slot = jax.vmap(route)(eg)

        # dispatch: buf rows gathered from tokens (bwd = gather over slots)
        bufs = ops.moe_permute(xg, buf_src, tok_slots, k)  # (G, E*cap, d)
        bufs = bufs.reshape(G, E, cap, d)
        bufs = mctx.wsc(bufs, dp, None, None, None, enabled=unit.staged)
        # reshard (G@data, E full) -> (G@data, E@model): all-to-all over the
        # MODEL axis only; device (di, mi) then holds group di's slots for
        # experts mi — G stays data-sharded so expert compute divides over
        # the FULL device set (GShard layout)
        bufs = mctx.wsc(bufs, dp, ee, None, None, enabled=unit.staged)
        ybs = experts_fn(bufs)  # (G@data, E@model, cap, d)
        ybs = mctx.wsc(
            ybs.astype(COMPUTE_DTYPE), dp, ee, None, None,
            enabled=unit.staged,
        )
        # reshard back (G@data, E full): the combine all-to-all
        ybs = mctx.wsc(ybs, dp, None, None, None, enabled=unit.staged)

        # combine: per-token rows gathered from slots (bwd = slot gather)
        y_flat = ops.moe_permute(
            ybs.reshape(G, E * cap, d), tok_slots, flat_of_slot, 1
        )  # (G, Tg*k, d)
        y = (
            y_flat.reshape(G, Tg, k, d).astype(acc_dtype)
            * gg[..., None].astype(acc_dtype)
        ).sum(axis=2)
        y = y.reshape(T, d).astype(COMPUTE_DTYPE)
    else:
        # paper-faithful baseline: one global sort-based capacity dispatch
        cap = int(CAPACITY_FACTOR * T * k / E) + 1

        def experts_sharded(buf):
            buf = mctx.wsc(buf, ee, None, None, enabled=unit.staged)
            yb = experts_fn(buf)
            # combine all-gather, placed EXPLICITLY: every token shard
            # reads arbitrary slots in the next gather, so the expert
            # outputs must be replicated here. This constraint is
            # load-bearing for correctness, not a staging choice — left
            # to GSPMD, the jax<=0.4.x SPMD partitioner miscompiles the
            # E-sharded reshape+concat+row-gather chain (jit != eager).
            return mctx.wsc(yb.astype(COMPUTE_DTYPE), None, None, None)

        y = _dispatch_combine_local(
            xt, eids, gate_vals, E, k, cap, experts_sharded
        ).astype(COMPUTE_DTYPE)

    # ---- shared experts (always-on dense path) ------------------------------
    if "shared" in params:
        sh = params["shared"]
        sspec = specs["shared"]
        wg = _gather_for_use(mctx, sh["wi_gate"], sspec["wi_gate"], unit)
        wu = _gather_for_use(mctx, sh["wi_up"], sspec["wi_up"], unit)
        wd = _gather_for_use(mctx, sh["wo"], sspec["wo"], unit)
        hs = jnp.einsum("td,df->tf", xt, wg, preferred_element_type=acc_dtype)
        us = jnp.einsum("td,df->tf", xt, wu, preferred_element_type=acc_dtype)
        hs = (jax.nn.silu(hs) * us).astype(COMPUTE_DTYPE)
        y = y + jnp.einsum(
            "tf,fd->td", hs, wd, preferred_element_type=acc_dtype
        ).astype(COMPUTE_DTYPE)

    y = y.reshape(B, S, d)
    y = mctx.wsc(y, tok_spec, None, None, enabled=unit.staged)
    return y, aux.astype(jnp.float32)
