"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060].
"""
from repro.configs.base import ArchConfig, SSMConfig

ARCH = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,  # attention-free
    kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
