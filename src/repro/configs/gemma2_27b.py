"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating attention, logit softcaps
[arXiv:2408.00118].
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    local_window=4096,
    local_global_pattern=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    sandwich_norms=True,
    act="gelu",
    scale_embed=True,
    source="arXiv:2408.00118",
)
