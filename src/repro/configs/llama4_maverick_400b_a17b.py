"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].
"""
from repro.configs.base import ArchConfig, MoEConfig

ARCH = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    kv_heads=8,
    d_ff=8192,
    vocab=202048,
    moe=MoEConfig(num_experts=128, top_k=1, shared_experts=1),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
