"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

Simplification recorded in DESIGN.md: the shared attention+MLP block (single
weight set) is applied every ``hybrid_attn_every`` SSM layers; Zamba2's
per-invocation LoRA deltas are omitted.
"""
from repro.configs.base import ArchConfig, SSMConfig

ARCH = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    hybrid_attn_every=6,
    source="arXiv:2411.15242",
)
