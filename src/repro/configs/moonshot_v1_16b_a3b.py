"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight)
[hf:moonshotai/Moonlight-16B-A3B].
"""
from repro.configs.base import ArchConfig, MoEConfig

ARCH = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=1408,
    vocab=163840,
    moe=MoEConfig(num_experts=64, top_k=6, shared_experts=1),
    source="hf:moonshotai/Moonlight-16B-A3B",
)
