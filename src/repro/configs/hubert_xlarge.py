"""hubert-xlarge [audio]: 48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504.

Encoder-only transformer (same arch as wav2vec2) [arXiv:2106.07447].
The audio frontend (conv feature extractor) is a STUB: ``input_specs()``
provides precomputed frame embeddings of shape (batch, seq, d_model).
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    frontend="audio",
    frontend_positions=0,  # every position is a frame embedding
    source="arXiv:2106.07447",
)
