"""Architecture / shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload shape a
``ShapeConfig``. The registry maps ``--arch <id>`` / ``--shape <name>`` CLI
selections to configs, and encodes the applicability rules (encoder-only archs
have no decode step; ``long_500k`` requires sub-quadratic attention).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Shape configs (assigned input-shape set for the LM family)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """A workload cell: sequence length x global batch x step kind."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    shared_experts: int = 0  # extra always-on experts (Llama-4 style)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int  # N: SSM state size per head
    head_dim: int = 64  # P: channels per SSD head
    expand: int = 2  # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture. Field values follow the assignment sheet."""

    name: str
    family: str  # "dense" | "moe" | "ssm" | "hybrid" | "encoder" | "vlm"
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    # --- optional per-family extensions -----------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # attention structure
    head_dim: Optional[int] = None  # default d_model // n_heads
    rope_fraction: float = 1.0  # chatglm-style partial rotary
    local_window: int = 0  # >0: sliding-window size for local layers
    local_global_pattern: bool = False  # gemma2: alternate local/global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    causal: bool = True  # False for encoder-only
    # hybrid structure: attention block shared + applied every k ssm layers
    hybrid_attn_every: int = 0
    # modality frontend stub ("none" | "audio" | "vision")
    frontend: str = "none"
    # number of frontend embedding positions occupied at the head of the seq
    frontend_positions: int = 256
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sandwich_norms: bool = False  # gemma2 pre+post block norms
    act: str = "silu"
    scale_embed: bool = False  # gemma2 multiplies embeddings by sqrt(d)
    source: str = ""

    # -- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def encoder_only(self) -> bool:
        return self.family == "encoder"

    @property
    def subquadratic(self) -> bool:
        """True when a 512k-token decode cell is tractable for this arch."""
        if self.family in ("ssm", "hybrid"):
            return True  # SSM state is O(1) in sequence length
        # local+global alternating (gemma2): local layers windowed; global
        # layers at decode are O(KV) per token -> tractable.
        return self.local_global_pattern

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks), for roofline math."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            inner = self.ssm.expand * d
            nheads = inner // self.ssm.head_dim
            # in_proj: d -> 2*inner + 2*ngroups*N + nheads ; out_proj inner->d
            per_layer = d * (2 * inner + 2 * self.ssm.state_dim + nheads)
            per_layer += inner * d + self.ssm.conv_width * (
                inner + 2 * self.ssm.state_dim
            )
        else:
            qkv = d * hd * (self.n_heads + 2 * self.kv_heads) + self.n_heads * hd * d
            if self.moe is not None:
                nexp = self.moe.num_experts + self.moe.shared_experts
                ff = nexp * 3 * d * f + d * self.moe.num_experts
            else:
                ff = 3 * d * f
            per_layer = qkv + ff
            if self.family == "hybrid":
                # SSM backbone layers; the attention+MLP block is SHARED
                # (single weight set applied every hybrid_attn_every layers).
                inner = self.ssm.expand * d
                nheads = inner // self.ssm.head_dim
                per_layer = (
                    d * (2 * inner + 2 * self.ssm.state_dim + nheads) + inner * d
                )
                return embed + L * per_layer + (qkv + ff)
        return embed + L * per_layer

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        qkv = d * hd * (self.n_heads + 2 * self.kv_heads) + self.n_heads * hd * d
        act_ff = (self.moe.top_k + self.moe.shared_experts) * 3 * d * f
        return embed + L * (qkv + act_ff + d * self.moe.num_experts)

    # -- shape applicability --------------------------------------------------
    def shapes(self) -> Tuple[ShapeConfig, ...]:
        out = []
        for s in ALL_SHAPES:
            if s.is_decode and self.encoder_only:
                continue  # no autoregressive step exists
            if s.name == "long_500k" and not self.subquadratic:
                continue  # needs sub-quadratic attention
            out.append(s)
        return tuple(out)

    def skipped_shapes(self) -> Tuple[Tuple[str, str], ...]:
        out = []
        for s in ALL_SHAPES:
            if s.is_decode and self.encoder_only:
                out.append((s.name, "encoder-only: no autoregressive decode step"))
            elif s.name == "long_500k" and not self.subquadratic:
                out.append((s.name, "pure full-attention arch: 512k decode excluded"))
        return tuple(out)

    # -- reduced config for CPU smoke tests ----------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config: runs a real step on one CPU device."""
        kv = max(1, min(self.kv_heads, 2))
        heads = max(kv, min(self.n_heads, 4))
        moe = None
        if self.moe is not None:
            moe = MoEConfig(
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                shared_experts=min(self.moe.shared_experts, 1),
            )
        ssm = None
        if self.ssm is not None:
            ssm = SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=32)
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 if self.hybrid_attn_every == 0 else 4),
            d_model=64,
            n_heads=heads,
            kv_heads=kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            moe=moe,
            ssm=ssm,
            local_window=32 if self.local_window else 0,
            frontend_positions=8 if self.frontend != "none" else 0,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "hubert-xlarge",
    "internvl2-76b",
    "moonshot-v1-16b-a3b",
    "llama4-maverick-400b-a17b",
    "gemma2-27b",
    "glm4-9b",
    "chatglm3-6b",
    "stablelm-3b",
    "zamba2-1.2b",
    "mamba2-1.3b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.ARCH


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def all_cells() -> Sequence[Tuple[ArchConfig, ShapeConfig]]:
    """Every runnable (arch x shape) cell under the applicability rules."""
    cells = []
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for s in arch.shapes():
            cells.append((arch, s))
    return cells
