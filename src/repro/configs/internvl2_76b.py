"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

InternViT + InternLM2 [arXiv:2404.16821]. Backbone only: the vision frontend
is a STUB — ``input_specs()`` provides precomputed patch embeddings occupying
the first ``frontend_positions`` sequence slots.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=28672,
    vocab=128256,
    frontend="vision",
    frontend_positions=256,
    source="arXiv:2404.16821",
)
