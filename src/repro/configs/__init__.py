from repro.configs.base import (
    ALL_SHAPES,
    ARCH_IDS,
    ArchConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    all_cells,
    get_arch,
    get_shape,
)

__all__ = [
    "ALL_SHAPES",
    "ARCH_IDS",
    "ArchConfig",
    "MoEConfig",
    "ShapeConfig",
    "SSMConfig",
    "all_cells",
    "get_arch",
    "get_shape",
]
