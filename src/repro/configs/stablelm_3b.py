"""stablelm-3b [dense]: 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304
[hf:stabilityai/stablelm-2-1_6b].
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    kv_heads=32,
    d_ff=6912,
    vocab=50304,
    rope_fraction=0.25,
    source="hf:stabilityai/stablelm-2-1_6b",
)
