"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024
— 2d RoPE (applied to half of head dim), GQA [arXiv:2406.12793].
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    kv_heads=2,
    d_ff=13696,
    vocab=65024,
    rope_fraction=0.5,
    source="arXiv:2406.12793",
)
