"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the AOT dry-run lowers
against these. Frontend stubs per assignment: [audio] provides frame
embeddings, [vlm] provides patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model import Model
from repro.models.sharding import MeshCtx


def _sds(mctx: MeshCtx, shape, dtype, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=mctx.sharding(spec))


def batch_specs(
    cfg: ArchConfig, shape: ShapeConfig, mesh, *, with_targets: bool
) -> Dict[str, Any]:
    mctx = MeshCtx(mesh)
    B, S = shape.global_batch, shape.seq_len
    b = mctx.batch_entry(B)
    out: Dict[str, Any] = {}
    if cfg.family == "encoder":
        out["frames"] = _sds(
            mctx, (B, S, cfg.d_model), jnp.bfloat16, P(b, None, None)
        )
    elif cfg.family == "vlm":
        pv = cfg.frontend_positions
        out["tokens"] = _sds(mctx, (B, S - pv), jnp.int32, P(b, None))
        out["vision"] = _sds(
            mctx, (B, pv, cfg.d_model), jnp.bfloat16, P(b, None, None)
        )
    else:
        out["tokens"] = _sds(mctx, (B, S), jnp.int32, P(b, None))
    if with_targets:
        tgt_len = S - cfg.frontend_positions if cfg.family == "vlm" else S
        out["targets"] = _sds(mctx, (B, tgt_len), jnp.int32, P(b, None))
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeConfig, mesh, model: Model):
    """(tokens, positions, cache) structs for one decode step vs a full
    seq_len context."""
    mctx = MeshCtx(mesh)
    B, S = shape.global_batch, shape.seq_len
    b = mctx.batch_entry(B)
    tokens = _sds(mctx, (B, 1), jnp.int32, P(b, None))
    positions = _sds(mctx, (B, 1), jnp.int32, P(b, None))
    cache = model.cache_shape_structs(B, S)
    return tokens, positions, cache
