import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)
# ^ MUST run before any other import: jax locks device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real step function (train_step for
training shapes, prefill/decode for serving shapes) against ShapeDtypeStruct
inputs on the production mesh, then records:
- memory_analysis()  (fits-per-device evidence)
- cost_analysis()    (FLOPs / bytes for the roofline)
- the parsed collective schedule (bytes, op counts, trip-count aware)

Usage:
  python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, get_shape
from repro.core import analysis
from repro.launch import inputs as inp
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    Roofline,
    cost_analysis_dict,
    kernel_hbm_bytes,
    model_flops,
    parse_hlo_costs,
)
from repro.models.model import Model
from repro.models.sharding import MeshCtx, shaped_params
from repro.optim.adamw import adafactor, adamw, cosine_schedule
from repro.train import train_step as ts

ADAFACTOR_THRESHOLD = 100e9  # params above this use the factored optimizer


def pick_optimizer(cfg):
    if cfg.n_params() > ADAFACTOR_THRESHOLD:
        return adafactor(cosine_schedule(1e-3, 100, 10000))
    return adamw(cosine_schedule(3e-4, 100, 10000))


def build_cell(arch_id: str, shape_name: str, mesh, plan=None,
               n_groups: int = analysis.DEFAULT_GROUPS,
               opt: bool = False, tokens_budget: int = 8192,
               remat: str = "full"):
    """Returns (jitted fn, arg structs tuple, model, plan)."""
    cfg = get_arch(arch_id)
    shape = get_shape(shape_name)
    mctx = MeshCtx(mesh)
    if plan is None:
        if opt and tokens_budget == 8192:
            tokens_budget = 32768  # opt default; explicit values win
        mb = (
            ts.pick_microbatches(shape.global_batch, shape.seq_len,
                                 mctx.dp_size, tokens_budget)
            if shape.kind == "train"
            else 1
        )
        plan = analysis.build_plan(
            cfg, mesh, n_groups=n_groups, microbatches=mb, optimized=opt,
            bulk_gather=(None if opt else True), remat=remat,
        )
    model = Model(cfg, plan, mesh=mesh)
    pshapes = jax.eval_shape(model.init, jax.random.key(0))
    pstructs = shaped_params(pshapes, model.param_specs(), mctx)

    if shape.kind == "train":
        opt = pick_optimizer(cfg)
        step = ts.make_train_step(model, opt)
        oshapes = jax.eval_shape(opt.init, pstructs)
        ostructs = shaped_params(
            oshapes, opt.state_specs(model.param_specs()), mctx
        )
        batch = inp.batch_specs(cfg, shape, mesh, with_targets=True)
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn, (pstructs, ostructs, batch), model, plan
    if shape.kind == "prefill":
        step = ts.make_prefill_step(model)
        batch = inp.batch_specs(cfg, shape, mesh, with_targets=False)
        fn = jax.jit(step)
        return fn, (pstructs, batch), model, plan
    # decode
    step = ts.make_decode_step(model)
    tokens, positions, cache = inp.decode_specs(cfg, shape, mesh, model)
    fn = jax.jit(step, donate_argnums=(1,))
    return fn, (pstructs, cache, tokens, positions), model, plan


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, plan=None, tag: str = "default",
             verbose: bool = True, mesh=None, opt: bool = False,
             tokens_budget: int = 8192, remat: str = "full"):
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_arch(arch_id)
    shape = get_shape(shape_name)
    t0 = time.time()
    fn, args, model, plan = build_cell(
        arch_id, shape_name, mesh, plan=plan, opt=opt,
        tokens_budget=tokens_budget, remat=remat,
    )
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    costs = parse_hlo_costs(compiled.as_text())
    n_dev = mesh.devices.size
    mesh_name = (
        "x".join(str(s) for s in mesh.devices.shape)
        if mesh.devices.shape not in ((16, 16), (2, 16, 16))
        else ("2x16x16" if multi_pod else "16x16")
    )
    mctx = MeshCtx(mesh)
    kbytes = kernel_hbm_bytes(
        cfg, shape, mctx.model_size, mctx.dp_size, plan.microbatches,
        remat_full=any(u.remat == "full" for u in plan.units),
    )
    rl = Roofline(
        flops_per_dev=costs.flops,
        bytes_per_dev=costs.bytes_accessed + kbytes,
        collective_bytes_per_dev=costs.collective_bytes,
        collective_count=costs.collective_count,
        n_devices=n_dev,
        model_flops=model_flops(cfg, shape),
        overlap=0.0,
    )
    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_name,
        "tag": tag,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost_analysis_raw": {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")
        },
        "hlo_costs": {
            "flops_per_dev": costs.flops,
            "bytes_per_dev": costs.bytes_accessed,
            "kernel_ref_bytes_excluded": costs.kernel_ref_bytes,
            "kernel_hbm_bytes_added": kbytes,
        },
        "collectives": {
            "bytes_by_op": costs.coll_bytes,
            "count_by_op": costs.coll_count,
            "total_bytes": costs.collective_bytes,
            "schedule": costs.describe_collectives(),
        },
        "roofline": rl.row(),
        "model_flops": rl.model_flops,
        "plan": plan.describe(),
    }
    if verbose:
        peak = rec["memory"]["peak_bytes_per_device"] / 2**30
        print(
            f"[dryrun] {arch_id} x {shape_name} x {rec['mesh']} ({tag}): "
            f"compile {t_compile:.0f}s, peak {peak:.2f} GiB/dev, "
            f"t_step {rl.t_step*1e3:.2f} ms, bottleneck {rl.bottleneck}, "
            f"roofline {rl.roofline_fraction:.2%}"
        )
        print(f"  memory_analysis: {mem}")
        print(f"  collective schedule: {costs.describe_collectives()}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch_id}_{shape_name}_{rec['mesh']}_{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="default")
    ap.add_argument("--archs", help="comma-separated subset for --all")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimized profile (§Perf)")
    ap.add_argument("--tokens-budget", type=int, default=8192)
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    ap.add_argument("--mesh-shape", default=None,
                    help="custom logical mesh over the same chips, e.g. 64x4")
    args = ap.parse_args()
    mesh = None
    if args.mesh_shape:
        from repro.launch.mesh import make_mesh_shape

        mesh = make_mesh_shape(args.mesh_shape)

    if args.all:
        failures = []
        arch_list = args.archs.split(",") if args.archs else list(ARCH_IDS)
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for aid in arch_list:
            cfg = get_arch(aid)
            for shape in cfg.shapes():
                for mp in meshes:
                    try:
                        run_cell(aid, shape.name, mp, out_dir=args.out,
                                 tag=args.tag, opt=args.opt,
                                 tokens_budget=args.tokens_budget,
                                 remat=args.remat, mesh=mesh)
                    except Exception as e:  # noqa: BLE001
                        failures.append((aid, shape.name, mp, repr(e)))
                        traceback.print_exc()
        if failures:
            print(f"FAILED cells: {failures}")
            raise SystemExit(1)
        print("all cells passed")
        return
    run_cell(args.arch, args.shape, args.multi_pod, out_dir=args.out,
             tag=args.tag, opt=args.opt, tokens_budget=args.tokens_budget,
             remat=args.remat, mesh=mesh)


if __name__ == "__main__":
    main()
