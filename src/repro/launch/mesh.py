"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state. Single pod: (16, 16) = 256 chips, axes (data, model). Multi-pod:
(2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis is a pure
data-parallel/FSDP axis crossing the inter-pod links.

``compat_make_mesh`` absorbs the ``axis_types=`` API drift: newer jax
accepts (and eventually wants) explicit ``jax.sharding.AxisType.Auto``
axis types; jax<=0.4.x has neither the kwarg nor the enum, and its meshes
are Auto-typed implicitly — so omitting the kwarg there is semantically
identical.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax


def compat_make_mesh(shape: Tuple[int, ...], axes: Sequence[str]):
    """jax.make_mesh with Auto axis types across jax versions."""
    try:
        return jax.make_mesh(
            shape, tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(shape),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_debug_mesh(model: int = 4, data: int = 2):
    """Small host-device mesh for tests (requires device_count >= data*model)."""
    return compat_make_mesh((data, model), ("data", "model"))


def make_mesh_shape(spec: str):
    """Custom logical view over the same chips, e.g. '64x4' -> (data, model).

    §Perf: the (data, model) SPLIT of a pod is a tuning knob — small models
    waste ICI at model=16 (row-parallel all-reduce and residual-stream bytes
    scale with tokens/device). The pod hardware is unchanged; only the
    logical mesh differs from the baseline (16, 16)."""
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 2:
        return compat_make_mesh(dims, ("data", "model"))
    assert len(dims) == 3, dims
    return compat_make_mesh(dims, ("pod", "data", "model"))
