import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Dry-run profiler: rank a compiled cell's HLO ops by trip-weighted bytes.

This is the container's stand-in for a TPU trace: it shows WHERE the
roofline's memory/collective terms come from, per op kind and per source
line, so §Perf hypotheses target the real dominators.

  PYTHONPATH=src python -m repro.launch.profile_cell \
      --arch stablelm-3b --shape train_4k [--opt] [--top 30]
"""
import argparse
import collections
import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.roofline import (
    _CONST_RE,
    _DEF_RE,
    _ELEMENTWISE,
    _OPND_RE,
    _WHILE_RE,
    _dims,
    _nbytes,
)

_METADATA_RE = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass
class OpCost:
    kind: str
    name: str
    bytes: float
    trips: float
    op_name: str = ""

    @property
    def total(self) -> float:
        return self.bytes * self.trips


def profile_text(hlo_text: str, top: int = 30):
    lines = hlo_text.splitlines()
    symbols: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            symbols[m.group(1)] = (m.group(2), _dims(m.group(3)))

    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for ln in lines:
        s = ln.rstrip()
        if cur is None:
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                name = s.split("(")[0].strip().lstrip("ENTRY ").strip().lstrip("%")
                cur = name
                comps[cur] = []
                if s.startswith("ENTRY"):
                    entry = cur
        else:
            if s.strip() == "}":
                cur = None
            else:
                comps[cur].append(s.strip())

    # computation -> trip multiplier, resolved from the while nest
    trip_of: Dict[str, float] = {c: 0.0 for c in comps}
    whiles: Dict[str, List[Tuple[str, str]]] = {
        c: [_WHILE_RE.search(l).groups() for l in body if _WHILE_RE.search(l)]
        for c, body in comps.items()
    }

    def cond_trip(cond: str) -> int:
        consts = []
        for ln in comps.get(cond, []):
            consts += [int(x) for x in _CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    def walk(name: str, mult: float, depth=0):
        if depth > 24:
            return
        trip_of[name] = trip_of.get(name, 0.0) + mult
        for cond, body in whiles.get(name, []):
            walk(body, mult * cond_trip(cond), depth + 1)

    if entry:
        walk(entry, 1.0)

    ops: List[OpCost] = []
    for cname, body in comps.items():
        mult = trip_of.get(cname, 0.0)
        if mult <= 0:
            continue
        for ln in body:
            md = _DEF_RE.match(ln)
            if not md:
                continue
            if any(f" {t}(" in ln for t in (
                "tuple", "get-tuple-element", "parameter", "bitcast",
                "constant")):
                continue
            out_bytes = _nbytes(md.group(2), _dims(md.group(3)))
            kind = ln.split("=", 1)[1].strip().split("(")[0].split()[-1]
            if kind in ("dynamic-update-slice", "scatter"):
                argpart = ln.split("(", 1)[1] if "(" in ln else ""
                opnds = _OPND_RE.findall(argpart)
                b = sum(
                    _nbytes(*symbols[o]) for o in opnds[1:2] if o in symbols
                )
            elif kind in _ELEMENTWISE:
                b = out_bytes
            else:
                b = out_bytes
                argpart = ln.split("(", 1)[1] if "(" in ln else ""
                for o in _OPND_RE.findall(argpart)[:8]:
                    if o in symbols:
                        b += _nbytes(*symbols[o])
            mm = _METADATA_RE.search(ln)
            kernel_ref = "KERNEL_" in ln
            shape = f"{md.group(2)}[{md.group(3)}]"
            name = (mm.group(1) if mm else "") or ""
            ops.append(OpCost(
                "KERNEL_ref/" + kind if kernel_ref else kind,
                md.group(1), b, mult, f"{shape} {name}",
            ))

    by_kind = collections.Counter()
    for o in ops:
        by_kind[o.kind] += o.total
    return ops, by_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--tokens-budget", type=int, default=8192)
    ap.add_argument("--top", type=int, default=30)
    args = ap.parse_args()

    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, fargs, model, plan = dryrun.build_cell(
        args.arch, args.shape, mesh, opt=args.opt, remat=args.remat,
        tokens_budget=args.tokens_budget,
    )
    compiled = fn.lower(*fargs).compile()
    ops, by_kind = profile_text(compiled.as_text(), args.top)

    print(f"== bytes by op kind ({args.arch} x {args.shape}"
          f"{' opt' if args.opt else ''}) ==")
    for kind, b in by_kind.most_common(20):
        print(f"  {kind:28s} {b/2**30:10.2f} GiB")
    print(f"\n== top {args.top} ops by trip-weighted bytes ==")
    for o in sorted(ops, key=lambda o: -o.total)[: args.top]:
        tag = ".." + o.op_name[-88:] if len(o.op_name) > 90 else o.op_name
        print(f"  {o.total/2**30:9.2f} GiB  x{o.trips:<5.0f} {o.kind:22s} {tag}")


if __name__ == "__main__":
    main()
