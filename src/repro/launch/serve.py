"""Serving launcher: batched requests against a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.core import analysis
from repro.models.model import Model
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    plan = analysis.build_plan(cfg, None, n_groups=2)
    model = Model(cfg, plan)
    params = jax.jit(model.init)(jax.random.key(args.seed))

    engine = Engine(
        cfg, plan, params,
        ServeConfig(slots=args.slots, ctx_len=128),
    )
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for i in range(args.requests):
        engine.submit(
            Request(
                request_id=i,
                prompt=rng.integers(
                    0, cfg.vocab, size=args.prompt_len
                ).astype(np.int32),
                max_new_tokens=args.max_new,
            )
        )
    done = engine.run_until_done()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(
        f"[serve] {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
        f"({total_tokens/dt:.1f} tok/s, slots={args.slots})"
    )
    for r in done[:4]:
        print(f"  req{r.request_id}: {r.output}")


if __name__ == "__main__":
    main()
