"""Roofline analysis of compiled artifacts (TPU v5e model).

Three terms, all in seconds per step, derived from the dry-run's compiled
module (per-device partitioned program):

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

IMPORTANT measurement note (verified by probe): ``compiled.cost_analysis()``
counts while-loop bodies ONCE — a scanned 48-layer model would be
undercounted ~50x. This module therefore re-derives FLOPs / bytes /
collective bytes from the compiled HLO text with a symbol table and
**trip-count multiplication** for while loops (trip counts are recovered
from the s32 bound constants that XLA clones into each loop's condition
computation). cost_analysis() is kept as a cross-check on 1-trip modules.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z]+\d*(?:e\d+m\d+\w*)?)\[([\d,]*)\]"
)
_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\d+m\d+\w*)?)\[([\d,]*)\]")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)"
)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALL_RE = re.compile(r"\b(?:call|async-start)\(")

_ELEMENTWISE = frozenset(
    "add subtract multiply divide exponential tanh maximum minimum select "
    "compare convert negate rsqrt sqrt log and or not xor power abs sign "
    "floor ceil clamp broadcast iota reduce exponential-minus-one".split()
)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to one flat dict.

    jax<=0.4.x returns a list with one per-program dict; newer jax returns
    the dict directly; some backends return None.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if isinstance(ca, dict) else {}


def _dims(dim_str: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in dim_str.split(",")) if dim_str else ()


def _nbytes(dtype: str, dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class HloCosts:
    """Trip-count-aware totals for one compiled (per-device) module."""

    flops: float = 0.0
    bytes_accessed: float = 0.0
    kernel_ref_bytes: float = 0.0  # ref-path traffic the Pallas kernel replaces
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def collective_count(self) -> int:
        return int(sum(self.coll_count.values()))

    def describe_collectives(self) -> str:
        rows = [
            f"{op}: {int(self.coll_count.get(op, 0))} ops, "
            f"{self.coll_bytes.get(op, 0)/1e6:.1f} MB"
            for op in COLLECTIVE_OPS
            if self.coll_count.get(op, 0)
        ]
        return "; ".join(rows) if rows else "none"


def parse_hlo_costs(hlo_text: str) -> HloCosts:
    lines = hlo_text.splitlines()

    # ---- pass 1: module-wide symbol table (instruction -> dtype/dims) ------
    symbols: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            symbols[m.group(1)] = (m.group(2), _dims(m.group(3)))

    # ---- pass 2: split into computations ----------------------------------
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for ln in lines:
        s = ln.rstrip()
        if cur is None:
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                name = s.split("(")[0].strip().lstrip("ENTRY ").strip().lstrip("%")
                cur = name
                comps[cur] = []
                if s.startswith("ENTRY"):
                    entry = cur
        else:
            if s.strip() == "}":
                cur = None
            else:
                comps[cur].append(s.strip())
    if entry is None and comps:
        entry = next(iter(comps))

    # ---- logical-bf16 detection --------------------------------------------
    # XLA:CPU's float-normalization materializes logical bf16 values as f32
    # (convert(bf16)->f32 chains). The TPU target keeps them bf16, so
    # collectives fed by such converts are counted at HALF (logical) bytes.
    def _root_convert_from_bf16(comp: str) -> bool:
        body = comps.get(comp, [])
        for ln in body:
            if ln.startswith("ROOT "):
                m = _DEF_RE.match(ln)
                if not m or not m.group(2).startswith("f32"):
                    return False
                if " convert(" not in ln:
                    return False
                src = _OPND_RE.findall(ln.split(" convert(", 1)[1])
                if not src:
                    return False
                # source defined inside this computation
                for l2 in body:
                    m2 = _DEF_RE.match(l2)
                    if m2 and m2.group(1) == src[0]:
                        return m2.group(2) == "bf16"
        return False

    _fusion_root_bf16: Dict[str, bool] = {}
    logical_bf16: set = set()
    _CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m or not m.group(2).startswith("f32"):
            continue
        if " convert(" in ln and " fusion(" not in ln:
            src = _OPND_RE.findall(ln.split(" convert(", 1)[1])
            if src and symbols.get(src[0], ("",))[0] == "bf16":
                logical_bf16.add(m.group(1))
        elif " fusion(" in ln:
            mc = _CALLS_RE.search(ln)
            if mc:
                fc = mc.group(1)
                if fc not in _fusion_root_bf16:
                    _fusion_root_bf16[fc] = _root_convert_from_bf16(fc)
                if _fusion_root_bf16[fc]:
                    logical_bf16.add(m.group(1))

    # ---- per-computation raw costs + while edges ---------------------------
    raw: Dict[str, HloCosts] = {}
    whiles: Dict[str, List[Tuple[str, str]]] = {}
    calls: Dict[str, List[str]] = {}
    for name, body in comps.items():
        hc = HloCosts()
        w: List[Tuple[str, str]] = []
        cl: List[str] = []
        for ln in body:
            mw = _WHILE_RE.search(ln)
            if mw:
                w.append((mw.group(1), mw.group(2)))
            md = _DEF_RE.match(ln)
            out_bytes = 0
            if md:
                out_bytes = _nbytes(md.group(2), _dims(md.group(3)))
            # ---- flops: dot ops -------------------------------------------
            if " dot(" in ln and md:
                out_dims = _dims(md.group(3))
                inside = ln.split(" dot(", 1)[1]
                opnds = _OPND_RE.findall(inside)
                mc = _CDIMS_RE.search(ln)
                if opnds and mc and opnds[0] in symbols:
                    lhs_dims = symbols[opnds[0]][1]
                    k = 1
                    for ci in _dims(mc.group(1)):
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
                    out_n = 1
                    for d in out_dims:
                        out_n *= d
                    hc.flops += 2.0 * out_n * k
            # ---- bytes: fusion-aware accounting ------------------------------
            # tuples/GTE/bitcast are metadata (no traffic); standalone
            # elementwise ops count output only (TPU fuses them with their
            # producer); fusions/dots/copies/DUS count operands + output.
            if md and not any(
                f" {t}(" in ln
                for t in ("tuple", "get-tuple-element", "parameter", "bitcast",
                          "constant")
            ):
                kind = ln.split("=", 1)[1].strip().split("(")[0].split()[-1]
                elementwise = kind in _ELEMENTWISE

                def _opnd_bytes(opnd: str) -> float:
                    b1 = float(_nbytes(*symbols[opnd]))
                    # logically-bf16 values materialized f32 by the CPU
                    # backend count at TPU-target (bf16) size
                    return b1 * 0.5 if opnd in logical_bf16 else b1

                out_b = float(out_bytes)
                if md.group(1) in logical_bf16:
                    out_b *= 0.5
                if kind in ("dynamic-update-slice", "scatter"):
                    # in-place on TPU (donated/aliased): traffic = the update
                    # operand only, not the full buffer
                    argpart = ln.split("(", 1)[1] if "(" in ln else ""
                    opnds = _OPND_RE.findall(argpart)
                    b = 0.0
                    for opnd in opnds[1:2]:
                        if opnd in symbols:
                            b += _opnd_bytes(opnd)
                elif elementwise:
                    b = out_b
                else:
                    b = out_b
                    argpart = ln.split("(", 1)[1] if "(" in ln else ""
                    for opnd in _OPND_RE.findall(argpart)[:8]:
                        if opnd in symbols:
                            b += _opnd_bytes(opnd)
                if "KERNEL_" in ln:
                    # ref-path internals of a Pallas-kernel region: on the TPU
                    # target this traffic stays in VMEM; accounted separately
                    # and replaced by the kernel's streaming bytes.
                    hc.kernel_ref_bytes += b
                else:
                    hc.bytes_accessed += b
            # ---- collectives ----------------------------------------------
            for op in COLLECTIVE_OPS:
                if f" {op}(" in ln or f" {op}-start(" in ln:
                    cb = 0
                    argpart = ln.split("(", 1)[1] if "(" in ln else ""
                    for opnd in _OPND_RE.findall(argpart):
                        if opnd in symbols:
                            b1 = _nbytes(*symbols[opnd])
                            if opnd in logical_bf16:
                                b1 *= 0.5  # CPU f32-materialized bf16 value
                            cb += b1
                    if cb == 0 and md:
                        cb = out_bytes
                    if "_promoted" in ln:
                        # CPU-backend artifact: XLA promotes bf16/f16
                        # reductions to f32 on host ("%add.clone_promoted").
                        # The TPU target reduces at the original dtype —
                        # count the pre-promotion bytes.
                        cb *= 0.5
                    hc.coll_bytes[op] = hc.coll_bytes.get(op, 0.0) + cb
                    hc.coll_count[op] = hc.coll_count.get(op, 0) + 1
                    break
        raw[name] = hc
        whiles[name] = w
        calls[name] = cl

    def trip_count(cond: str) -> int:
        consts = []
        for ln in comps.get(cond, []):
            consts += [int(x) for x in _CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    memo: Dict[str, HloCosts] = {}

    def total(name: str, depth: int = 0) -> HloCosts:
        if name in memo or depth > 24:
            return memo.get(name, HloCosts())
        base = raw.get(name, HloCosts())
        acc = HloCosts(
            flops=base.flops,
            bytes_accessed=base.bytes_accessed,
            kernel_ref_bytes=base.kernel_ref_bytes,
            coll_bytes=dict(base.coll_bytes),
            coll_count=dict(base.coll_count),
        )
        for cond, bodyc in whiles.get(name, []):
            t = trip_count(cond)
            sub = total(bodyc, depth + 1)
            acc.flops += t * sub.flops
            acc.bytes_accessed += t * sub.bytes_accessed
            acc.kernel_ref_bytes += t * sub.kernel_ref_bytes
            for op, v in sub.coll_bytes.items():
                acc.coll_bytes[op] = acc.coll_bytes.get(op, 0.0) + t * v
            for op, v in sub.coll_count.items():
                acc.coll_count[op] = acc.coll_count.get(op, 0) + t * v
        memo[name] = acc
        return acc

    return total(entry) if entry else HloCosts()


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    collective_bytes_per_dev: float
    collective_count: int
    n_devices: int
    model_flops: float  # 6*N*D-style global useful FLOPs
    overlap: float = 0.0  # fraction of collective hidden under compute

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(ts, key=ts.get)

    @property
    def t_step(self) -> float:
        return max(self.t_compute, self.t_memory) + (
            1.0 - self.overlap
        ) * self.t_collective

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_dev * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """ideal_time / predicted_time, ideal = useful FLOPs at peak."""
        ideal = self.model_flops / (self.n_devices * PEAK_FLOPS)
        return ideal / self.t_step if self.t_step else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_step_s": self.t_step,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_count": self.collective_count,
        }


def kernel_hbm_bytes(cfg, shape, model_size: int, dp_size: int,
                     microbatches: int, remat_full: bool = True) -> float:
    """Per-device HBM traffic of the Pallas-kernel regions (the fused TPU
    target), substituted for the reference path's materialized intermediates.

    flash attention fwd: read q,k,v + write o (KV streamed through VMEM);
    bwd ~ 3x fwd; full remat adds one fwd. SSD: read x,B,C,dt + write y.
    Decode: the fused decode-attention reads the KV cache once per step.
    """
    B, S = shape.global_batch, shape.seq_len
    bpe = 2  # bf16
    mult = 1.0 if shape.kind != "train" else (4.0 + (1.0 if remat_full else 0.0))
    total = 0.0
    tokens_dev = max(B // max(dp_size, 1), 1) * S / max(microbatches, 1)

    if cfg.n_heads:
        hd = cfg.resolved_head_dim
        H_loc = max(cfg.n_heads // model_size, 1)
        K_loc = max(cfg.kv_heads // model_size, 1)
        L = (
            cfg.n_layers
            if cfg.family != "hybrid"
            else -(-cfg.n_layers // cfg.hybrid_attn_every)
        )
        if shape.kind == "decode":
            # cache read once (k+v) + q/o negligible
            b_loc = max(B // max(dp_size, 1), 1)
            per_layer = 2 * b_loc * S * K_loc * hd * bpe
            total += L * per_layer
        else:
            per_layer_mb = tokens_dev * (H_loc * 2 + K_loc * 2) * hd * bpe
            total += L * per_layer_mb * microbatches * mult

    if cfg.moe is not None and shape.kind != "decode":
        # moe_permute row-copy kernel: dispatch writes 1.25*Tk rows +
        # reads Tk token rows; combine reads Tk + writes T rows (x read+write
        # on the TPU DMA path)
        rows = tokens_dev * cfg.moe.top_k * 2.25 + tokens_dev
        per_layer_mb = 2.0 * rows * cfg.d_model * bpe
        total += cfg.n_layers * per_layer_mb * microbatches * mult

    if cfg.ssm is not None:
        inner = cfg.ssm.expand * cfg.d_model
        inner_loc = max(inner // model_size, 1)
        N = cfg.ssm.state_dim
        L = cfg.n_layers
        if shape.kind == "decode":
            b_loc = max(B // max(dp_size, 1), 1)
            H_loc = max((inner // cfg.ssm.head_dim) // model_size, 1)
            total += L * b_loc * H_loc * cfg.ssm.head_dim * N * 4 * 2  # state rw
        else:
            per_layer_mb = tokens_dev * (2 * inner_loc + 2 * N) * bpe
            total += L * per_layer_mb * microbatches * mult
    return total


def model_flops(cfg, shape, n_active: Optional[int] = None) -> float:
    """Useful-work FLOPs: 6*N*D train, 2*N*D inference + attention terms."""
    N = n_active if n_active is not None else cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        base = 6.0 * N * B * S
        attn_mult = 3.0  # fwd + 2x bwd
    elif shape.kind == "prefill":
        base = 2.0 * N * B * S
        attn_mult = 1.0
    else:  # decode: one token per sequence
        base = 2.0 * N * B
        attn_mult = 1.0

    attn = 0.0
    if cfg.n_heads:
        hd = cfg.resolved_head_dim
        H = cfg.n_heads
        L = (
            cfg.n_layers
            if cfg.family != "hybrid"
            else -(-cfg.n_layers // cfg.hybrid_attn_every)
        )
        if shape.kind == "decode":
            attn = 4.0 * B * H * hd * S * L
        else:
            causal = 0.5 if cfg.causal else 1.0
            if cfg.local_global_pattern and cfg.local_window < S:
                # half the layers see only the window
                kv_eff = (S + cfg.local_window) / 2
            else:
                kv_eff = S
            attn = 4.0 * B * S * kv_eff * H * hd * L * causal
        attn *= attn_mult
    return base + attn
