"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \\
      --steps 50 [--reduced] [--ga-search] [--ckpt-dir /tmp/ckpt]

--reduced runs the family-reduced config on this container (real compute);
the full config is for real TPU slices. --ga-search runs the paper's GA
over the arch's offload units with the analytic plan evaluator first and
trains under the found plan.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_arch, get_shape
from repro.core import analysis
from repro.data.pipeline import DataConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="family-reduced config (CPU-runnable)")
    ap.add_argument("--batch", type=int, default=0,
                    help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = get_shape(args.shape)
    import dataclasses

    if args.batch or args.seq:
        shape = dataclasses.replace(
            shape,
            global_batch=args.batch or shape.global_batch,
            seq_len=args.seq or shape.seq_len,
        )
    plan = analysis.build_plan(cfg, None, n_groups=2 if args.reduced else 4)
    tcfg = TrainConfig(
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        save_every=args.save_every,
        compress_grads=args.compress_grads,
        seed=args.seed,
    )
    trainer = Trainer(cfg, shape, plan, mesh=None, tcfg=tcfg,
                      data=DataConfig())
    summary = trainer.run()
    print(f"[train] done: {summary}")


if __name__ == "__main__":
    main()
