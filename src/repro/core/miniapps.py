"""The paper's evaluation applications as LoopPrograms + runnable JAX impls.

Two levels per app, mirroring the paper's verification environment:

1. **LoopProgram** — the static structure the offload search operates on:
   loop statements, pgcc-style classes, variable read/write sets, trip
   counts and FLOP counts. Gene lengths match the paper exactly:
   Himeno = 13 offloadable loops, NAS.FT = 65 offloadable of 82 total.

2. **Runnable implementation** (``himeno_run`` / ``nasft_run``) — the same
   computation in JAX, where each offloadable loop executes either on the
   "CPU path" (pure NumPy, interpreter-rate) or the "accelerator path"
   (jitted JAX) according to the genome. This gives the GA a *measured*
   verification environment on this container and gives PCAST real
   CPU-vs-accelerator outputs to diff.

Sizes default to scaled-down grids so measured GA runs finish quickly;
the LoopProgram carries the paper-scale sizes for the analytic evaluator.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.loopir import Loop, LoopClass, LoopProgram, SeqRegion, Var

F32 = 4  # bytes
C64 = 8  # bytes (two f32) — NPB FT uses complex; f32 pairs here


# ===========================================================================
# Himeno benchmark (Poisson solver, Jacobi iteration) — 13 offloadable loops
# ===========================================================================


def himeno_program(
    grid: Tuple[int, int, int] = (128, 128, 256), nn: int = 100
) -> LoopProgram:
    """Himeno 'M' class by default. 19-point-ish stencil, 34 flops/cell.

    Loop inventory (matching the paper's gene length 13):
    10 initializer loops (initmt splits per array: a0..a3, b0..b2, c0..c2 —
    the real initmt writes each coefficient plane in its own statement),
    + p/wrk/bnd init, + the Jacobi stencil nest, + the pressure copy nest,
    + the final residual reduction. The time-step loop itself is sequential
    (NOT offloadable, not a gene) — it is the SeqRegion the paper's bulk
    transfer must cross to win.
    """
    i, j, k = grid
    cells = i * j * k
    plane = F32 * cells

    gv = dict(is_global=True, init_external=True)  # file-scope arrays in C
    vars_ = [
        Var("p", plane, "himenobmtxpa.c", **gv),
        Var("a", 4 * plane, "himenobmtxpa.c", **gv),
        Var("b", 3 * plane, "himenobmtxpa.c", **gv),
        Var("c", 3 * plane, "himenobmtxpa.c", **gv),
        Var("bnd", plane, "himenobmtxpa.c", **gv),
        Var("wrk1", plane, "himenobmtxpa.c", **gv),
        Var("wrk2", plane, "himenobmtxpa.c", **gv),
        Var("gosa", F32, "himenobmtxpa.c", is_global=False),
    ]

    inits = []
    for name, writes, comps in [
        ("init_a0", "a", 1), ("init_a1", "a", 1), ("init_a2", "a", 1),
        ("init_a3", "a", 1), ("init_b", "b", 3), ("init_c", "c", 3),
        ("init_p", "p", 1), ("init_wrk1", "wrk1", 1),
        ("init_wrk2", "wrk2", 1), ("init_bnd", "bnd", 1),
    ]:
        inits.append(
            Loop(
                name=name,
                klass=LoopClass.TIGHT,  # simple triple nests: kernels-able
                trip=i,
                inner_trip=j * k * comps,
                flops_per_iter=1.0,
                reads=frozenset(),
                writes=frozenset({writes}),
                file="himenobmtxpa.c",
            )
        )

    stencil = Loop(
        name="jacobi_stencil",
        klass=LoopClass.TIGHT,
        trip=i - 2,
        inner_trip=(j - 2) * (k - 2),
        flops_per_iter=34.0,
        reads=frozenset({"p", "a", "b", "c", "bnd", "wrk1"}),
        writes=frozenset({"wrk2", "gosa"}),
        file="himenobmtxpa.c",
        parent_seq="jacobi_iter",
    )
    copy = Loop(
        name="jacobi_copy",
        klass=LoopClass.TIGHT,
        trip=i - 2,
        inner_trip=(j - 2) * (k - 2),
        flops_per_iter=1.0,
        reads=frozenset({"wrk2"}),
        writes=frozenset({"p"}),
        file="himenobmtxpa.c",
        parent_seq="jacobi_iter",
    )
    residual = Loop(
        name="final_residual",
        klass=LoopClass.VECTOR_ONLY,  # scalar reduction: vectorizable only
        trip=i - 2,
        inner_trip=(j - 2) * (k - 2),
        flops_per_iter=2.0,
        reads=frozenset({"p", "bnd"}),
        writes=frozenset({"gosa"}),
        file="himenobmtxpa.c",
    )
    # the sequential time-step driver: found by Clang, rejected by pgcc
    driver = Loop(
        name="jacobi_driver",
        klass=LoopClass.NOT_OFFLOADABLE,
        trip=nn,
        inner_trip=1,
        flops_per_iter=2.0,
        reads=frozenset({"gosa"}),
        writes=frozenset({"gosa"}),
        file="himenobmtxpa.c",
        sequential_carry=True,
    )

    return LoopProgram(
        name="himeno",
        loops=tuple(inits + [stencil, copy, residual, driver]),
        vars=tuple(vars_),
        seq_regions=(SeqRegion("jacobi_iter", nn),),
        description=f"Himeno {i}x{j}x{k}, {nn} Jacobi iterations",
    )


# ===========================================================================
# NAS.FT (3-D FFT PDE solver) — 82 loops, 65 offloadable (paper counts)
# ===========================================================================


def nasft_program(
    grid: Tuple[int, int, int] = (256, 256, 128), niter: int = 6
) -> LoopProgram:
    """NPB FT-style structure (class A dims by default).

    Per iteration: evolve (pointwise exp multiply), 3 cffts passes (each:
    tilt copy-in, log2(n) butterfly stage loops, copy-out), checksum.
    Butterfly stage loops are NON-TIGHT (stride-dependent inner bounds) —
    the loops the previous method's `kernels`-only directive could not
    offload and this paper's `parallel loop` expansion recovers. RNG-based
    initial conditions carry a sequential dependence -> vector_only/excluded.

    Loop count bookkeeping (= paper's 82 total / 65 offloadable):
    the generator below emits exactly 82 loop statements of which 65 are
    offloadable (the paper: "NAS.FT has 82 for statements but many cannot
    be GPU-processed; gene length 65") — asserted at the end.
    """
    nx, ny, nz = grid
    n = nx * ny * nz
    u_bytes = C64 * n  # fp32 complex pair

    vars_ = [
        Var("u0", u_bytes, "ft.c", is_global=True, init_external=True),
        Var("u1", u_bytes, "ft.c", is_global=True, init_external=True),
        Var("twiddle", F32 * n, "ft.c", is_global=True, init_external=True),
        Var("indexmap", F32 * n, "ft.c", is_global=True),
        Var("scratch", u_bytes, "fft3d.c", is_global=True),
        # cfftz working set: fftblock pencils staged through cache/VMEM
        Var("pencil", C64 * 16 * max(nx, ny, nz), "fft3d.c"),
        Var("roots", C64 * max(nx, ny, nz), "fft3d.c", is_global=True),
        Var("chk", C64, "ft.c"),
    ]

    loops = []

    def L(name, klass, trip, inner, flops, reads, writes, file="ft.c",
          parent=None, seq_carry=False):
        loops.append(
            Loop(
                name=name, klass=klass, trip=trip, inner_trip=inner,
                flops_per_iter=flops, reads=frozenset(reads),
                writes=frozenset(writes), file=file, parent_seq=parent,
                sequential_carry=seq_carry,
            )
        )

    # --- setup ---------------------------------------------------------
    for d in range(3):
        L(f"indexmap_{d}", LoopClass.TIGHT, nx, ny * nz // nx if d else ny * nz,
          4.0, [], ["indexmap"])
    L("zero_u0", LoopClass.TIGHT, nz, nx * ny, 1.0, [], ["u0"])
    # vranlc: linear-congruential RNG with a sequential carry — the serial
    # Amdahl fraction that bounds the whole-app speedup (stays on the CPU)
    L("init_rng_seeds", LoopClass.NOT_OFFLOADABLE, nz, 1, 10.0, [], ["u1"],
      seq_carry=True)
    L("init_rng_fill", LoopClass.NOT_OFFLOADABLE, nz, nx * ny, 72.0, ["u1"],
      ["u1"], seq_carry=True)
    L("twiddle_table", LoopClass.TIGHT, nx, ny * nz // nx, 6.0, ["indexmap"],
      ["twiddle"])
    L("indexmap_fold", LoopClass.TIGHT, nx, ny * nz // nx, 2.0,
      ["indexmap"], ["indexmap"])
    L("roots_re", LoopClass.VECTOR_ONLY, max(nx, ny, nz), 1, 4.0, [],
      ["roots"], file="fft3d.c")
    L("roots_im", LoopClass.VECTOR_ONLY, max(nx, ny, nz), 1, 4.0, [],
      ["roots"], file="fft3d.c")
    L("roots_scale", LoopClass.VECTOR_ONLY, max(nx, ny, nz), 1, 2.0,
      ["roots"], ["roots"], file="fft3d.c")
    L("pencil_warm", LoopClass.TIGHT, 16, max(nx, ny, nz), 1.0, [],
      ["pencil"], file="fft3d.c")
    L("indexmap_scale", LoopClass.TIGHT, nx, ny * nz // nx, 1.0,
      ["indexmap"], ["indexmap"])

    # --- per-iteration region -------------------------------------------
    L("evolve", LoopClass.TIGHT, nz, nx * ny, 6.0, ["u0", "twiddle"],
      ["u0", "u1"], parent="step_iter")

    import math

    stage_counts = {0: int(math.log2(nx)), 1: int(math.log2(ny)),
                    2: int(math.log2(nz))}
    dims = {0: nx, 1: ny, 2: nz}
    for d in range(3):
        planes = n // dims[d]
        stages = stage_counts[d]
        # ---- heavy scratch-chained loop statements (the real cfftz body:
        # one loop STATEMENT executes for all log2(n) stages) --------------
        L(f"cffts{d+1}_copyin", LoopClass.TIGHT, planes, dims[d], 2.0,
          ["u1"], ["scratch"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_stage_even", LoopClass.TIGHT, planes,
          (dims[d] // 2) * ((stages + 1) // 2), 10.0, ["scratch", "roots"],
          ["scratch"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_stage_odd", LoopClass.TIGHT, planes,
          (dims[d] // 2) * (stages // 2), 10.0, ["scratch", "roots"],
          ["scratch"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_copyout", LoopClass.TIGHT, planes, dims[d], 2.0,
          ["scratch"], ["u1"], file="fft3d.c", parent="step_iter")
        # ---- light pencil-batch staging loops (cache-resident working set)
        L(f"cffts{d+1}_zero_pencil", LoopClass.TIGHT, 16, dims[d], 1.0,
          [], ["pencil"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_pencil_load", LoopClass.TIGHT, 16, dims[d], 2.0,
          ["scratch"], ["pencil"], file="fft3d.c", parent="step_iter")
        # blocked transposes: non-tight (ragged tile loops) — the loop
        # shapes the previous method's `kernels` could not accept
        L(f"cffts{d+1}_transpose_in", LoopClass.NON_TIGHT, 16, dims[d],
          2.0, ["pencil"], ["pencil"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_fftz2_lo", LoopClass.NON_TIGHT, 16, dims[d] // 2,
          5.0, ["pencil", "roots"], ["pencil"], file="fft3d.c",
          parent="step_iter")
        L(f"cffts{d+1}_fftz2_hi", LoopClass.NON_TIGHT, 16, dims[d] // 2,
          5.0, ["pencil", "roots"], ["pencil"], file="fft3d.c",
          parent="step_iter")
        L(f"cffts{d+1}_transpose_out", LoopClass.NON_TIGHT, 16, dims[d],
          2.0, ["pencil"], ["pencil"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_pencil_store", LoopClass.TIGHT, 16, dims[d], 2.0,
          ["pencil"], ["scratch"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_twiddle_prep", LoopClass.TIGHT, 16, dims[d], 3.0,
          ["roots"], ["pencil"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_edge_fix", LoopClass.NON_TIGHT, 16, dims[d], 2.0,
          ["pencil"], ["pencil"], file="fft3d.c", parent="step_iter")
        L(f"cffts{d+1}_pencil_scale", LoopClass.TIGHT, 16, dims[d], 1.0,
          ["pencil"], ["pencil"], file="fft3d.c", parent="step_iter")
    # inverse-FFT normalization: strided real/imag sweeps over u1 — the
    # paper's `parallel loop` expansion offloads these (non-tight)
    L("ifft_norm_re", LoopClass.NON_TIGHT, nz, nx * ny, 1.0, ["u1"], ["u1"],
      parent="step_iter")
    L("ifft_norm_im", LoopClass.NON_TIGHT, nz, nx * ny, 1.0, ["u1"], ["u1"],
      parent="step_iter")
    L("twiddle_refresh", LoopClass.TIGHT, 16, nx, 2.0, ["roots"],
      ["pencil"], parent="step_iter")
    L("evolve_mag", LoopClass.TIGHT, 16, nx, 2.0, ["pencil"], ["pencil"],
      parent="step_iter")
    L("checksum_zero", LoopClass.TIGHT, 1024, 1, 1.0, [], ["chk"],
      parent="step_iter")
    # checksum reductions over u1: not parallelizable, vectorizable ->
    # `parallel loop vector` (previous method left them on the CPU, which
    # also dragged u1 back across the link every iteration)
    L("checksum", LoopClass.VECTOR_ONLY, 1024, 1, 8.0, ["u1"], ["chk"],
      parent="step_iter")
    L("checksum_gather", LoopClass.VECTOR_ONLY, 1024, 1, 2.0, ["u1"],
      ["chk"], parent="step_iter")
    L("chk_scale", LoopClass.VECTOR_ONLY, 1024, 1, 2.0, ["chk"], ["chk"],
      parent="step_iter")
    L("chk_accum", LoopClass.VECTOR_ONLY, 1024, 1, 2.0, ["chk"], ["chk"],
      parent="step_iter")

    # --- warm-up / validation / drivers ---------------------------------
    L("warmup_touch", LoopClass.TIGHT, nz, nx * ny, 1.0, ["u0"], ["u0"])
    L("verify_scan", LoopClass.TIGHT, 1024, 1, 2.0, ["chk"], ["chk"])
    for name, trip in [
        ("verify_seq", niter), ("main_driver", niter), ("timer_clear", 16),
        ("timer_report", 16), ("ipow46_loop", 46), ("vranlc_outer", nz),
        ("vranlc_inner", 64), ("arg_parse", 4), ("setup_dims", 3),
        ("setup_layout", 3), ("print_results", 8), ("alloc_touch", 8),
        ("rand_warmup", 32), ("verify_compare", 6), ("epsilon_scan", 10),
    ]:
        L(name, LoopClass.NOT_OFFLOADABLE, trip, 1, 2.0, ["chk"], ["chk"],
          seq_carry=True)

    prog = LoopProgram(
        name="nasft",
        loops=tuple(loops),
        vars=tuple(vars_),
        seq_regions=(SeqRegion("step_iter", niter),),
        description=f"NAS.FT-style 3D FFT {nx}x{ny}x{nz}, {niter} iterations",
    )
    # paper counts: 82 for statements, 65 GPU-compilable (gene length)
    assert len(prog.loops) == 82, len(prog.loops)
    assert prog.gene_length == 65, prog.gene_length
    return prog


# ===========================================================================
# Heterogeneous pipeline miniapp (mixed-destination search target)
# ===========================================================================


def hetero_program(
    grid: Tuple[int, int, int] = (128, 128, 256), frames: int = 50
) -> LoopProgram:
    """A radar/beamforming-style per-frame pipeline where no single
    accelerator dominates — the mixed-destination search's showcase app
    (arXiv:2011.12431's "mixed offloading destination environment"):

    - ``stencil_a/b``: compute-dense tight nests -> the GPU's win;
    - ``scan_stage1..4``: FFT/IIR-like stages with a sequential carry —
      lane-rate on the GPU, full pipelined rate on the FPGA profile;
    - ``ctrl_gain``: a small host-coupled control loop whose data the
      sequential ``host_ctrl`` loop rewrites every frame — any offload
      pays a per-frame transfer bigger than the CPU just doing the work.

    12 offloadable loops = gene length 12; ``frame_iter`` is the
    sequential per-frame region the transfers must cross.
    """
    i, j, k = grid
    cells = i * j * k
    plane = F32 * cells

    vars_ = [
        Var("raw", plane, "pipeline.c", is_global=True, init_external=True),
        Var("field", plane, "pipeline.c", is_global=True),
        Var("tmp", plane, "pipeline.c", is_global=True),
        Var("coefs", plane, "pipeline.c", is_global=True),
        Var("spec", plane, "pipeline.c", is_global=True),
        Var("gains", F32 * 16384, "control.c", is_global=True,
            init_external=True),
        Var("acc", F32, "control.c"),
    ]

    loops = []

    def L(name, klass, trip, inner, flops, reads, writes,
          parent=None, seq_carry=False, file="pipeline.c"):
        loops.append(
            Loop(
                name=name, klass=klass, trip=trip, inner_trip=inner,
                flops_per_iter=flops, reads=frozenset(reads),
                writes=frozenset(writes), file=file, parent_seq=parent,
                sequential_carry=seq_carry,
            )
        )

    # setup (once per run)
    L("init_coefs", LoopClass.TIGHT, i, j * k, 1.0, [], ["coefs"])
    L("init_gains", LoopClass.VECTOR_ONLY, 16384, 1, 2.0, [], ["gains"],
      file="control.c")

    # per-frame pipeline
    L("load_frame", LoopClass.TIGHT, i, j * k, 2.0, ["raw"], ["field"],
      parent="frame_iter")
    L("stencil_a", LoopClass.TIGHT, i - 2, (j - 2) * (k - 2), 140.0,
      ["field", "coefs"], ["tmp"], parent="frame_iter")
    L("stencil_b", LoopClass.TIGHT, i - 2, (j - 2) * (k - 2), 140.0,
      ["tmp", "coefs"], ["field"], parent="frame_iter")
    L("scan_stage1", LoopClass.VECTOR_ONLY, i, j * k, 64.0,
      ["field"], ["spec"], parent="frame_iter", seq_carry=True)
    for s in (2, 3, 4):
        L(f"scan_stage{s}", LoopClass.VECTOR_ONLY, i, j * k, 64.0,
          ["spec"], ["spec"], parent="frame_iter", seq_carry=True)
    L("normalize", LoopClass.TIGHT, i, j * k, 3.0, ["spec", "gains"],
      ["spec"], parent="frame_iter")
    L("reduce_power", LoopClass.VECTOR_ONLY, i, j * k, 2.0, ["spec"],
      ["acc"], parent="frame_iter")
    L("ctrl_gain", LoopClass.VECTOR_ONLY, 16384, 1, 4.0, ["gains"],
      ["gains"], parent="frame_iter", file="control.c")

    # sequential host control: rewrites gains from the reduction every
    # frame (the host-coupling that pins ctrl_gain's data to the CPU)
    L("host_ctrl", LoopClass.NOT_OFFLOADABLE, 16384, 1, 3.0,
      ["acc", "gains"], ["gains"], parent="frame_iter", seq_carry=True,
      file="control.c")
    L("frame_driver", LoopClass.NOT_OFFLOADABLE, frames, 1, 2.0, ["acc"],
      ["acc"], seq_carry=True)

    prog = LoopProgram(
        name="hetero",
        loops=tuple(loops),
        vars=tuple(vars_),
        seq_regions=(SeqRegion("frame_iter", frames),),
        description=(
            f"heterogeneous per-frame pipeline {i}x{j}x{k}, "
            f"{frames} frames"
        ),
    )
    assert prog.gene_length == 12, prog.gene_length
    return prog


# ===========================================================================
# Runnable implementations (measured verification environment + PCAST)
# ===========================================================================


@dataclasses.dataclass
class HimenoState:
    p: np.ndarray
    a: np.ndarray
    b: np.ndarray
    c: np.ndarray
    bnd: np.ndarray
    wrk1: np.ndarray
    wrk2: np.ndarray


def himeno_init(grid: Tuple[int, int, int] = (17, 17, 33)) -> HimenoState:
    i, j, k = grid
    p = (np.arange(i, dtype=np.float32) ** 2 / (i - 1) ** 2)[:, None, None]
    p = np.broadcast_to(p, (i, j, k)).copy()
    return HimenoState(
        p=p,
        a=np.stack([np.ones((i, j, k), np.float32)] * 3
                   + [np.full((i, j, k), 1.0 / 6.0, np.float32)]),
        b=np.zeros((3, i, j, k), np.float32),
        c=np.ones((3, i, j, k), np.float32),
        bnd=np.ones((i, j, k), np.float32),
        wrk1=np.zeros((i, j, k), np.float32),
        wrk2=np.zeros((i, j, k), np.float32),
    )


def _himeno_stencil_np(s: HimenoState, omega: float = 0.8):
    """One Jacobi sweep (vectorized numpy = the oracle computation)."""
    p, a, b, c, bnd, wrk1 = s.p, s.a, s.b, s.c, s.bnd, s.wrk1
    I, J, K = p.shape
    c0, c1, c2 = slice(1, I - 1), slice(1, J - 1), slice(1, K - 1)
    s0 = (
        a[0, c0, c1, c2] * p[2:, c1, c2]
        + a[1, c0, c1, c2] * p[c0, 2:, c2]
        + a[2, c0, c1, c2] * p[c0, c1, 2:]
        + b[0, c0, c1, c2] * (p[2:, 2:, c2] - p[2:, :-2, c2]
                              - p[:-2, 2:, c2] + p[:-2, :-2, c2])
        + b[1, c0, c1, c2] * (p[c0, 2:, 2:] - p[c0, :-2, 2:]
                              - p[c0, 2:, :-2] + p[c0, :-2, :-2])
        + b[2, c0, c1, c2] * (p[2:, c1, 2:] - p[:-2, c1, 2:]
                              - p[2:, c1, :-2] + p[:-2, c1, :-2])
        + c[0, c0, c1, c2] * p[:-2, c1, c2]
        + c[1, c0, c1, c2] * p[c0, :-2, c2]
        + c[2, c0, c1, c2] * p[c0, c1, :-2]
        + wrk1[c0, c1, c2]
    )
    ss = (s0 * a[3, c0, c1, c2] - p[c0, c1, c2]) * bnd[c0, c1, c2]
    gosa = float((ss * ss).sum())
    wrk2 = p.copy()
    wrk2[c0, c1, c2] = p[c0, c1, c2] + omega * ss
    return wrk2, gosa


# jitted hot-loop implementations, built once and cached at module level:
# the measured verification environment times the COMPILED kernel's
# runtime (the paper's measured seconds are post-pgcc-compile runtimes;
# compile cost is why fitness caching exists, not part of the fitness),
# and a closure re-jitted per run would re-pay XLA compilation on every
# single wall-clocked measurement.
_JITTED: Dict[str, Any] = {}


def _himeno_sweep_jit():
    fn = _JITTED.get("himeno_sweep")
    if fn is None:
        import jax

        @jax.jit
        def sweep(p, a, b, c, bnd, wrk1):
            # identical arithmetic through jnp (shape-polymorphic slices)
            I, J, K = p.shape
            c0, c1, c2 = slice(1, I - 1), slice(1, J - 1), slice(1, K - 1)
            s0 = (
                a[0, c0, c1, c2] * p[2:, c1, c2]
                + a[1, c0, c1, c2] * p[c0, 2:, c2]
                + a[2, c0, c1, c2] * p[c0, c1, 2:]
                + b[0, c0, c1, c2] * (p[2:, 2:, c2] - p[2:, :-2, c2]
                                      - p[:-2, 2:, c2] + p[:-2, :-2, c2])
                + b[1, c0, c1, c2] * (p[c0, 2:, 2:] - p[c0, :-2, 2:]
                                      - p[c0, 2:, :-2] + p[c0, :-2, :-2])
                + b[2, c0, c1, c2] * (p[2:, c1, 2:] - p[:-2, c1, 2:]
                                      - p[2:, c1, :-2] + p[:-2, c1, :-2])
                + c[0, c0, c1, c2] * p[:-2, c1, c2]
                + c[1, c0, c1, c2] * p[c0, :-2, c2]
                + c[2, c0, c1, c2] * p[c0, c1, :-2]
                + wrk1[c0, c1, c2]
            )
            ss = (s0 * a[3, c0, c1, c2] - p[c0, c1, c2]) * bnd[c0, c1, c2]
            gosa = (ss * ss).sum()
            wrk2 = p.at[c0, c1, c2].add(0.8 * ss)
            return wrk2, gosa

        _JITTED["himeno_sweep"] = fn = sweep
    return fn


def himeno_run(
    grid: Tuple[int, int, int] = (17, 17, 33),
    nn: int = 4,
    jit_stencil: bool = True,
    dtype=np.float32,
):
    """Run the Jacobi solver; returns (p, gosa). ``jit_stencil`` switches the
    stencil between the jitted JAX path (offloaded) and numpy (host)."""
    import jax.numpy as jnp

    s = himeno_init(grid)

    if jit_stencil:
        sweep = _himeno_sweep_jit()
        pj = jnp.asarray(s.p, dtype)
        aj = jnp.asarray(s.a, dtype)
        bj = jnp.asarray(s.b, dtype)
        cj = jnp.asarray(s.c, dtype)
        bndj = jnp.asarray(s.bnd, dtype)
        w1j = jnp.asarray(s.wrk1, dtype)
        gosa = 0.0
        for _ in range(nn):
            pj, g = sweep(pj, aj, bj, cj, bndj, w1j)
            gosa = float(g)
        return np.asarray(pj, np.float32), gosa

    gosa = 0.0
    for _ in range(nn):
        wrk2, gosa = _himeno_stencil_np(s)
        s.p = wrk2
    return s.p, gosa


def _nasft_step_jit():
    fn = _JITTED.get("nasft_step")
    if fn is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(ut, k2, t):
            twiddle = jnp.exp(-4.0 * jnp.pi**2 * 1e-2 * t * k2)
            return jnp.fft.ifftn(ut * twiddle)

        _JITTED["nasft_step"] = fn = step
    return fn


def nasft_run(
    grid: Tuple[int, int, int] = (16, 16, 16),
    niter: int = 2,
    jit_fft: bool = True,
):
    """NAS.FT-style PDE: u1 = IFFT( exp(-4 pi^2 t |k|^2) * FFT(u0) ).

    Returns the per-iteration checksums (complex64 ndarray, shape (niter,)).
    ``jit_fft`` switches the FFT+evolve between jitted JAX and numpy."""
    import jax.numpy as jnp

    nx, ny, nz = grid
    rng = np.random.default_rng(314159)
    u0 = (rng.standard_normal((nz, ny, nx)) +
          1j * rng.standard_normal((nz, ny, nx))).astype(np.complex64)
    kz = np.fft.fftfreq(nz)[:, None, None]
    ky = np.fft.fftfreq(ny)[None, :, None]
    kx = np.fft.fftfreq(nx)[None, None, :]
    k2 = (kx**2 + ky**2 + kz**2).astype(np.float32)
    alpha = 1e-2

    def checksum(u1):
        idx = (np.arange(1024) * 17) % u1.size
        flat = np.asarray(u1).ravel()[idx]
        return complex(flat.sum() / u1.size)

    if jit_fft:
        step = _nasft_step_jit()
        ut = jnp.fft.fftn(jnp.asarray(u0))
        k2j = jnp.asarray(k2)
        sums = []
        for it in range(1, niter + 1):
            u1 = step(ut, k2j, jnp.float32(it))
            sums.append(checksum(np.asarray(u1)))
        return np.asarray(sums, np.complex64)

    ut = np.fft.fftn(u0)
    sums = []
    for it in range(1, niter + 1):
        tw = np.exp(-4.0 * np.pi**2 * alpha * it * k2)
        u1 = np.fft.ifftn(ut * tw)
        sums.append(checksum(u1))
    return np.asarray(sums, np.complex64)


# ===========================================================================
# Picklable genes->run callables (MeasuredEvaluator + process EvalPools)
# ===========================================================================
#
# ``MeasuredEvaluator`` wall-clocks ``run_fn(genes)``. The runnable
# implementations above expose ONE offload switch (jitted JAX vs numpy),
# so the run fn collapses the genome to the gene of the designated hot
# loop. Defined as frozen module-level dataclasses — not closures — so a
# ``ProcessPoolExecutor`` (``EvalPool(executor="process")``) can pickle
# the evaluator into its workers.


def _gene_index(prog: LoopProgram, loop_name: str) -> int:
    for idx, l in enumerate(prog.offloadable_loops):
        if l.name == loop_name:
            return idx
    raise KeyError(loop_name)


_HOT_GENES: Dict[Tuple[str, str], int] = {}


def _hot_gene(prog_fn, loop_name: str) -> int:
    """Memoized gene index of a program's hot loop: run fns sit inside
    MeasuredEvaluator's perf_counter window, so the LoopProgram must not
    be rebuilt per measurement."""
    key = (prog_fn.__name__, loop_name)
    if key not in _HOT_GENES:
        _HOT_GENES[key] = _gene_index(prog_fn(), loop_name)
    return _HOT_GENES[key]


@dataclasses.dataclass(frozen=True)
class HimenoRunFn:
    """genes -> run Himeno; the ``jacobi_stencil`` gene picks the path."""

    grid: Tuple[int, int, int] = (9, 9, 17)
    nn: int = 2

    def __call__(self, genes: Sequence[int]) -> None:
        hot = _hot_gene(himeno_program, "jacobi_stencil")
        himeno_run(self.grid, self.nn, jit_stencil=bool(genes[hot]))

    def cache_key(self, genes: Sequence[int]) -> str:
        """Canonical measurement key: the implementation only branches on
        the hot-loop gene, so genomes equal there run the *same*
        computation and share one wall-clock measurement (generation
        dedup + the persistent cache both collapse on this)."""
        hot = _hot_gene(himeno_program, "jacobi_stencil")
        return f"hot={int(bool(genes[hot]))}"

    @property
    def tag(self) -> str:
        """Cache tag for MeasuredEvaluator (captures the config)."""
        return f"himeno:{'x'.join(map(str, self.grid))}:nn{self.nn}"


@dataclasses.dataclass(frozen=True)
class NasftRunFn:
    """genes -> run NAS.FT; the ``evolve`` gene picks the path."""

    grid: Tuple[int, int, int] = (8, 8, 8)
    niter: int = 2

    def __call__(self, genes: Sequence[int]) -> None:
        hot = _hot_gene(nasft_program, "evolve")
        nasft_run(self.grid, self.niter, jit_fft=bool(genes[hot]))

    def cache_key(self, genes: Sequence[int]) -> str:
        """See :meth:`HimenoRunFn.cache_key`."""
        hot = _hot_gene(nasft_program, "evolve")
        return f"hot={int(bool(genes[hot]))}"

    @property
    def tag(self) -> str:
        return f"nasft:{'x'.join(map(str, self.grid))}:it{self.niter}"


MINIAPPS = {
    "himeno": himeno_program,
    "nasft": nasft_program,
    "hetero": hetero_program,
}
