"""Static analysis: build offload units + assign directives (paper Step 1-2).

The paper's flow: parse the code (Clang), find loop statements, let pgcc
classify each loop (kernels-able / parallel-able / vectorizable-only), and
exclude loops that fail GPU compilation. Here the "code" is an ArchConfig:
units are the stage groups of the model graph, and the directive per unit
comes from structural applicability tests (divisibility of heads/experts/
channels by the model axis — the exact analogue of "does pgcc accept the
directive on this loop").
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core.plan import Directive, ExecutionPlan, UnitPlan
from repro.models.sharding import MeshCtx, attn_tp_mode

DEFAULT_GROUPS = 4


def n_groups_for(cfg: ArchConfig, n_groups: int = DEFAULT_GROUPS) -> int:
    if cfg.family == "hybrid":
        return -(-cfg.n_layers // cfg.hybrid_attn_every)
    total = cfg.n_layers // 2 if cfg.local_global_pattern else cfg.n_layers
    return min(n_groups, total)


def attention_directive(cfg: ArchConfig, mctx: MeshCtx) -> Directive:
    """kernels when the tight structure holds (head-sharded flash kernel);
    parallel (sequence-sharded) otherwise; vector if nothing shards."""
    mode = attn_tp_mode(cfg.n_heads, cfg.kv_heads, mctx)
    if mode in ("heads", "qheads"):
        return Directive.KERNELS
    return Directive.PARALLEL


def ffn_directive(cfg: ArchConfig, mctx: MeshCtx) -> Directive:
    if cfg.moe is not None:
        ok = mctx.mesh is None or cfg.moe.num_experts % mctx.model_size == 0
        return Directive.PARALLEL if ok else Directive.VECTOR
    ok = mctx.mesh is None or cfg.d_ff % mctx.model_size == 0
    return Directive.PARALLEL if ok else Directive.VECTOR


def ssd_directive(cfg: ArchConfig, mctx: MeshCtx) -> Directive:
    inner = cfg.ssm.expand * cfg.d_model
    heads = inner // cfg.ssm.head_dim
    ok = mctx.mesh is None or (
        inner % mctx.model_size == 0 and heads % mctx.model_size == 0
    )
    return Directive.KERNELS if ok else Directive.VECTOR


def build_units(
    cfg: ArchConfig, mesh=None, n_groups: int = DEFAULT_GROUPS
) -> List[UnitPlan]:
    mctx = MeshCtx(mesh)
    G = n_groups_for(cfg, n_groups)
    units: List[UnitPlan] = []
    if cfg.family != "encoder":
        units.append(UnitPlan("embed", Directive.VECTOR))
    if cfg.family in ("ssm", "hybrid"):
        d = ssd_directive(cfg, mctx)
        for i in range(G):
            units.append(UnitPlan(f"g{i}/ssd", d))
        if cfg.family == "hybrid":
            units.append(UnitPlan("shared/attn", attention_directive(cfg, mctx)))
            units.append(UnitPlan("shared/ffn", ffn_directive(cfg, mctx)))
    else:
        da = attention_directive(cfg, mctx)
        df = ffn_directive(cfg, mctx)
        tag = "moe" if cfg.moe is not None else "ffn"
        for i in range(G):
            units.append(UnitPlan(f"g{i}/attn", da))
            units.append(UnitPlan(f"g{i}/{tag}", df))
    units.append(UnitPlan("unembed", Directive.PARALLEL))
    return units


GROUP_GATHER_BUDGET = 4 << 30  # bytes: max bulk-gathered group weight size


def group_weight_bytes(cfg: ArchConfig, n_groups: int) -> int:
    """bf16 bytes of one stacked layer-group's gathered weights."""
    per_layer = (cfg.n_params() - cfg.vocab * cfg.d_model * 2) // max(
        cfg.n_layers, 1
    )
    layers_per_group = -(-cfg.n_layers // max(n_groups, 1))
    return int(2 * per_layer * layers_per_group)


def build_plan(
    cfg: ArchConfig,
    mesh=None,
    n_groups: int = DEFAULT_GROUPS,
    *,
    genes: Optional[Tuple[int, ...]] = None,
    bulk_gather: Optional[bool] = None,
    keep_sharded: bool = True,
    staged: bool = True,
    remat: str = "full",
    overlap_collectives: bool = True,
    microbatches: int = 1,
    optimized: bool = False,
) -> ExecutionPlan:
    """Default plan = the paper's proposed method output: every unit
    offloaded with all three transfer reductions on. ``genes`` overrides the
    offload vector (GA individuals); flags toggle the §3.3 ablations.

    ``optimized=True`` enables the beyond-paper §Perf flags (grouped MoE
    dispatch, bf16 intermediates) on top of the paper-faithful plan.
    """
    units = build_units(cfg, mesh, n_groups)
    if bulk_gather is None:
        # bulk "data copy" batching is bounded by device memory: gathering a
        # whole stacked group only when it fits the budget (big models fall
        # back to per-layer gathers inside the scan).
        bulk_gather = group_weight_bytes(cfg, n_groups) <= GROUP_GATHER_BUDGET
    plan = ExecutionPlan(
        units=tuple(units),
        overlap_collectives=overlap_collectives,
        microbatches=microbatches,
    ).with_flags(
        bulk_gather=bulk_gather,
        keep_sharded=keep_sharded,
        staged=staged,
        remat=remat,
        grouped_dispatch=optimized,
        bf16_intermediates=optimized,
    )
    if genes is not None:
        plan = plan.with_genes(genes)
    return plan


def previous_method_plan(cfg: ArchConfig, mesh=None, **kw) -> ExecutionPlan:
    """The paper's PREVIOUS method [33]: nest-level transfer batching only
    (per-layer gathers, no bulk coalescing, no presence, no staging) and the
    kernels directive only (units whose directive is PARALLEL run baseline)."""
    plan = build_plan(
        cfg, mesh, bulk_gather=False, keep_sharded=False, staged=False, **kw
    )
    genes = tuple(
        1 if u.directive == Directive.KERNELS else 0 for u in plan.units
    )
    return plan.with_genes(genes)


def applicability_notes(cfg: ArchConfig, mesh=None) -> List[str]:
    """DESIGN.md §Arch-applicability: why a directive was / wasn't assigned."""
    mctx = MeshCtx(mesh)
    notes = []
    if cfg.family == "ssm":
        notes.append("attention-free: attention offload directives inapplicable;"
                     " SSD chunked-scan kernel is the KERNELS unit")
    elif cfg.n_heads and attn_tp_mode(cfg.n_heads, cfg.kv_heads, mctx) == "seq":
        notes.append(
            f"n_heads={cfg.n_heads} not divisible by model axis "
            f"{mctx.model_size}: head-TP rejected, sequence-parallel "
            "attention assigned (kernels -> parallel fallback)"
        )
    elif cfg.n_heads and attn_tp_mode(cfg.n_heads, cfg.kv_heads, mctx) == "qheads":
        notes.append(
            f"kv_heads={cfg.kv_heads} < model axis: KV weights/cache "
            "replicated, q heads sharded (partial offload)"
        )
    if cfg.moe is not None:
        notes.append(
            f"MoE dispatch is the non-tightly-nested loop: PARALLEL (EP) "
            f"directive, {cfg.moe.num_experts} experts over model axis"
        )
    if cfg.encoder_only:
        notes.append("encoder-only: no decode shapes (no autoregressive step)")
    if not cfg.subquadratic:
        notes.append("pure full attention: long_500k skipped")
    return notes
