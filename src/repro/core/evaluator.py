"""Verification-environment evaluators: genes -> processing time (seconds).

Three evaluators, one per fidelity level:

- ``MiniappEvaluator`` — analytic cost model over a LoopProgram. Per-loop
  time = max(arithmetic, memory-traffic) on the executing side + kernel
  launch latency; transfers priced from ``core.transfer``'s schedule.
  Hardware constants model the paper's verification machine (Quadro P4000
  over PCIe3 x16); a TPU-v5e-host profile is provided for the adapted
  system. Constants were calibrated once against the paper's measured
  end-points (see ``calibration`` note below) and then frozen.

- ``MeasuredEvaluator`` — actually runs a miniapp implementation on this
  container and wall-clocks it (the paper's real measurement loop, with
  timeout -> penalty handled by the GA).

- ``CompiledEvaluator`` — framework level: genes -> ExecutionPlan ->
  AOT ``.lower().compile()`` on the production mesh -> three-term roofline
  ``t_step``. Compile failure plays the role of a pgcc compile error
  (penalty). Used by the beyond-paper architecture offload search.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core import transfer as tr
from repro.core.loopir import Loop, LoopClass, LoopProgram


# ---------------------------------------------------------------------------
# hardware model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Effective (not peak) rates; calibrated, see module docstring."""

    name: str
    cpu_flops: float  # scalar/autovec CPU pipeline
    cpu_membw: float  # CPU stream bandwidth through cache misses
    accel_flops_kernels: float  # `kernels`-directive loops (tight nests)
    accel_flops_parallel: float  # `parallel loop` (non-tight: slightly worse)
    accel_flops_vector: float  # `parallel loop vector` (VPU-rate only)
    accel_membw: float
    link_bw: float  # CPU<->accelerator (PCIe / host-HBM)
    link_latency: float  # per transfer batch
    launch_latency: float  # per kernel launch


# Paper verification machine: i5-7500 + Quadro P4000 (PCIe3 x16).
# Calibration (scripts/calibrate_miniapps.py, frozen 2026-07-16): constants
# chosen so the PROPOSED and PREVIOUS pipelines run through the full GA land
# on the paper's measured fig. 5 speedups:
#   paper   Himeno 4.8x / 15.4x   NAS.FT 5.4x / 10.0x
#   model   Himeno 5.0x / 15.3x   NAS.FT 4.6x /  9.7x
QUADRO_P4000 = HardwareModel(
    name="quadro-p4000",
    cpu_flops=3.262e9,
    cpu_membw=5.464e9,
    accel_flops_kernels=4.988e11,
    accel_flops_parallel=3.99e11,  # paper: kernels beats parallel on PGI
    accel_flops_vector=3.325e10,
    accel_membw=9.301e10,
    link_bw=7.694e9,
    link_latency=2.0e-5,
    launch_latency=8.0e-6,
)

# TPU adaptation of the same verification loop: v5e chip fed from host RAM.
TPU_V5E_HOST = HardwareModel(
    name="tpu-v5e-host",
    cpu_flops=6.0e9,
    cpu_membw=2.0e10,
    accel_flops_kernels=1.97e14,  # bf16 MXU
    accel_flops_parallel=1.6e14,
    accel_flops_vector=4.0e12,  # VPU-rate
    accel_membw=8.19e11,
    link_bw=3.2e10,  # PCIe gen4-ish host link
    link_latency=1.0e-5,
    launch_latency=3.0e-6,
)


# ---------------------------------------------------------------------------
# analytic model
# ---------------------------------------------------------------------------


_DIRECTIVE_RATE = {
    LoopClass.TIGHT: "accel_flops_kernels",
    LoopClass.NON_TIGHT: "accel_flops_parallel",
    LoopClass.VECTOR_ONLY: "accel_flops_vector",
}


def loop_bytes(prog: LoopProgram, loop: Loop) -> float:
    """Memory traffic of one nest execution: every touched array streamed
    once (true for the miniapps' loops, which sweep their arrays).
    Public: shared with :mod:`repro.destinations`' per-backend models."""
    return float(sum(prog.var(v).nbytes for v in loop.touched()))


def loop_time(
    prog: LoopProgram, loop: Loop, offloaded: bool, hw: HardwareModel
) -> float:
    """Time for ONE execution of the full nest (all trips of this loop)."""
    flops = loop.total_flops
    byts = loop_bytes(prog, loop)
    if not offloaded:
        return max(flops / hw.cpu_flops, byts / hw.cpu_membw)
    rate = getattr(hw, _DIRECTIVE_RATE[loop.klass])
    if loop.sequential_carry:
        rate = hw.accel_flops_vector  # no parallelism to exploit
    return max(flops / rate, byts / hw.accel_membw) + hw.launch_latency


@dataclasses.dataclass
class TimeBreakdown:
    cpu_s: float = 0.0
    accel_s: float = 0.0
    transfer_s: float = 0.0
    launch_s: float = 0.0  # included in accel_s; reported for analysis

    @property
    def total_s(self) -> float:
        return self.cpu_s + self.accel_s + self.transfer_s


def predict_time(
    prog: LoopProgram,
    genes: Sequence[int],
    mode: tr.TransferMode = tr.TransferMode.BULK,
    staged: bool = True,
    hw: HardwareModel = QUADRO_P4000,
) -> TimeBreakdown:
    offload = prog.genes_to_offloads(genes)
    bd = TimeBreakdown()
    for loop in prog.loops:
        execs = prog.region_trip(loop.parent_seq)
        t = loop_time(prog, loop, offload[loop.name], hw) * execs
        if offload[loop.name]:
            bd.accel_s += t
            bd.launch_s += hw.launch_latency * execs
        else:
            bd.cpu_s += t
    sched = tr.build_schedule(prog, genes, mode=mode, staged=staged)
    bd.transfer_s = (
        sched.total_bytes / hw.link_bw + sched.total_events * hw.link_latency
    )
    return bd


class MiniappEvaluator:
    """genes -> predicted seconds, under a transfer mode + staging flag."""

    def __init__(
        self,
        prog: LoopProgram,
        mode: tr.TransferMode = tr.TransferMode.BULK,
        staged: bool = True,
        hw: HardwareModel = QUADRO_P4000,
        kernels_only: bool = False,
    ):
        self.prog = prog
        self.mode = mode
        self.staged = staged
        self.hw = hw
        # previous method [33]: only `kernels`-class loops may be offloaded
        self.kernels_only = kernels_only

    def admissible(self, genes: Sequence[int]) -> Tuple[int, ...]:
        if not self.kernels_only:
            return tuple(genes)
        return tuple(
            g if l.klass == LoopClass.TIGHT else 0
            for g, l in zip(genes, self.prog.offloadable_loops)
        )

    def __call__(self, genes: Sequence[int]) -> float:
        return predict_time(
            self.prog, self.admissible(genes), self.mode, self.staged, self.hw
        ).total_s

    def fingerprint(self) -> str:
        """Configuration key for the persistent fitness cache (evalpool):
        two evaluators share measurements iff their fingerprints match.
        Keys on the program's structural digest, not its name — the same
        app at another grid size must not share cached times."""
        return (
            f"miniapp:{self.prog.fingerprint()}:{self.mode.value}"
            f":{'staged' if self.staged else 'unstaged'}:{self.hw.name}"
            f"{':kernels-only' if self.kernels_only else ''}"
        )

    def cpu_only_time(self) -> float:
        return predict_time(
            self.prog, (0,) * self.prog.gene_length, self.mode, True, self.hw
        ).total_s


# ---------------------------------------------------------------------------
# measured evaluator (this container's real verification environment)
# ---------------------------------------------------------------------------


class MeasuredEvaluator:
    """Wall-clocks ``run_fn(genes)``; the GA applies the timeout penalty.

    Measurements are machine-bound facts: the fingerprint carries the
    *measurement identity* — run_fn, repeat count, config tag AND the
    host the clock ran on — so a persistent fitness cache can hold
    modeled and measured entries side by side without ever serving one
    host's (or the analytic model's) numbers to another.
    """

    def __init__(self, run_fn: Callable[[Sequence[int]], None],
                 repeats: int = 1, tag: str = "default",
                 host: Optional[str] = None):
        self.run_fn = run_fn
        self.repeats = repeats
        # qualnames don't distinguish lambdas/partials/closures that differ
        # only in captured state; set tag to the app/config identity when
        # sharing a persistent fitness cache
        self.tag = tag
        self.host = host if host is not None else _local_host()

    def __call__(self, genes: Sequence[int]) -> float:
        best = float("inf")
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            self.run_fn(genes)
            best = min(best, time.perf_counter() - t0)
        return best

    def cache_key(self, genes: Sequence[int]) -> str:
        """Delegates to the run_fn's canonicalization when it has one
        (``HimenoRunFn``/``NasftRunFn`` collapse to the genes their
        implementation actually distinguishes, so equivalent genomes
        share one real measurement); digit-string otherwise."""
        ck = getattr(self.run_fn, "cache_key", None)
        if callable(ck):
            return str(ck(genes))
        return "".join(str(int(g)) for g in genes)

    def fingerprint(self) -> str:
        name = getattr(self.run_fn, "__qualname__", None) \
            or type(self.run_fn).__name__
        mod = getattr(self.run_fn, "__module__", "")
        return (f"measured:{mod}.{name}:r{self.repeats}:{self.tag}"
                f"@{self.host}")


def _local_host() -> str:
    import platform

    return platform.node() or "localhost"


# ---------------------------------------------------------------------------
# compiled evaluator (framework level, beyond-paper)
# ---------------------------------------------------------------------------


class CompiledEvaluator:
    """genes -> plan -> AOT compile -> roofline t_step (seconds).

    ``build_and_score(genes)`` must lower+compile the cell under the genes'
    ExecutionPlan and return predicted step seconds; it is injected (from
    ``launch.dryrun``) to keep core/ free of launch-time imports. Compile
    errors are the pgcc-compile-error analogue -> penalty (returned as inf,
    which the GA maps to the penalty time).

    ``evaluate_batch`` is the evalpool's batched AOT-compile path: a whole
    generation's unique, uncached genomes are compiled with up to
    ``compile_workers`` concurrent lower+compile pipelines (XLA compilation
    releases the GIL, so threads overlap the C++ compile work).
    """

    def __init__(
        self,
        build_and_score: Callable[[Tuple[int, ...]], float],
        verbose: bool = False,
        compile_workers: int = 1,
        tag: str = "default",
    ):
        self.build_and_score = build_and_score
        self.verbose = verbose
        self.compile_workers = max(1, int(compile_workers))
        self.tag = tag
        self.failures: Dict[Tuple[int, ...], str] = {}

    def __call__(self, genes: Sequence[int]) -> float:
        key = tuple(genes)
        try:
            t = float(self.build_and_score(key))
        except Exception as e:  # noqa: BLE001 — compile error == penalty
            self.failures[key] = repr(e)
            if self.verbose:
                print(f"[compiled-eval] {key} failed: {e!r}")
            return float("inf")
        if self.verbose:
            print(f"[compiled-eval] {key} -> {t*1e3:.2f} ms")
        return t

    def evaluate_batch(
        self, genes_list: Sequence[Sequence[int]]
    ) -> "list[float]":
        from repro.core.evalpool import parallel_map

        return parallel_map(self, list(genes_list), self.compile_workers)

    def fingerprint(self) -> str:
        name = getattr(self.build_and_score, "__qualname__", None) \
            or type(self.build_and_score).__name__
        mod = getattr(self.build_and_score, "__module__", "")
        return f"compiled:{mod}.{name}:{self.tag}"
