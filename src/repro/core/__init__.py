"""Core: the paper's automatic offloading technology, generalized.

- loopir / miniapps: the applications' loop statements as an IR
- analysis: directive assignment (the pgcc loop classification analogue)
- genome / ga: the evolutionary search (fitness t^-1/2, roulette+elitism)
- transfer: CPU-accelerator transfer reduction (bulk / present / temp-area)
- evaluator: verification-environment scoring (analytic / measured / compiled)
- evalpool: generation-level evaluation (dedup / persistent cache / workers)
- pcast: final result-difference check
- plan: ExecutionPlan — the genome's phenotype at the framework level

Layered on top (sibling package): ``repro.destinations`` — the
mixed-destination search (arXiv:2011.12431). Destination registry with
per-backend profiles + admissibility + transfer topology, the N-memory
generalization of ``transfer``'s BULK residency tracking, and the
``MixedEvaluator`` scoring k-ary genomes (``genome``'s operators with
``GAParams.alleles=k``) with subset-independent fitness-cache keys.
"""
from repro.core import analysis, evaluator, evalpool, ga, genome, loopir
from repro.core import miniapps, pcast, plan, transfer

__all__ = [
    "analysis", "evaluator", "evalpool", "ga", "genome", "loopir",
    "miniapps", "pcast", "plan", "transfer",
]
