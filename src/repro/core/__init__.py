"""Core: the paper's automatic offloading technology, generalized.

- loopir / miniapps: the applications' loop statements as an IR
- analysis: directive assignment (the pgcc loop classification analogue)
- genome / ga: the evolutionary search (fitness t^-1/2, roulette+elitism)
- transfer: CPU-accelerator transfer reduction (bulk / present / temp-area)
- evaluator: verification-environment scoring (analytic / measured / compiled)
- evalpool: generation-level evaluation (dedup / persistent cache / workers)
- pcast: final result-difference check
- plan: ExecutionPlan — the genome's phenotype at the framework level
"""
from repro.core import analysis, evaluator, evalpool, ga, genome, loopir
from repro.core import miniapps, pcast, plan, transfer

__all__ = [
    "analysis", "evaluator", "evalpool", "ga", "genome", "loopir",
    "miniapps", "pcast", "plan", "transfer",
]
