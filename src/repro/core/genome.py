"""Genome operators for the offload GA (paper [32] §GA setup).

Originally binary: gene value 1 = insert the offload directive on that
loop/unit; 0 = leave it on the CPU path. The mixed-destination follow-up
(arXiv:2011.12431) searches several offload backends in one genome, so the
operators are k-ary: a gene holds a *destination index* in ``[0, k)`` and
``k=2`` (the default everywhere) reproduces the binary operators
bit-for-bit — same RNG draws, same outputs — so existing searches and
their persisted fitness caches are untouched.

Operators are pure functions over numpy Generators so the GA is
reproducible and hypothesis-testable.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

Genes = Tuple[int, ...]


def random_genome(rng: np.random.Generator, length: int, k: int = 2) -> Genes:
    """Uniform gene draw over destination indices ``[0, k)``."""
    assert k >= 2, k
    return tuple(int(b) for b in rng.integers(0, k, size=length))


def initial_population(
    rng: np.random.Generator, length: int, size: int, k: int = 2
) -> List[Genes]:
    """Random destination assignment; duplicates re-drawn (bounded) to keep
    the initial search wide, as the paper's implementation does."""
    pop: List[Genes] = []
    seen = set()
    attempts = 0
    while len(pop) < size:
        g = random_genome(rng, length, k)
        attempts += 1
        if g in seen and attempts < 20 * size and length > 1:
            continue
        seen.add(g)
        pop.append(g)
    return pop


def crossover(
    rng: np.random.Generator, a: Genes, b: Genes, rate: float
) -> Tuple[Genes, Genes]:
    """Single-point crossover with probability ``rate`` (Pc=0.9).
    Allele-agnostic: children only ever hold parent gene values."""
    assert len(a) == len(b)
    if len(a) < 2 or rng.random() >= rate:
        return a, b
    point = int(rng.integers(1, len(a)))
    return a[:point] + b[point:], b[:point] + a[point:]


def uniform_crossover(
    rng: np.random.Generator, a: Genes, b: Genes, rate: float
) -> Tuple[Genes, Genes]:
    """Uniform crossover with probability ``rate``: each gene swaps sides
    with p=0.5 — better building-block mixing on long genomes.
    Allele-agnostic: children only ever hold parent gene values."""
    assert len(a) == len(b)
    if rng.random() >= rate:
        return a, b
    mask = rng.integers(0, 2, size=len(a))
    ca = tuple(x if m else y for x, y, m in zip(a, b, mask))
    cb = tuple(y if m else x for x, y, m in zip(a, b, mask))
    return ca, cb


def mutate(rng: np.random.Generator, g: Genes, rate: float, k: int = 2) -> Genes:
    """Independent per-gene mutation (Pm=0.05). Binary genes flip; k-ary
    genes re-draw uniformly among the k-1 OTHER destinations (never a
    self-mutation, matching the binary flip semantics)."""
    flips = rng.random(len(g)) < rate
    if k == 2:
        return tuple(int(b) ^ int(f) for b, f in zip(g, flips))
    # draw in [0, k-1) and shift past the current allele: uniform over the
    # other k-1 values. Draws happen for every gene (vectorized) so the
    # number of RNG pulls is independent of which genes mutate.
    draws = rng.integers(0, k - 1, size=len(g))
    out = []
    for b, f, d in zip(g, flips, draws):
        b = int(b)
        if not f:
            out.append(b)
            continue
        d = int(d)
        out.append(d + 1 if d >= b else d)
    return tuple(out)


def roulette_pick(
    rng: np.random.Generator, population: Sequence[Genes],
    fitness: Sequence[float],
) -> Genes:
    """Fitness-proportional (roulette) selection."""
    total = float(sum(fitness))
    if total <= 0.0:
        return population[int(rng.integers(0, len(population)))]
    r = rng.random() * total
    acc = 0.0
    for g, f in zip(population, fitness):
        acc += f
        if acc >= r:
            return g
    return population[-1]
