"""CPU-accelerator transfer scheduling (paper §3.3).

Given a LoopProgram and a genome (which loops are offloaded), build the
transfer schedule under one of three modes — the paper's method lineage:

- ``NAIVE``  ([32], 2018): plain per-loop ``acc kernels`` semantics. Every
  offloaded loop opens its own data region per execution: reads copied in,
  writes copied out, every region iteration, no residency anywhere.

- ``NEST``   ([33], 2019 — the "previous method" of this paper): variables
  are hoisted "to as upper a loop as possible" — read-only arrays transfer
  once for the whole run — but there is NO present-tracking across kernel
  regions: any variable *written* on the accelerator is flushed back and
  re-validated at every enclosing time-step iteration (the Jacobi pressure
  array ping-pong that caps Himeno at 4.8x). Transfers are per-variable
  (no multi-file coalescing into batches).

- ``BULK``   (this paper): one whole-program data region with host/device
  validity tracking — a variable already on the accelerator is *present*
  (no copy); only CPU writes invalidate the device copy; device writes
  come back on first CPU read or once at program end. Multi-file variables
  coalesce into batched transfers (one latency per batch).

Independently, ``staged`` models the temp-area trick (paper fig. 2): when
False, every offloaded loop touching a small variable the compiler cannot
prove safe (``is_global or init_external``, scalars/parameters) pays a
conservative auto-sync per execution; when True the GPU-side temp area
(``declare create`` + explicit ``update``) blocks those transfers.

Everything here is pure static analysis + counting — byte/second costs are
applied by ``core.evaluator``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

from repro.core.loopir import Loop, LoopProgram, Var


class TransferMode(str, enum.Enum):
    NAIVE = "naive"  # [32] per-kernel-region sync, no residency
    NEST = "nest"  # [33] hoisted read-onlys, per-iteration flush of writes
    BULK = "bulk"  # this paper: program-wide region + present tracking


AUTO_SYNC_MAX_BYTES = 4 << 20  # compiler auto-syncs scalars/parameters only:
# large arrays under explicit `data copy` / `present` are directive-controlled
# (the paper's fig. 2 leak is parameters initialized in other functions).


@dataclasses.dataclass
class TransferSchedule:
    """Totals of the scheduled CPU<->accelerator copies."""

    h2d_bytes: float = 0.0
    d2h_bytes: float = 0.0
    h2d_count: float = 0.0  # individual variable transfers
    d2h_count: float = 0.0
    batches: float = 0.0  # latency-bearing transfer events (bulk coalesces)
    auto_sync_bytes: float = 0.0  # compiler auto-transfers (staged=False)
    auto_sync_count: float = 0.0
    by_var: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return self.h2d_bytes + self.d2h_bytes + self.auto_sync_bytes

    @property
    def total_events(self) -> float:
        return self.batches + self.auto_sync_count

    def _add(self, var: Var, direction: str, times: float, batched: bool):
        b = var.nbytes * times
        if direction == "h2d":
            self.h2d_bytes += b
            self.h2d_count += times
        else:
            self.d2h_bytes += b
            self.d2h_count += times
        if not batched:
            self.batches += times
        self.by_var[var.name] = self.by_var.get(var.name, 0.0) + b

    def describe(self) -> str:
        return (
            f"h2d {self.h2d_bytes/1e6:.1f} MB/{self.h2d_count:.0f}x, "
            f"d2h {self.d2h_bytes/1e6:.1f} MB/{self.d2h_count:.0f}x, "
            f"auto-sync {self.auto_sync_bytes/1e6:.1f} MB/"
            f"{self.auto_sync_count:.0f}x, batches {self.batches:.0f}"
        )


# ---------------------------------------------------------------------------
# dynamic execution order
# ---------------------------------------------------------------------------

Event = Tuple[str, Optional[Loop], float]  # ("loop", l, times) | ("boundary", None, times)


def dynamic_events(prog: LoopProgram, boundaries: bool) -> Iterator[Event]:
    """Linearized execution with steady-state weighting.

    Loops sharing a ``parent_seq`` region execute region.trip times as a
    block. The simulation unrolls each region as: first iteration (times=1)
    then one steady-state iteration weighted times=trip-1 — exact when the
    residency state is periodic after one iteration, which holds because
    decisions depend only on validity state the first iteration establishes.
    ``boundaries``: emit a region-iteration boundary event after each
    (weighted) iteration — NEST mode flushes device-written vars there.

    Public: :mod:`repro.destinations.schedule` replays the same event
    stream through its N-memory residency simulation.
    """
    i = 0
    loops = prog.loops
    while i < len(loops):
        region = loops[i].parent_seq
        if region is None:
            yield ("loop", loops[i], 1.0)
            i += 1
            continue
        j = i
        while j < len(loops) and loops[j].parent_seq == region:
            j += 1
        trip = prog.region_trip(region)
        for l in loops[i:j]:
            yield ("loop", l, 1.0)
        if boundaries:
            yield ("boundary", None, 1.0)
        if trip > 1:
            for l in loops[i:j]:
                yield ("loop", l, float(trip - 1))
            if boundaries:
                yield ("boundary", None, float(trip - 1))
        i = j


# ---------------------------------------------------------------------------
# schedule construction
# ---------------------------------------------------------------------------


def build_schedule(
    prog: LoopProgram,
    genes: Sequence[int],
    mode: TransferMode = TransferMode.BULK,
    staged: bool = True,
) -> TransferSchedule:
    offload = prog.genes_to_offloads(genes)
    sched = TransferSchedule()
    if mode == TransferMode.NAIVE:
        _schedule_naive(prog, offload, staged, sched)
    else:
        _schedule_tracked(
            prog, offload, staged, sched,
            iteration_flush=(mode == TransferMode.NEST),
            coalesce=(mode == TransferMode.BULK),
        )
    return sched


def _auto_sync(loop: Loop, prog: LoopProgram, staged: bool,
               sched: TransferSchedule, times: float):
    """Temp-area analogue: conservative compiler transfers on unsafe vars."""
    if staged:
        return
    for vn in sorted(loop.touched()):
        v = prog.var(vn)
        if (v.is_global or v.init_external) and v.nbytes <= AUTO_SYNC_MAX_BYTES:
            sched.auto_sync_bytes += 2.0 * v.nbytes * times
            sched.auto_sync_count += 2.0 * times


def _schedule_naive(
    prog: LoopProgram,
    offload: Dict[str, bool],
    staged: bool,
    sched: TransferSchedule,
):
    """NAIVE: every offloaded loop execution opens its own data region."""
    for loop in prog.loops:
        if not offload[loop.name]:
            continue
        entries = float(prog.region_trip(loop.parent_seq))
        for vn in sorted(loop.reads):
            sched._add(prog.var(vn), "h2d", entries, batched=False)
        for vn in sorted(loop.writes):
            sched._add(prog.var(vn), "d2h", entries, batched=False)
        _auto_sync(loop, prog, staged, sched, entries)


def _schedule_tracked(
    prog: LoopProgram,
    offload: Dict[str, bool],
    staged: bool,
    sched: TransferSchedule,
    *,
    iteration_flush: bool,
    coalesce: bool,
):
    """Residency simulation. ``iteration_flush`` (NEST): device-written vars
    are flushed + invalidated at region-iteration boundaries — the previous
    method's missing cross-iteration present tracking."""
    device_valid: Dict[str, bool] = {v.name: False for v in prog.vars}
    host_valid: Dict[str, bool] = {v.name: True for v in prog.vars}
    device_dirty: Dict[str, bool] = {v.name: False for v in prog.vars}
    region_dirty: set = set()  # device-written WITHIN the current region iter

    for kind, loop, times in dynamic_events(prog, boundaries=iteration_flush):
        if kind == "boundary":
            # NEST ([33]): no present-tracking across kernel regions inside
            # the time-step loop — vars the region's kernels wrote are synced
            # back and re-validated every iteration. Vars written BEFORE the
            # region (hoisted init results) stay resident: [33] does hoist
            # transfers "to as upper a loop as possible".
            for vn in sorted(region_dirty):
                if device_dirty[vn]:
                    sched._add(prog.var(vn), "d2h", times, batched=coalesce)
                    host_valid[vn] = True
                    device_dirty[vn] = False
                    device_valid[vn] = False  # re-validated next iteration
            region_dirty.clear()
            continue
        assert loop is not None
        if offload[loop.name]:
            moved = 0
            for vn in sorted(loop.reads):
                if not device_valid[vn]:
                    sched._add(prog.var(vn), "h2d", times, batched=coalesce)
                    device_valid[vn] = True
                    moved += 1
            for vn in sorted(loop.writes):
                device_valid[vn] = True
                device_dirty[vn] = True
                host_valid[vn] = False
                if iteration_flush and loop.parent_seq is not None:
                    region_dirty.add(vn)
            if moved and coalesce:
                # coalesced: all copyins at this point share one batch
                sched.batches += times
            _auto_sync(loop, prog, staged, sched, times)
        else:
            moved = 0
            for vn in sorted(loop.reads):
                if not host_valid[vn]:
                    sched._add(prog.var(vn), "d2h", times, batched=coalesce)
                    host_valid[vn] = True
                    device_dirty[vn] = False
                    moved += 1
            for vn in sorted(loop.writes):
                host_valid[vn] = True
                device_valid[vn] = False
            if moved and coalesce:
                sched.batches += times

    # program end: return dirty device results to the host once
    flushed = False
    for vn in sorted(device_dirty):
        if device_dirty[vn] and not host_valid[vn]:
            sched._add(prog.var(vn), "d2h", 1.0, batched=coalesce)
            flushed = True
    if flushed and coalesce:
        sched.batches += 1.0
    return sched


def mode_for_flags(bulk_gather: bool, keep_sharded: bool) -> TransferMode:
    """Plan-flag mapping used by the framework-level GA: bulk+present on ->
    BULK; both off -> NEST (the previous method); bulk off but present on
    degenerates to NEST too (a program-wide region is what enables present)."""
    if bulk_gather and keep_sharded:
        return TransferMode.BULK
    return TransferMode.NEST
