"""Result-difference check (the paper's PCAST / acc_compare analogue).

The paper's final step samples the offloaded program and the CPU-only
program on test inputs and shows the numerical differences to the user
(PGI PCAST, ``acc_compare``). Here: compare two pytrees of arrays
(reference path vs offloaded/plan path) with dtype-aware tolerances and
produce a per-leaf report the caller can print or assert on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

# IEEE-754-aware defaults per compute dtype (the paper checks against an
# IEEE 754 tolerance spec via PCAST options)
DEFAULT_TOLS: Dict[str, Tuple[float, float]] = {
    "float64": (1e-12, 1e-12),
    "float32": (3e-5, 3e-5),
    "bfloat16": (2e-2, 2e-2),
    "float16": (5e-3, 5e-3),
    "complex64": (3e-5, 3e-5),
}


@dataclasses.dataclass
class LeafDiff:
    path: str
    dtype: str
    shape: Tuple[int, ...]
    max_abs: float
    max_rel: float
    rel_tol: float
    abs_tol: float
    n_mismatch: int
    n_total: int

    @property
    def ok(self) -> bool:
        return self.n_mismatch == 0

    def row(self) -> str:
        flag = "OK  " if self.ok else "DIFF"
        return (
            f"{flag} {self.path:40s} {self.dtype:9s} {str(self.shape):18s} "
            f"max_abs={self.max_abs:.3e} max_rel={self.max_rel:.3e} "
            f"mismatch={self.n_mismatch}/{self.n_total}"
        )


@dataclasses.dataclass
class PcastReport:
    leaves: List[LeafDiff]

    @property
    def ok(self) -> bool:
        return all(l.ok for l in self.leaves)

    @property
    def max_rel(self) -> float:
        return max((l.max_rel for l in self.leaves), default=0.0)

    def describe(self) -> str:
        head = (
            f"PCAST result-difference check: "
            f"{'PASS' if self.ok else 'FAIL'} "
            f"({len(self.leaves)} tensors, max_rel={self.max_rel:.3e})"
        )
        return "\n".join([head] + ["  " + l.row() for l in self.leaves])


def _leaf_path(kp) -> str:
    return jax.tree_util.keystr(kp)


def compare(
    reference: Any,
    offloaded: Any,
    rel_tol: Optional[float] = None,
    abs_tol: Optional[float] = None,
) -> PcastReport:
    """Compare two pytrees leaf-by-leaf (shapes must match exactly)."""
    ref_leaves = jax.tree_util.tree_leaves_with_path(reference)
    off_leaves = jax.tree_util.tree_leaves_with_path(offloaded)
    assert len(ref_leaves) == len(off_leaves), "pytree structures differ"

    out: List[LeafDiff] = []
    for (kp, r), (_, o) in zip(ref_leaves, off_leaves):
        r = np.asarray(r)
        o = np.asarray(o)
        assert r.shape == o.shape, f"{_leaf_path(kp)}: {r.shape} vs {o.shape}"
        dt = str(o.dtype)
        d_rel, d_abs = DEFAULT_TOLS.get(dt, (1e-5, 1e-5))
        rt = rel_tol if rel_tol is not None else d_rel
        at = abs_tol if abs_tol is not None else d_abs
        rf = r.astype(np.float64) if not np.iscomplexobj(r) else r.astype(np.complex128)
        of = o.astype(np.float64) if not np.iscomplexobj(o) else o.astype(np.complex128)
        adiff = np.abs(rf - of)
        denom = np.maximum(np.abs(rf), np.abs(of))
        rel = np.where(denom > 0, adiff / np.maximum(denom, 1e-300), 0.0)
        bad = adiff > (at + rt * denom)
        out.append(
            LeafDiff(
                path=_leaf_path(kp),
                dtype=dt,
                shape=tuple(r.shape),
                max_abs=float(adiff.max()) if adiff.size else 0.0,
                max_rel=float(np.real(rel).max()) if rel.size else 0.0,
                rel_tol=rt,
                abs_tol=at,
                n_mismatch=int(bad.sum()),
                n_total=int(r.size),
            )
        )
    return PcastReport(out)
