"""Evaluation pool: decouples the GA loop from fitness measurement.

The paper's search cost is dominated by verification-environment
measurements (§5.2: caching fitness for recurring gene patterns is what
made the 7-hour budget feasible), and the mixed-destination follow-up
(arXiv:2011.12431) searches several backends at once, multiplying the
measurements per generation. This module scales that bottleneck three
ways, without changing GA semantics:

- **dedup** — identical gene patterns inside one generation are measured
  once (roulette selection re-picks strong parents, so duplicates are
  common in late generations);
- **persistent fitness cache** — measurements are appended to an on-disk
  JSONL file keyed by (evaluator fingerprint, genome), so a killed search
  resumes without re-measuring anything it already paid for, and repeated
  calibration sweeps share measurements across processes;
- **concurrent evaluation** — the unique, uncached individuals of a
  generation run on a thread (or process) pool with the paper's
  per-individual timeout -> penalty semantics preserved, or through an
  evaluator-provided ``evaluate_batch`` (the ``CompiledEvaluator``'s
  batched AOT-compile path).

Determinism: the GA's RNG stream never depends on evaluation order or
worker count, and results are reduced back into population order, so a
fixed seed produces the same best individual at pool size 1 and N.

Cache file format (JSONL, one record per line, append-only)::

    {"v": 1, "fp": "<evaluator fingerprint>", "genes": "0110...",
     "t": <measured seconds, float>, "penalized": <bool>}

- ``v``        format version (this module writes 1, skips others);
- ``fp``       evaluator fingerprint — configuration string such as
               ``miniapp:himeno:bulk:staged:quadro-p4000``; entries whose
               fingerprint differs from the pool's are ignored, so one
               file can serve many searches;
- ``genes``    the genome's cache key. By default the gene digits as a
               string (``"0110..."``; k-ary genomes use digits up to
               k-1). An evaluator may provide ``cache_key(genes) -> str``
               to canonicalize the key — the mixed-destination evaluator
               maps destination *indices* (subset-relative) to destination
               *names*, so searches over different destination subsets
               share measurements for placements they both contain;
- ``t``        the time fed back to the GA (post-penalty, seconds);
- ``penalized`` whether ``t`` is the timeout/failure penalty rather than
               a real measurement. Penalized records are written (for
               telemetry/audit) but NOT replayed by ``load``: a timeout
               may be transient and the penalty constant may differ
               between runs, so resumed searches re-measure those
               genomes instead of inheriting a poisoned value.

Truncated/corrupt trailing lines (a killed writer) are skipped on load.
Appends are **multi-owner safe**: every record is written as ONE
``os.write`` to an ``O_APPEND`` descriptor under an advisory ``flock``,
so concurrent FitnessCache objects over the same path — two pools in one
process, or two service workers in different processes — never interleave
partial lines. Concurrent readers see a prefix of the log and a resumed
search re-reads its own history. Use :meth:`FitnessCache.load` /
:meth:`FitnessCache.flush_sync` for explicit control.

Shared (serving-side) use goes through :class:`EvalBroker`: one JSONL
store path handing out refcounted per-fingerprint cache views, so many
concurrent Offloaders share one in-memory cache per evaluator family and
a stage ``close()`` never yanks the store out from under a sibling
search (docs/serving.md).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import json
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

try:  # advisory inter-process append lock; absent on some platforms
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX fallback
    fcntl = None

Genes = Tuple[int, ...]

_CACHE_VERSION = 1


def genes_key(genes: Sequence[int]) -> str:
    """Genome -> stable string key ('0110...')."""
    return "".join(str(int(g)) for g in genes)


def _atomic_append(fd: int, data: bytes) -> None:
    """Append one whole record to an ``O_APPEND`` descriptor without
    interleaving with other writers: a single ``os.write`` under an
    advisory exclusive ``flock`` (the lock also covers the rare partial
    write a signal could split)."""
    if fcntl is not None:
        fcntl.flock(fd, fcntl.LOCK_EX)
    try:
        while data:
            n = os.write(fd, data)
            data = data[n:]
    finally:
        if fcntl is not None:
            fcntl.flock(fd, fcntl.LOCK_UN)


def evaluator_fingerprint(evaluate: Callable) -> str:
    """Configuration fingerprint for an evaluator callable.

    Evaluators must provide ``fingerprint()`` (every shipped evaluator
    does). The fingerprint keys the persistent cache, so two
    differently-configured evaluators never share measurements — which
    is exactly why a name-based fallback is refused: two instances of
    the same evaluator class with different constants would share a
    qualified name, and their cached measurements would silently
    cross-contaminate.
    """
    fp = getattr(evaluate, "fingerprint", None)
    if callable(fp):
        return str(fp())
    name = getattr(evaluate, "__qualname__", None) or type(evaluate).__name__
    mod = getattr(evaluate, "__module__", "")
    raise TypeError(
        f"evaluator {mod}.{name} has no fingerprint(); refusing to key "
        "the persistent fitness cache on its name alone (two "
        "differently-configured instances would share cached "
        "measurements) — give it a fingerprint() method"
    )


class FitnessCache:
    """Genome -> measured seconds, optionally persisted as JSONL.

    With ``path=None`` this is a plain in-memory dict (the GA's original
    §5.2 cache). With a path, every ``put`` appends one JSON line and the
    constructor replays the file, so a killed search resumes warm.

    ``key_fn`` maps a genome to its cache-key string (default:
    :func:`genes_key`, the digit string). :class:`EvalPool` swaps in the
    evaluator's ``cache_key`` when it provides one, so callers normally
    construct the cache with just ``(path, fingerprint)``.

    **Multi-owner semantics.** Appends go through a single ``os.write``
    on an ``O_APPEND`` descriptor under an advisory ``flock``, so several
    cache objects over one path (in one process or many) never tear each
    other's lines. ``close()`` is refcounted: each :meth:`retain` call
    adds an owner and each ``close()`` releases one; the descriptor
    closes when the last owner leaves, so a pipeline stage closing its
    view of a shared store cannot double-close or strand a sibling
    search mid-write. Constructing the object counts as the first owner,
    which keeps single-owner callers exactly as before.
    """

    def __init__(
        self,
        path: Optional[str] = None,
        fingerprint: str = "",
        key_fn: Callable[[Sequence[int]], str] = genes_key,
    ):
        self.path = path
        self.fingerprint = fingerprint
        self.key_fn = key_fn
        self._mem: Dict[str, float] = {}
        self._lock = threading.Lock()
        self._fd: Optional[int] = None
        self._refs = 1  # construction is the first ownership
        self.loaded = 0  # records replayed from disk at construction
        if path:
            self.load()
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
            self._fd = os.open(
                path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644
            )

    def load(self) -> int:
        """(Re)read the JSONL file; skips foreign-fingerprint, foreign-
        version, and corrupt lines. Returns records absorbed."""
        if not self.path or not os.path.exists(self.path):
            return 0
        n = 0
        with open(self.path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except (json.JSONDecodeError, ValueError):
                    continue  # truncated trailing write from a killed run
                if not isinstance(rec, dict):
                    continue
                if rec.get("v") != _CACHE_VERSION:
                    continue
                if rec.get("fp") != self.fingerprint:
                    continue
                if rec.get("penalized"):
                    continue  # transient/param-dependent; re-measure
                genes, t = rec.get("genes"), rec.get("t")
                if not isinstance(genes, str) or not isinstance(
                    t, (int, float)
                ):
                    continue
                self._mem[genes] = float(t)
                n += 1
        self.loaded += n
        return n

    def __len__(self) -> int:
        return len(self._mem)

    def __contains__(self, genes: Sequence[int]) -> bool:
        return self.key_fn(genes) in self._mem

    def get(
        self, genes: Sequence[int], key: Optional[str] = None
    ) -> Optional[float]:
        """``key`` overrides ``key_fn`` for this lookup — the EvalPool
        passes its own evaluator-derived keys so one cache object can
        serve pools over different evaluators without being mutated."""
        return self._mem.get(key if key is not None else self.key_fn(genes))

    def put(
        self,
        genes: Sequence[int],
        t: float,
        penalized: bool = False,
        key: Optional[str] = None,
    ) -> None:
        key = key if key is not None else self.key_fn(genes)
        with self._lock:
            self._mem[key] = float(t)
            if self._fd is not None:
                rec = {
                    "v": _CACHE_VERSION,
                    "fp": self.fingerprint,
                    "genes": key,
                    "t": float(t),
                    "penalized": bool(penalized),
                }
                _atomic_append(
                    self._fd, (json.dumps(rec) + "\n").encode("utf-8")
                )

    def retain(self) -> "FitnessCache":
        """Register another owner; its ``close()`` is then a release,
        not a descriptor close. Returns self for chaining."""
        with self._lock:
            self._refs += 1
        return self

    def flush_sync(self) -> None:
        if self._fd is not None:
            os.fsync(self._fd)

    def close(self) -> None:
        """Release one ownership; the descriptor closes when the last
        owner leaves. Extra closes are no-ops (never double-close)."""
        with self._lock:
            if self._refs > 0:
                self._refs -= 1
            if self._refs == 0 and self._fd is not None:
                os.close(self._fd)
                self._fd = None

    def __enter__(self) -> "FitnessCache":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class EvalBroker:
    """One shared fitness-cache store multiplexed across concurrent
    searches — the serving layer's half of "one shared EvalPool".

    The broker owns a single JSONL store path and hands out one
    refcounted :class:`FitnessCache` view per evaluator fingerprint:

    - concurrent searches whose evaluators share a fingerprint (e.g.
      mixed-destination searches over different destination subsets of
      one machine — the fingerprint is subset-independent) share ONE
      in-memory view, so a measurement either of them pays is a hit for
      the other *immediately*, not only after a file re-read;
    - each view is retained per :meth:`open_cache` call, so a pipeline
      stage closing "its" cache merely releases its reference — the
      broker keeps every view alive (and its descriptor open) until
      :meth:`close`;
    - all views append to the same file through the cache's atomic
      O_APPEND writes, so searches in *other processes* sharing the
      store stay safe too, and a service restart replays everything.

    Worker budgeting stays with the callers (an :class:`EvalPool` per
    search, as ever); the serving layer bounds total measurement
    concurrency by admission (max in-flight jobs x per-job workers).
    """

    def __init__(self, path: str):
        self.path = path
        self._views: Dict[str, FitnessCache] = {}
        self._lock = threading.Lock()

    def open_cache(self, fingerprint: str) -> FitnessCache:
        """A retained cache view for this fingerprint; the caller's
        ``close()`` releases its reference only."""
        with self._lock:
            view = self._views.get(fingerprint)
            if view is None:
                view = FitnessCache(self.path, fingerprint=fingerprint)
                self._views[fingerprint] = view
        return view.retain()

    def stats(self) -> Dict[str, int]:
        """entries per open fingerprint view (observability)."""
        with self._lock:
            return {fp: len(v) for fp, v in self._views.items()}

    def close(self) -> None:
        """Release the broker's own reference on every view (views still
        retained by in-flight stages stay open until those release)."""
        with self._lock:
            views, self._views = list(self._views.values()), {}
        for v in views:
            v.close()

    def __enter__(self) -> "EvalBroker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclasses.dataclass
class GenTelemetry:
    """Per-generation search telemetry (emitted by evaluate_generation)."""

    submitted: int = 0  # individuals handed to the pool
    unique: int = 0  # distinct genomes after in-generation dedup
    cache_hits: int = 0  # dedup repeats + persistent/memory cache serves
    evaluated: int = 0  # fresh measurements actually run
    timeouts: int = 0  # measurements scored as the penalty
    wall_s: float = 0.0  # generation wall-clock (submit -> all reduced)
    # lane-seconds the pool's workers spent waiting rather than measuring
    # (generational path: the barrier stall behind the slowest lane;
    # steady-state path: lanes starved because the breeder fell behind)
    idle_s: float = 0.0

    @property
    def dedup_ratio(self) -> float:
        """Fraction of submissions that were in-generation repeats of
        another individual (a strict subset of what hit_rate counts)."""
        if self.submitted == 0:
            return 0.0
        return (self.submitted - self.unique) / self.submitted

    @property
    def hit_rate(self) -> float:
        """Fraction of submissions answered without a fresh measurement
        (in-generation repeats + memory/persistent cache serves)."""
        if self.submitted == 0:
            return 0.0
        return self.cache_hits / self.submitted

    def row(self) -> Dict[str, float]:
        return {
            "submitted": self.submitted,
            "unique": self.unique,
            "cache_hits": self.cache_hits,
            "evaluated": self.evaluated,
            "timeouts": self.timeouts,
            "wall_s": round(self.wall_s, 4),
            # named *_wall_s on purpose: observability comparisons scrub
            # wall-clock-derived row keys by that suffix
            "idle_wall_s": round(self.idle_s, 4),
            "dedup_ratio": round(self.dedup_ratio, 4),
            "hit_rate": round(self.hit_rate, 4),
        }


# the long-form name the pipeline/trace observability layer uses for
# this record (persisted per generation in the search payload and
# carried on every per-generation trace event)
GenerationTelemetry = GenTelemetry


def _timed_call(
    evaluate: Callable[[Genes], float], genes: Genes
) -> Tuple[float, float]:
    """(value, duration) for one measurement — module-level so the
    process executor can pickle it. The duration is the worker lane's
    busy time, the raw material for idle-lane attribution."""
    t0 = time.perf_counter()
    v = evaluate(genes)
    return float(v), time.perf_counter() - t0


def _run_with_executor(
    executor_kind: str,
    workers: int,
    evaluate: Callable[[Genes], float],
    genes_list: List[Genes],
    timeout_s: float,
) -> List[Tuple[float, bool, float]]:
    """Measure each genome; returns (raw seconds, timed_out, busy
    seconds) per genome — busy 0.0 for timeouts/crashes whose duration
    was never observed.

    Thread pools cannot kill a hung measurement, but a future that misses
    its deadline is *scored* as a timeout immediately (the straggler
    finishes in the background, exactly like the paper's verification
    machine finishing a run after the 3-minute cutoff already penalized
    it). Process pools get the same deadline semantics.
    """
    out: List[Tuple[float, bool, float]] = (
        [(float("inf"), True, 0.0)] * len(genes_list)
    )
    if executor_kind == "process":
        import multiprocessing as mp

        # spawn, not fork: the parent has usually initialized JAX/XLA
        # (runtime threads + locks), and forking that state can deadlock
        # the child mid-measurement. Spawn requires the evaluator to be
        # picklable — module-level run_fns like miniapps.HimenoRunFn.
        ex = cf.ProcessPoolExecutor(
            max_workers=max(1, workers), mp_context=mp.get_context("spawn")
        )
    else:
        ex = cf.ThreadPoolExecutor(max_workers=max(1, workers))
    try:
        t0 = time.monotonic()
        futs = {
            ex.submit(_timed_call, evaluate, g): i
            for i, g in enumerate(genes_list)
        }
        # every individual gets its full timeout; with w workers the batch
        # runs in ceil(n/w) waves, so the generation deadline is that many
        # timeouts out
        deadline = t0 + timeout_s * max(
            1, (len(genes_list) + workers - 1) // max(1, workers)
        )
        requeue: List[int] = []
        for fut in list(futs):
            i = futs[fut]
            try:
                remaining = max(0.0, deadline - time.monotonic())
                v, dur = fut.result(timeout=remaining)
                out[i] = (float(v), False, float(dur))
            except cf.TimeoutError:
                if fut.cancel():
                    # never started (earlier hangs held every worker):
                    # it used none of its budget, so it gets re-measured
                    # below instead of being penalized unmeasured
                    requeue.append(i)
                else:
                    out[i] = (float("inf"), True, 0.0)
            except Exception:  # measurement crash == compile error == penalty
                out[i] = (float("inf"), True, 0.0)
    finally:
        # don't block on hung stragglers mid-search: they are already
        # scored as penalties and their results discarded while the GA
        # moves on. LIMITATION: a worker that never returns still blocks
        # interpreter exit (concurrent.futures joins surviving workers
        # atexit), so an evaluator that can deadlock outright should
        # enforce its own hard timeout (subprocess + kill), as a real
        # verification harness does.
        ex.shutdown(wait=False, cancel_futures=True)
    if requeue:
        # fresh executor, fresh deadline — each requeued individual still
        # runs under timeout enforcement (never unbounded inline). Hangs
        # shrink the set every round, so this terminates.
        sub = _run_with_executor(
            executor_kind, workers, evaluate,
            [genes_list[i] for i in requeue], timeout_s,
        )
        for i, r in zip(requeue, sub):
            out[i] = r
    return out


class EvalPool:
    """Evaluates whole GA generations: dedup -> cache -> concurrent misses.

    Parameters
    ----------
    evaluate:
        ``genes -> seconds`` callable (any of the three core evaluators).
        If it exposes ``evaluate_batch(list_of_genes) -> list_of_seconds``
        and ``batch=True``, cache misses go through it in one call (the
        ``CompiledEvaluator`` uses this for its batched AOT-compile path).
    workers:
        Concurrent measurements for the executor path. 1 with the thread
        executor = serial in-line execution (no executor; byte-identical
        to the pre-pool GA loop, and what ``run_ga`` builds when no pool
        is passed). A process pool runs through the executor even at
        workers=1: its subprocess isolation is semantic, not just
        parallelism.
    executor:
        "thread" (default) or "process". Threads suit the analytic and
        compiled evaluators (numpy/XLA release the GIL); processes suit
        CPU-bound Python ``run_fn``s fed to ``MeasuredEvaluator`` —
        but require picklable evaluators.
    cache:
        A :class:`FitnessCache`. Defaults to a fresh in-memory cache.
        If the evaluator provides ``cache_key(genes) -> str``, the POOL
        keys every lookup/store with it (the cache object itself is
        never mutated, so one cache can serve several pools) — this is
        how the mixed-destination evaluator canonicalizes subset-relative
        destination indices to destination names so different searches
        share measurements.
    """

    def __init__(
        self,
        evaluate: Callable[[Genes], float],
        workers: int = 1,
        executor: str = "thread",
        cache: Optional[FitnessCache] = None,
        batch: bool = True,
    ):
        if executor not in ("thread", "process"):
            raise ValueError(f"executor must be thread|process: {executor!r}")
        self.evaluate = evaluate
        self.workers = max(1, int(workers))
        self.executor = executor
        # a cache the pool built itself is closed by close(); a CALLER's
        # cache is left open — it may be serving other pools (the
        # advertised cross-subset sharing), and every put is flushed to
        # disk immediately so nothing is lost either way. Callers that
        # construct a persistent cache own its close().
        self._owns_cache = cache is None
        self.cache = cache if cache is not None else FitnessCache()
        ck = getattr(evaluate, "cache_key", None)
        self.key_fn: Callable[[Genes], str] = (
            ck if callable(ck) else self.cache.key_fn
        )
        self.batch = batch
        self.history: List[GenTelemetry] = []

    # -- single-genome path (kept for spot queries / penalty application) --

    def _penalize(
        self, t: float, timeout_s: float, penalty_time_s: float
    ) -> Tuple[float, bool]:
        ok = (
            t == t  # not NaN
            and t >= 0.0
            and t != float("inf")
            and t < timeout_s
        )
        return (t, False) if ok else (penalty_time_s, True)

    def evaluate_generation(
        self,
        population: Sequence[Genes],
        timeout_s: float,
        penalty_time_s: float,
    ) -> Tuple[List[float], GenTelemetry]:
        """Times for every individual, in population order, plus telemetry.

        Every returned time is post-penalty (the GA consumes it as-is).
        """
        t0 = time.monotonic()
        tel = GenTelemetry(submitted=len(population))
        pop = [tuple(int(g) for g in ind) for ind in population]

        # in-generation dedup + cache lookup, both on the CANONICAL key:
        # genomes that canonicalize identically (e.g. mixed-destination
        # placements that clamp to the same admissible plan) share one
        # measurement even within a generation
        keys = [self.key_fn(ind) for ind in pop]
        unique: Dict[str, Genes] = {}
        for ind, key in zip(pop, keys):
            if key not in unique:
                unique[key] = ind
        tel.unique = len(unique)

        times: Dict[str, float] = {}
        misses: List[Tuple[str, Genes]] = []
        for key, ind in unique.items():
            hit = self.cache.get(ind, key=key)
            if hit is not None:
                # re-validate against THIS run's params: a resumed search
                # may use a tighter timeout than the run that measured
                # the value, in which case the stored time must score as
                # the penalty now (the cache record itself is untouched)
                times[key] = self._penalize(hit, timeout_s, penalty_time_s)[0]
            else:
                misses.append((key, ind))
        # dedup repeats + cache serves both avoid a fresh measurement
        tel.cache_hits = (len(pop) - len(unique)) + (len(unique) - len(misses))
        tel.evaluated = len(misses)

        if misses:
            m0 = time.monotonic()
            raw, lanes = self._measure([ind for _, ind in misses], timeout_s)
            mwall = time.monotonic() - m0
            busy = sum(r[2] for r in raw)
            # barrier stall: lane-seconds held open past their last
            # measurement while the slowest lane finished the generation
            tel.idle_s = max(0.0, mwall * lanes - busy)
            for (key, ind), (t, timed_out, _dur) in zip(misses, raw):
                t, penalized = self._penalize(t, timeout_s, penalty_time_s)
                penalized = penalized or timed_out
                if penalized:
                    t = penalty_time_s
                    tel.timeouts += 1
                times[key] = t
                self.cache.put(ind, t, penalized=penalized, key=key)

        tel.wall_s = time.monotonic() - t0
        self.history.append(tel)
        return [times[key] for key in keys], tel

    def _measure(
        self, misses: List[Genes], timeout_s: float
    ) -> Tuple[List[Tuple[float, bool, float]], int]:
        """-> ((raw seconds, timed_out, busy seconds) per miss, lanes).

        ``lanes`` is the worker count the measurement actually occupied;
        the caller attributes ``wall * lanes - sum(busy)`` as idle time.
        """
        # NOTE: the batch path trusts the evaluator to bound its own time
        # (CompiledEvaluator treats a failed compile as inf itself); only
        # the executor path below enforces the wall-clock deadline. Pass
        # batch=False to force deadline enforcement for a batch-capable
        # evaluator.
        batch_fn = getattr(self.evaluate, "evaluate_batch", None)
        if self.batch and callable(batch_fn):
            try:
                b0 = time.perf_counter()
                vals = batch_fn(misses)
                per = (time.perf_counter() - b0) / max(1, len(vals))
                return [(float(t), False, per) for t in vals], 1
            except Exception:
                pass  # batch path degraded; fall through to point-wise
        # the inline shortcut (byte-identical to the pre-pool GA loop)
        # applies to THREAD pools only: a process pool's subprocess
        # isolation is the point even at workers=1 — measured-fidelity
        # searches must never wall-clock inside the driver process
        if self.workers == 1 and self.executor == "thread":
            out: List[Tuple[float, bool, float]] = []
            for g in misses:
                try:
                    v, dur = _timed_call(self.evaluate, g)
                    out.append((v, False, dur))
                except Exception:
                    out.append((float("inf"), True, 0.0))
            return out, 1
        raw = _run_with_executor(
            self.executor, self.workers, self.evaluate, misses, timeout_s
        )
        # tolerate 2-tuples from substituted executors (tests stub this
        # boundary); busy time simply goes unattributed
        norm = [
            (float(r[0]), bool(r[1]), float(r[2]) if len(r) > 2 else 0.0)
            for r in raw
        ]
        return norm, min(self.workers, len(misses)) or 1

    # -- aggregate telemetry ------------------------------------------------

    def totals(self) -> GenTelemetry:
        tot = GenTelemetry()
        for t in self.history:
            tot.submitted += t.submitted
            tot.unique += t.unique
            tot.cache_hits += t.cache_hits
            tot.evaluated += t.evaluated
            tot.timeouts += t.timeouts
            tot.wall_s += t.wall_s
            tot.idle_s += t.idle_s
        return tot

    def steady_session(
        self, timeout_s: float, penalty_time_s: float
    ) -> "SteadySession":
        """A :class:`SteadySession` over this pool's evaluator, cache,
        key function and worker budget (the steady-state GA's half of
        ``evaluate_generation``)."""
        return SteadySession(self, timeout_s, penalty_time_s)

    def close(self) -> None:
        if self._owns_cache:
            self.cache.close()

    def __enter__(self) -> "EvalPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SteadySession:
    """Continuous evaluation without a generation barrier.

    The generational :meth:`EvalPool.evaluate_generation` holds every
    worker until the slowest measurement of the batch lands (the
    barrier-idle stall the telemetry's ``idle_s`` measures). A steady
    session instead keeps the lanes saturated: the caller ``submit``\\ s
    offspring whenever it has one and ``collect``\\ s finished
    ``(genes, seconds)`` results in completion order, one at a time.

    Semantics match the generational path exactly:

    - **dedup/cache** — submissions are canonicalized through the pool's
      ``key_fn``; persistent-cache hits are re-validated against THIS
      session's timeout (penalty re-applied if the stored time no longer
      fits) and resolve immediately; a submission whose key is already
      in flight never measures twice — it waits on the in-flight result;
    - **timeout -> penalty** — a measurement past ``timeout_s`` is scored
      ``penalty_time_s`` the moment its deadline passes (the straggler
      finishes in the background and its late result is discarded), and
      the penalized record is persisted exactly like the barrier path;
    - **telemetry** — the same :class:`GenTelemetry` counters, windowed:
      :meth:`cut` closes the current window, appends it to
      ``pool.history`` and starts the next, so a steady search still
      emits one telemetry row per generation-equivalent. Within every
      window ``submitted == evaluated + cache_hits`` holds (in-flight
      joins count as hits). ``idle_s`` here attributes *starvation*:
      lane-seconds workers sat free because the caller had nothing in
      flight to give them.

    Thread-safe; ``submit`` may be called from ``collect``'s thread or
    any other. With the pool's inline configuration (1 thread worker)
    submissions evaluate synchronously — byte-identical measurement
    order to the generational inline path.
    """

    def __init__(
        self, pool: EvalPool, timeout_s: float, penalty_time_s: float
    ):
        self.pool = pool
        self.timeout_s = float(timeout_s)
        self.penalty_time_s = float(penalty_time_s)
        self.tel = GenTelemetry()
        self._cond = threading.Condition()
        self._done: List[Tuple[Genes, float]] = []
        # key -> (first-submitted genes, duplicate waiters)
        self._pending: Dict[str, Tuple[Genes, List[Genes]]] = {}
        self._deadlines: Dict[str, float] = {}
        self._zombies: set = set()
        self._inflight = 0
        self._idle = 0.0
        self._seen: set = set()  # per-window unique keys
        self._t0 = time.monotonic()
        self._ex: Optional[cf.Executor] = None
        self._inline = pool.workers == 1 and pool.executor == "thread"

    @property
    def in_flight(self) -> int:
        with self._cond:
            return self._inflight

    def _executor(self) -> cf.Executor:
        if self._ex is None:
            if self.pool.executor == "process":
                import multiprocessing as mp

                self._ex = cf.ProcessPoolExecutor(
                    max_workers=self.pool.workers,
                    mp_context=mp.get_context("spawn"),
                )
            else:
                self._ex = cf.ThreadPoolExecutor(
                    max_workers=self.pool.workers
                )
        return self._ex

    def submit(self, genes: Sequence[int]) -> None:
        """Queue one individual; its result arrives via :meth:`collect`
        (immediately for cache hits, eventually otherwise)."""
        ind = tuple(int(g) for g in genes)
        key = self.pool.key_fn(ind)
        with self._cond:
            self.tel.submitted += 1
            hit = self.pool.cache.get(ind, key=key)
            if hit is not None:
                t = self.pool._penalize(
                    hit, self.timeout_s, self.penalty_time_s
                )[0]
                self.tel.cache_hits += 1
                if key not in self._seen:
                    self._seen.add(key)
                    self.tel.unique += 1
                self._done.append((ind, t))
                self._cond.notify_all()
                return
            if key in self._pending:
                # an identical genome is mid-measurement: join it
                self.tel.cache_hits += 1
                self._pending[key][1].append(ind)
                return
            if key not in self._seen:
                self._seen.add(key)
                self.tel.unique += 1
            self.tel.evaluated += 1
            self._pending[key] = (ind, [])
            self._deadlines[key] = time.monotonic() + self.timeout_s
            self._inflight += 1
        if self._inline:
            try:
                raw = float(self.pool.evaluate(ind))
            except Exception:
                raw = float("inf")
            self._resolve(key, raw)
        else:
            fut = self._executor().submit(
                _timed_call, self.pool.evaluate, ind
            )
            fut.add_done_callback(
                lambda f, k=key: self._on_future(k, f)
            )

    def _on_future(self, key: str, fut: "cf.Future") -> None:
        try:
            raw, _dur = fut.result()
        except Exception:
            raw = float("inf")
        self._resolve(key, float(raw))

    def _resolve(self, key: str, raw: float) -> None:
        t, penalized = self.pool._penalize(
            raw, self.timeout_s, self.penalty_time_s
        )
        with self._cond:
            if key in self._zombies:
                # already deadline-expired and scored as the penalty;
                # the late result is discarded, never double-counted
                self._zombies.discard(key)
                return
            ind, waiters = self._pending.pop(key)
            self._deadlines.pop(key, None)
            self._inflight -= 1
            if penalized:
                t = self.penalty_time_s
                self.tel.timeouts += 1
            self.pool.cache.put(ind, t, penalized=penalized, key=key)
            self._done.append((ind, t))
            for w in waiters:
                self._done.append((w, t))
            self._cond.notify_all()

    def collect(self) -> Tuple[Genes, float]:
        """Block for the next finished individual -> (genes, seconds).

        Results arrive in completion order, duplicates resolving with
        their measured twin. Raises ``RuntimeError`` if nothing is in
        flight and nothing is queued (a deadlocked caller bug)."""
        with self._cond:
            while not self._done:
                if self._inflight == 0:
                    raise RuntimeError(
                        "SteadySession.collect() with no submission in "
                        "flight"
                    )
                now = time.monotonic()
                expired = [
                    k for k, dl in self._deadlines.items() if dl <= now
                ]
                for k in expired:
                    ind, waiters = self._pending.pop(k)
                    del self._deadlines[k]
                    self._zombies.add(k)
                    self._inflight -= 1
                    self.tel.timeouts += 1
                    self.pool.cache.put(
                        ind, self.penalty_time_s, penalized=True, key=k
                    )
                    self._done.append((ind, self.penalty_time_s))
                    for w in waiters:
                        self._done.append((w, self.penalty_time_s))
                if self._done:
                    break
                nxt = min(self._deadlines.values()) - now
                # idle attribution: lanes with no work while we wait
                starved = max(0, self.pool.workers - self._inflight)
                w0 = time.monotonic()
                self._cond.wait(timeout=max(0.001, min(nxt, 0.5)))
                if starved:
                    self._idle += starved * (time.monotonic() - w0)
            ind, t = self._done.pop(0)
            return ind, float(t)

    def cut(self) -> GenTelemetry:
        """Close the current telemetry window: finalize wall/idle, push
        the row to ``pool.history``, start a fresh window."""
        with self._cond:
            tel = self.tel
            tel.wall_s = time.monotonic() - self._t0
            tel.idle_s = self._idle
            self.tel = GenTelemetry()
            self._t0 = time.monotonic()
            self._idle = 0.0
            self._seen = set()
        self.pool.history.append(tel)
        return tel

    def close(self) -> None:
        if self._ex is not None:
            self._ex.shutdown(wait=False, cancel_futures=True)
            self._ex = None
        # a window the caller never cut still reaches the history
        if self.tel.submitted:
            self.cut()

    def __enter__(self) -> "SteadySession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def parallel_map(
    fn: Callable, items: Sequence, workers: int = 1
) -> List:
    """Order-preserving concurrent map on a thread pool (workers<=1 is a
    plain loop). Shared by benchmark drivers for independent, GIL-releasing
    work such as interpret-mode kernel checks."""
    if workers <= 1 or len(items) <= 1:
        return [fn(x) for x in items]
    with cf.ThreadPoolExecutor(max_workers=workers) as ex:
        return list(ex.map(fn, items))
