"""Execution plans: the genome's phenotype.

The paper maps a binary gene string onto OpenACC directives inserted into
loop statements. Here the same gene string maps onto per-unit execution
treatments of a model's stage graph:

- ``Directive`` is assigned per unit by static analysis (``core.analysis``),
  exactly as pgcc's loop classification chooses kernels / parallel loop /
  parallel loop vector in the paper. It is NOT searched by the GA.
- ``offload`` (the 0/1 gene) decides whether the unit receives its directive
  treatment (TP/EP sharding + fused kernels) or runs in the baseline
  data-parallel ("CPU") mode.
- The transfer-reduction flags are set per individual by ``core.transfer``
  (the paper applies data copy / present / temp-area to every individual).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Sequence, Tuple


class Directive(str, enum.Enum):
    #: fused Pallas kernel path (tightly-structured compute) — `acc kernels`
    KERNELS = "kernels"
    #: explicit model-axis sharding: TP / EP / sequence-parallel — `acc parallel loop`
    PARALLEL = "parallel"
    #: no model-axis parallelism available; batch-vectorized only —
    #: `acc parallel loop vector`
    VECTOR = "vector"


@dataclasses.dataclass(frozen=True)
class UnitPlan:
    """Execution treatment for one offload unit (e.g. group 3's attention)."""

    name: str
    directive: Directive
    offload: bool = True  # the GA gene
    # --- transfer-reduction flags (paper §3.3 analogues) -------------------
    bulk_gather: bool = True  # multi-file bulk `data copy`: coalesced FSDP gather
    keep_sharded: bool = True  # `data present`: no reshard between offloaded units
    staged: bool = True  # temp-area: explicit internal sharding constraints
    # --- additional plan knobs ---------------------------------------------
    remat: str = "full"  # none | dots | full
    compress_grads: bool = False
    # --- beyond-paper optimization flags (§Perf; default off = baseline) ----
    # MoE: dispatch tokens locally per data-shard group and let the
    # (group, expert, cap, d) buffer reshard group->expert as an all-to-all
    # instead of a global (unshardable) sort.
    grouped_dispatch: bool = False
    # write projection-einsum outputs in bf16 (MXU still accumulates f32
    # per shard): halves activation HBM traffic AND halves the bytes of the
    # row-parallel partial-sum all-reduce.
    bf16_intermediates: bool = False

    @property
    def active_directive(self) -> Directive:
        return self.directive if self.offload else Directive.VECTOR


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Whole-model plan: one UnitPlan per offload unit, in graph order."""

    units: Tuple[UnitPlan, ...]
    overlap_collectives: bool = True
    microbatches: int = 1

    def __post_init__(self):
        names = [u.name for u in self.units]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate unit names: {names}")

    @property
    def by_name(self) -> Dict[str, UnitPlan]:
        return {u.name: u for u in self.units}

    def unit(self, name: str) -> UnitPlan:
        return self.by_name[name]

    def get(self, name: str, default: Optional[UnitPlan] = None):
        return self.by_name.get(name, default)

    def genes(self) -> Tuple[int, ...]:
        return tuple(int(u.offload) for u in self.units)

    def with_genes(self, genes: Sequence[int]) -> "ExecutionPlan":
        assert len(genes) == len(self.units)
        units = tuple(
            dataclasses.replace(u, offload=bool(g))
            for u, g in zip(self.units, genes)
        )
        return dataclasses.replace(self, units=units)

    def with_flags(self, **flags) -> "ExecutionPlan":
        """Set transfer/remat flags uniformly across units."""
        units = tuple(dataclasses.replace(u, **flags) for u in self.units)
        return dataclasses.replace(self, units=units)

    def describe(self) -> str:
        rows = []
        for u in self.units:
            rows.append(
                f"  {u.name:14s} {u.directive.value:9s} gene={int(u.offload)} "
                f"bulk={int(u.bulk_gather)} present={int(u.keep_sharded)} "
                f"staged={int(u.staged)} remat={u.remat}"
            )
        return "\n".join(rows)


def default_unit(name: str, directive: Directive, **kw) -> UnitPlan:
    return UnitPlan(name=name, directive=directive, **kw)
