"""The offload-search GA (paper §4-5, parameters kept exactly).

- fitness = (processing time)^(-1/2) — the -1/2 power keeps one fast
  individual from collapsing the roulette distribution (paper §5.1.2).
- roulette selection + elite preservation (best individual copied unchanged).
  Implementation detail the paper leaves unstated: fitness *windowing*
  (subtracting the generation's worst fitness before the spin) — the
  textbook roulette practice; without it t^-1/2 on same-order times gives
  near-uniform selection and the search drifts. The -1/2 power still damps
  over-concentration exactly as §5.1.2 intends.
- crossover rate Pc = 0.9, mutation rate Pm = 0.05 per gene. Crossover
  operator unstated in the paper: uniform crossover (better building-block
  mixing at gene length 65 than single-point; both provided).
- measurement timeout: an individual whose verification run exceeds the
  timeout (3 min in the paper) is scored as penalty_time_s = 1000 s.
- fitness cache: identical gene patterns recur across generations (paper
  §5.2 notes this); their measurement is reused, which is what made the
  paper's 7-hour search budget feasible.

The evaluator is any ``genes -> seconds`` callable: the analytic cost model,
the measured miniapp runner, or the compiled-roofline evaluator for the
framework-level search.

Evaluation goes through :mod:`repro.core.evalpool`: the GA submits each
whole generation to an :class:`~repro.core.evalpool.EvalPool`, which
dedups identical gene patterns, serves repeats from a (optionally
persistent on-disk) fitness cache, and measures the remaining unique
individuals concurrently. ``run_ga`` with no pool builds a serial
in-memory pool — identical results to the original point-wise loop for
well-behaved evaluators; the one semantic difference is that an
evaluator that *raises* is scored as the penalty (the pgcc
compile-error analogue) instead of aborting the whole search.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import genome as G
from repro.core.evalpool import EvalPool

Genes = G.Genes


@dataclasses.dataclass(frozen=True)
class GAParams:
    population: int
    generations: int
    crossover_rate: float = 0.9
    mutation_rate: float = 0.05
    elites: int = 1
    timeout_s: float = 180.0  # 3-minute measurement timeout
    penalty_time_s: float = 1000.0
    seed: int = 0
    crossover_kind: str = "uniform"  # "uniform" | "single_point"
    fitness_windowing: bool = True  # subtract generation-worst before roulette
    # gene alphabet size: 2 = the paper's binary offload/stay genome
    # (byte-identical to the pre-k-ary GA); >2 = mixed-destination search
    # (arXiv:2011.12431) where each gene is a destination index
    alleles: int = 2
    # fitness-sharing strength: an individual's roulette fitness is
    # divided by (copies of its genome in the generation) ** diversity,
    # so a converged majority stops amplifying itself. 0.0 = off — the
    # historical selection, byte-identical (the sharing block is never
    # entered). Exposed as OffloadSpec.ga.diversity.
    diversity: float = 0.0
    # asynchronous steady-state mode: after the generation-0 barrier,
    # offspring are bred and submitted continuously (one replacement per
    # completed measurement, conditional on not being worse than the
    # current worst) instead of waiting out a full-generation barrier —
    # workers never idle behind a straggler. False = the historical
    # generational loop, byte-identical. Exposed as
    # OffloadSpec.ga.steady_state.
    steady_state: bool = False

    @classmethod
    def for_gene_length(cls, n: int, **kw) -> "GAParams":
        """Paper rule: population M <= gene length, generations T <= gene
        length (Himeno 13 -> M=10 T=10; NAS.FT 65 -> M=30 T=20)."""
        m = min(n, 10 if n <= 16 else 30)
        t = min(n, 10 if n <= 16 else 20)
        return cls(population=m, generations=t, **kw)


@dataclasses.dataclass
class GenerationStats:
    generation: int
    best_time_s: float
    mean_time_s: float
    best_genes: Genes
    evaluations: int
    cache_hits: int
    # per-generation search telemetry (evalpool); defaults keep older
    # call sites constructing GenerationStats by position working
    gen_wall_s: float = 0.0
    dedup_ratio: float = 0.0
    hit_rate: float = 0.0
    # full generation snapshot (observability): the evaluated population
    # and its per-individual times, in population order — what the trace
    # layer computes allele entropy / median fitness from and what the
    # pipeline persists as the search's final population
    times: Optional[List[float]] = None
    population: Optional[List[Genes]] = None


@dataclasses.dataclass
class GAResult:
    best_genes: Genes
    best_time_s: float
    history: List[GenerationStats]
    evaluations: int
    cache_hits: int
    wall_s: float

    def speedup_over(self, baseline_time_s: float) -> float:
        return baseline_time_s / self.best_time_s if self.best_time_s else 0.0


def fitness_of_time(t: float) -> float:
    """(processing time)^(-1/2)."""
    return float(max(t, 1e-12)) ** -0.5


def _selection_fitness(
    params: GAParams, pop: Sequence[Genes], times: Sequence[float]
) -> List[float]:
    """times -> roulette fitness: t^-1/2, windowed, diversity-shared.

    One code path for both GA modes — the exact float operations of the
    historical generational loop, so extracting it is byte-neutral.
    """
    fit = [fitness_of_time(t) for t in times]
    if params.fitness_windowing and len(fit) > 1:
        worst = min(fit)
        fit = [f - worst for f in fit]
    if params.diversity > 0.0:
        # fitness sharing: divide each individual's roulette share by
        # (its genome's copy count this generation) ** diversity
        counts: Dict[Genes, int] = {}
        for ind in pop:
            counts[ind] = counts.get(ind, 0) + 1
        fit = [
            f / (counts[ind] ** params.diversity)
            for f, ind in zip(fit, pop)
        ]
    return fit


def run_ga(
    evaluate: Optional[Callable[[Genes], float]],
    gene_length: int,
    params: GAParams,
    on_generation: Optional[Callable[[GenerationStats], None]] = None,
    pool: Optional[EvalPool] = None,
    seeds: Optional[Sequence[Genes]] = None,
) -> GAResult:
    """Run the offload GA.

    ``pool`` is the evaluation pool a whole generation is submitted to;
    when omitted, a serial in-memory pool wrapping ``evaluate`` is built
    (the original point-wise behavior). Pass an :class:`EvalPool` with
    ``workers > 1`` and/or a persistent :class:`FitnessCache` to
    parallelize measurements and survive restarts; ``evaluate`` may then
    be ``None``.

    ``seeds`` warm-starts the search: the given genomes replace the
    first ``len(seeds)`` individuals of the random initial population
    (genome-aware seeding — e.g. single-destination bests re-expressed
    in the mixed k-ary alphabet). The random population is drawn FIRST
    with the same RNG pulls either way, so ``seeds=None`` is
    byte-identical to the pre-seeding GA and a seeded run's evolution
    stream differs only through selection, never through the generator.
    """
    if pool is None:
        if evaluate is None:
            raise ValueError("run_ga needs either evaluate or pool")
        pool = EvalPool(evaluate)
    if params.diversity < 0.0:
        raise ValueError(f"diversity must be >= 0: {params.diversity}")
    rng = np.random.default_rng(params.seed)
    evals0, hits0 = pool.totals().evaluated, pool.totals().cache_hits

    t0 = time.time()
    pop = G.initial_population(
        rng, gene_length, params.population, params.alleles
    )
    for i, s in enumerate(seeds or ()):
        if i >= len(pop):
            break
        s = tuple(int(x) for x in s)
        if len(s) != gene_length:
            raise ValueError(f"seed {i}: length {len(s)} != {gene_length}")
        if any(not (0 <= x < params.alleles) for x in s):
            raise ValueError(f"seed {i} has alleles outside [0, {params.alleles})")
        pop[i] = s
    if params.steady_state and params.generations > 1:
        return _run_steady(
            pool, params, on_generation, rng, pop, t0, evals0, hits0
        )
    history: List[GenerationStats] = []
    best_genes: Genes = pop[0]
    best_time = float("inf")

    for gen in range(params.generations):
        times, tel = pool.evaluate_generation(
            pop, params.timeout_s, params.penalty_time_s
        )
        tot = pool.totals()
        order = np.argsort(times)
        if times[order[0]] < best_time:
            best_time = times[order[0]]
            best_genes = pop[order[0]]
        gs = GenerationStats(
            generation=gen,
            best_time_s=best_time,
            mean_time_s=float(np.mean(times)),
            best_genes=best_genes,
            evaluations=tot.evaluated - evals0,
            cache_hits=tot.cache_hits - hits0,
            gen_wall_s=tel.wall_s,
            dedup_ratio=tel.dedup_ratio,
            hit_rate=tel.hit_rate,
            times=[float(t) for t in times],
            population=list(pop),
        )
        history.append(gs)
        if on_generation:
            on_generation(gs)
        if gen == params.generations - 1:
            break

        fit = _selection_fitness(params, pop, times)
        # elite preservation: the generation's best survive unchanged
        elite_idx = list(order[: params.elites])
        nxt: List[Genes] = [pop[i] for i in elite_idx]
        xover = (
            G.uniform_crossover
            if params.crossover_kind == "uniform"
            else G.crossover
        )
        while len(nxt) < params.population:
            pa = G.roulette_pick(rng, pop, fit)
            pb = G.roulette_pick(rng, pop, fit)
            ca, cb = xover(rng, pa, pb, params.crossover_rate)
            nxt.append(G.mutate(rng, ca, params.mutation_rate, params.alleles))
            if len(nxt) < params.population:
                nxt.append(
                    G.mutate(rng, cb, params.mutation_rate, params.alleles)
                )
        pop = nxt

    tot = pool.totals()
    return GAResult(
        best_genes=best_genes,
        best_time_s=best_time,
        history=history,
        evaluations=tot.evaluated - evals0,
        cache_hits=tot.cache_hits - hits0,
        wall_s=time.time() - t0,
    )


def _run_steady(
    pool: EvalPool,
    params: GAParams,
    on_generation: Optional[Callable[[GenerationStats], None]],
    rng: np.random.Generator,
    pop: List[Genes],
    t0: float,
    evals0: int,
    hits0: int,
) -> GAResult:
    """The steady-state tail of ``run_ga`` (``params.steady_state``).

    Generation 0 still prices as one barrier batch (a full random
    population has no completion order worth exploiting, and it gives
    the selection pool a complete fitness picture). After that the loop
    breeds one offspring per free worker lane and replaces the current
    worst individual the moment any measurement lands — no generation
    barrier, so a straggler delays only its own slot:

    - **budget** — exactly ``population * generations`` submissions
      total, same as the generational loop: the initial barrier plus
      ``population * (generations - 1)`` steady offspring.
    - **monotone best** — replacement is conditional (an offspring only
      displaces the worst member if it is no worse), so the best-so-far
      genome is never lost; elitism is implicit.
    - **telemetry windows** — every ``population`` completions the
      session's telemetry window is cut into ``pool.history`` and a
      :class:`GenerationStats` row is emitted, so tracing/reporting see
      the same one-row-per-generation shape as the barrier GA.

    With ``workers > 1`` the completion order (hence the RNG schedule)
    depends on measurement timing — steady-state runs trade generational
    reproducibility for lane saturation. At ``workers=1`` the loop is
    submit-one/collect-one and fully deterministic.
    """
    times, _tel = pool.evaluate_generation(
        pop, params.timeout_s, params.penalty_time_s
    )
    cur: List[Tuple[Genes, float]] = [
        (ind, float(t)) for ind, t in zip(pop, times)
    ]
    order = np.argsort(times)
    best_time = float(times[order[0]])
    best_genes: Genes = pop[order[0]]
    history: List[GenerationStats] = []

    def snapshot(gen: int) -> None:
        tot = pool.totals()
        tel = pool.history[-1]
        ts = [t for _, t in cur]
        gs = GenerationStats(
            generation=gen,
            best_time_s=best_time,
            mean_time_s=float(np.mean(ts)),
            best_genes=best_genes,
            evaluations=tot.evaluated - evals0,
            cache_hits=tot.cache_hits - hits0,
            gen_wall_s=tel.wall_s,
            dedup_ratio=tel.dedup_ratio,
            hit_rate=tel.hit_rate,
            times=list(ts),
            population=[g for g, _ in cur],
        )
        history.append(gs)
        if on_generation:
            on_generation(gs)

    snapshot(0)
    xover = (
        G.uniform_crossover
        if params.crossover_kind == "uniform"
        else G.crossover
    )

    def breed() -> Genes:
        genomes = [g for g, _ in cur]
        fit = _selection_fitness(params, genomes, [t for _, t in cur])
        pa = G.roulette_pick(rng, genomes, fit)
        pb = G.roulette_pick(rng, genomes, fit)
        ca, _cb = xover(rng, pa, pb, params.crossover_rate)
        return G.mutate(rng, ca, params.mutation_rate, params.alleles)

    budget = params.population * (params.generations - 1)
    launched = finished = 0
    with pool.steady_session(params.timeout_s, params.penalty_time_s) as ses:
        while finished < budget:
            # top up the lanes; the launched-finished bound (not the
            # session's in-flight count, which cache hits never enter)
            # keeps the inline pool breeding one offspring at a time
            while (
                launched < budget
                and launched - finished < max(1, pool.workers)
            ):
                ses.submit(breed())
                launched += 1
            genes, tm = ses.collect()
            finished += 1
            wi = max(range(len(cur)), key=lambda i: cur[i][1])
            if tm <= cur[wi][1]:
                cur[wi] = (genes, tm)
            if tm < best_time:
                best_time = tm
                best_genes = genes
            if finished % params.population == 0:
                ses.cut()
                snapshot(finished // params.population)

    tot = pool.totals()
    return GAResult(
        best_genes=best_genes,
        best_time_s=best_time,
        history=history,
        evaluations=tot.evaluated - evals0,
        cache_hits=tot.cache_hits - hits0,
        wall_s=time.time() - t0,
    )
