"""Loop-program IR: the paper's "application loop statements" made explicit.

The paper's pipeline parses a C/C++ application with Clang, finds its ``for``
statements, records the variables each loop reads/writes, and lets pgcc
classify every loop (kernels-able / parallel-able / vectorizable-only /
not offloadable). This module is that parse result as a first-class IR:

- ``Var``     — one array/scalar with size, definition site and init info
                (the fields the paper's transfer analysis keys on: global vs
                local, initialized where, defined in which file).
- ``Loop``    — one loop statement with nest structure, trip counts,
                read/write sets, arithmetic cost, and the pgcc-style
                classification flags.
- ``LoopProgram`` — the whole application: ordered loops + vars + the
                enclosing "time-step" iteration structure.

``core.analysis_loops`` classifies loops into directives, ``core.transfer``
builds the CPU-GPU transfer schedule for a genome, and ``core.evaluator``
turns (genome, schedule) into a predicted wall time. ``core.miniapps``
instantiates Himeno and NAS.FT as LoopPrograms with the paper's gene lengths
(13 and 65).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple


class LoopClass(str, enum.Enum):
    """pgcc-style loop classification (paper §3.3 / §4)."""

    TIGHT = "tight"  # single / tightly-nested -> `acc kernels`
    NON_TIGHT = "non_tight"  # non-tightly-nested -> `acc parallel loop`
    VECTOR_ONLY = "vector_only"  # not parallelizable, vectorizable -> `acc parallel loop vector`
    NOT_OFFLOADABLE = "not_offloadable"  # pgcc compile error -> excluded from GA


@dataclasses.dataclass(frozen=True)
class Var:
    """One program variable (array or scalar)."""

    name: str
    nbytes: int
    file: str = "main.c"
    is_global: bool = False
    # True when the compiler cannot prove the init site (other function /
    # other file): PGI then inserts conservative auto-transfers around every
    # kernel using it unless the temp-area staging blocks them (paper fig. 2).
    init_external: bool = False

    def __post_init__(self):
        assert self.nbytes >= 0


@dataclasses.dataclass(frozen=True)
class Loop:
    """One loop statement (outermost loop of a nest, or a nest level)."""

    name: str
    klass: LoopClass
    trip: int  # iterations of THIS loop level
    inner_trip: int  # product of inner-loop iterations (work per trip)
    flops_per_iter: float  # arithmetic per innermost iteration
    reads: FrozenSet[str]
    writes: FrozenSet[str]
    file: str = "main.c"
    # name of the enclosing *sequential* iteration construct (e.g. the Jacobi
    # time-step loop). Transfers hoisted only to nest level re-run once per
    # enclosing iteration; bulk transfers can cross it when dataflow allows.
    parent_seq: Optional[str] = None
    # innermost-dim contiguity: vectorizable-only loops run at lane (VPU)
    # rather than MXU rates on the accelerator
    sequential_carry: bool = False

    @property
    def total_flops(self) -> float:
        return self.flops_per_iter * self.trip * self.inner_trip

    @property
    def offloadable(self) -> bool:
        return self.klass != LoopClass.NOT_OFFLOADABLE

    def touched(self) -> FrozenSet[str]:
        return self.reads | self.writes


@dataclasses.dataclass(frozen=True)
class SeqRegion:
    """A sequential enclosing iteration (time-step loop): loops listed inside
    it execute ``trip`` times per program run."""

    name: str
    trip: int


@dataclasses.dataclass(frozen=True)
class LoopProgram:
    name: str
    loops: Tuple[Loop, ...]
    vars: Tuple[Var, ...]
    seq_regions: Tuple[SeqRegion, ...] = ()
    description: str = ""

    def __post_init__(self):
        names = [l.name for l in self.loops]
        assert len(set(names)) == len(names), "duplicate loop names"
        vnames = {v.name for v in self.vars}
        for l in self.loops:
            missing = (l.reads | l.writes) - vnames
            assert not missing, f"{l.name} touches undeclared vars {missing}"
        region_names = {r.name for r in self.seq_regions}
        for l in self.loops:
            assert l.parent_seq is None or l.parent_seq in region_names

    # -- gene mapping (paper: gene length = number of offloadable loops) ----
    @property
    def offloadable_loops(self) -> Tuple[Loop, ...]:
        return tuple(l for l in self.loops if l.offloadable)

    @property
    def gene_length(self) -> int:
        return len(self.offloadable_loops)

    def var(self, name: str) -> Var:
        return {v.name: v for v in self.vars}[name]

    def region_trip(self, name: Optional[str]) -> int:
        if name is None:
            return 1
        return {r.name: r.trip for r in self.seq_regions}[name]

    def genes_to_offloads(self, genes: Sequence[int]) -> Dict[str, bool]:
        """Map a genome onto {loop name: offloaded?} (non-offloadable: False)."""
        assert len(genes) == self.gene_length, (len(genes), self.gene_length)
        out = {l.name: False for l in self.loops}
        for g, l in zip(genes, self.offloadable_loops):
            out[l.name] = bool(g)
        return out

    def total_flops(self) -> float:
        return sum(
            l.total_flops * self.region_trip(l.parent_seq) for l in self.loops
        )

    def fingerprint(self) -> str:
        """Stable structural digest: name alone is NOT enough to key a
        persistent fitness cache — the same app at a different grid size
        or trip count has completely different loop times. Covers every
        field the evaluators read (loops, vars, regions)."""
        import hashlib

        parts = []
        for l in self.loops:
            parts.append(
                f"{l.name}:{l.klass.value}:{l.trip}:{l.inner_trip}"
                f":{l.flops_per_iter:.6g}:{','.join(sorted(l.reads))}"
                f":{','.join(sorted(l.writes))}:{l.parent_seq}"
                f":{int(l.sequential_carry)}"
            )
        parts += [f"{v.name}:{v.nbytes}:{int(v.is_global)}"
                  f":{int(v.init_external)}" for v in self.vars]
        parts += [f"{r.name}:{r.trip}" for r in self.seq_regions]
        digest = hashlib.sha256("|".join(parts).encode()).hexdigest()[:12]
        return f"{self.name}-{digest}"

    def describe(self) -> str:
        rows = [f"LoopProgram {self.name}: {len(self.loops)} loops "
                f"({self.gene_length} offloadable = gene length)"]
        for l in self.loops:
            rows.append(
                f"  {l.name:24s} {l.klass.value:16s} trip={l.trip}x{l.inner_trip} "
                f"flops={l.total_flops:.3g} R={sorted(l.reads)} W={sorted(l.writes)}"
            )
        return "\n".join(rows)
