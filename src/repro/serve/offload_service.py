"""Offload-as-a-service: concurrent `Offloader` runs over one shared
fitness-cache store, with admission control and crash-safe jobs.

The paper's end state is environment-adaptive software as a *service*
(arXiv:2002.12115 §6): users submit once-written code, the platform
converts/verifies/places it per environment. This module is that shape
for the repro pipeline: an :class:`OffloadService` accepts
:class:`~repro.offload.spec.OffloadSpec` submissions into a
filesystem-backed queue directory (:class:`~repro.serve.jobs.JobStore`),
admits them under an :class:`~repro.serve.admission.AdmissionPolicy`
(budget clamps, duplicate coalescing), and drains the queue over a
bounded worker pool — every job one full `Offloader` pipeline, all jobs
multiplexed over ONE shared JSONL fitness-cache store through an
:class:`~repro.core.evalpool.EvalBroker` (cache keys are evaluator-
fingerprinted, so cross-user sharing is safe and is the whole point: a
repeat submission is mostly cache hits).

Crash safety: the artifact IS the job record (:mod:`repro.serve.jobs`),
the cache store survives any kill, and :meth:`OffloadService.recover`
re-queues every artifact left RUNNING — so *restart = resume every
non-terminal job*, with zero re-measurement of anything already paid
for. Because a server is only as good as its behavior under crashes,
fault injection is built in (:class:`FaultPlan`): the test suite — and
``--fault`` on the CLI — can raise inside a stage, crash the service
after a stage, or SIGKILL it mid-search at a chosen generation
(docs/serving.md#fault-injection).

Single-run parity: nothing here is imported by the pipeline; an
`Offloader` used directly is byte-identical to PR-8 behavior
(regression-tested in tests/test_offload_service.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import threading
import time
from concurrent.futures import FIRST_EXCEPTION, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.evalpool import EvalBroker, evaluator_fingerprint
from repro.offload import trace as trace_mod
from repro.offload.pipeline import Offloader, _spec_digest
from repro.offload.result import STAGES, OffloadResult
from repro.offload.spec import OffloadSpec
from repro.serve.admission import AdmissionPolicy, admit
from repro.serve import jobs as jb


class ServiceCrash(RuntimeError):
    """Simulated process death (fault injection): the service run loop
    aborts WITHOUT transitioning the job — on disk it stays RUNNING,
    exactly as a SIGKILL would leave it, so recovery paths are testable
    in-process in the fast tier."""


# fault kinds: where the failure fires and what it does there.
#   raise-in-stage:<stage>    raise before entering <stage> -> job FAILED
#   raise-in-search:<gen>     raise from the GA loop at generation <gen>
#                             (the "evaluator blew up" fault) -> FAILED
#   crash-after-stage:<stage> ServiceCrash after <stage> completes
#   crash-in-search:<gen>     ServiceCrash from the GA loop at <gen>
#   kill-after-stage:<stage>  SIGKILL self after <stage> completes
#   kill-in-search:<gen>      SIGKILL self from the GA loop at <gen>
_FAULT_KINDS = (
    "raise-in-stage", "raise-in-search",
    "crash-after-stage", "crash-in-search",
    "kill-after-stage", "kill-in-search",
)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One injected fault, parsed from ``<kind>:<arg>[@<job-match>]``
    (e.g. ``crash-in-search:7``, ``raise-in-stage:verify@jb-ab12``).
    ``job-match`` is a substring filter on the job id; omitted = every
    job. The search-generation faults fire from the Offloader's
    per-generation callback, i.e. *after* that generation's measurements
    are in the shared cache — which is what makes kill-at-last-generation
    the canonical "resume must re-measure nothing" scenario."""

    kind: str
    arg: str
    match: str = ""

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        body, _, match = text.partition("@")
        kind, sep, arg = body.partition(":")
        if not sep or kind not in _FAULT_KINDS:
            raise ValueError(
                f"fault must be <kind>:<arg>[@<job-match>] with kind in "
                f"{_FAULT_KINDS}; got {text!r}"
            )
        if kind.endswith("-in-search"):
            int(arg)  # generation number; raise early on junk
        return cls(kind=kind, arg=arg, match=match)

    def applies_to(self, job_id: str) -> bool:
        return self.match in job_id

    def _fire(self, what: str) -> None:
        if self.kind.startswith("raise-"):
            raise RuntimeError(f"fault injected: {what}")
        if self.kind.startswith("crash-"):
            raise ServiceCrash(f"fault injected: {what}")
        os.kill(os.getpid(), signal.SIGKILL)  # kill-*: no cleanup at all

    def before_stage(self, job_id: str, stage: str) -> None:
        if self.kind == "raise-in-stage" and self.arg == stage \
                and self.applies_to(job_id):
            self._fire(f"{self.kind}:{stage}")

    def after_stage(self, job_id: str, stage: str) -> None:
        if self.kind in ("crash-after-stage", "kill-after-stage") \
                and self.arg == stage and self.applies_to(job_id):
            self._fire(f"{self.kind}:{stage}")

    def on_generation(self, job_id: str, generation: int) -> None:
        if self.kind.endswith("-in-search") and self.applies_to(job_id) \
                and generation == int(self.arg):
            self._fire(f"{self.kind}:{generation}")


@dataclasses.dataclass(frozen=True)
class SubmitReceipt:
    """What :meth:`OffloadService.submit` hands back."""

    job_id: str
    coalesced: bool  # True: job_id is an EXISTING job covering this spec
    digest: str  # the spec's coalesce key
    clamped: Dict[str, List[int]]  # admission clamps applied (may be {})


def _cache_stats(art: OffloadResult) -> Tuple[int, int]:
    """(cache hits, fresh measurements) this artifact's recorded search
    work paid — the per-job cache hit-rate the trace reports."""
    hits = evals = 0
    if "seed" in art.stages:
        for info in art.stages["seed"].payload.get("seed_info", []):
            hits += int(info.get("cache_hits", 0))
            evals += int(info.get("evaluations", 0))
    if "search" in art.stages:
        p = art.stages["search"].payload
        hits += int(p.get("cache_hits", 0))
        evals += int(p.get("evaluations", 0))
    return hits, evals


class OffloadService:
    """The queue-fed offload search service (docs/serving.md).

    Parameters
    ----------
    root:
        The queue directory (:class:`~repro.serve.jobs.JobStore` layout).
        Everything the service is — jobs, artifacts, traces, the shared
        fitness-cache store — lives under it; a second construction over
        the same directory (after a crash, in another process) sees the
        same service.
    policy:
        Admission policy; defaults to :class:`AdmissionPolicy` defaults
        (2 in-flight, no budget bounds, coalescing on).
    fault:
        Optional :class:`FaultPlan` — the fault-injection harness the
        test suite and ``serve run --fault`` use.
    trace_clock:
        Injected clock for the service's trace records (tests pin it;
        timing never enters trace digests either way).
    """

    def __init__(
        self,
        root: str,
        policy: Optional[AdmissionPolicy] = None,
        fault: Optional[FaultPlan] = None,
        trace_clock: Optional[Callable[[], float]] = None,
    ):
        self.store = jb.JobStore(root)
        self.policy = policy or AdmissionPolicy()
        self.fault = fault
        self._trace_clock = trace_clock
        # one submission at a time: concurrent identical submissions
        # must see each other (coalesce), not race to the anchor id
        self._submit_lock = threading.Lock()
        self._gauge_lock = threading.Lock()
        self._in_flight = 0
        self.max_in_flight_seen = 0  # high-water mark (stress tests)

    # -- submission --------------------------------------------------------

    def normalize(self, spec: OffloadSpec) -> OffloadSpec:
        """The spec as the service runs it: the fitness cache pinned to
        the service's shared store (every job shares it — including the
        report stage's stability re-searches, which open it by path)."""
        if spec.cache == self.store.cache_path:
            return spec
        return dataclasses.replace(spec, cache=self.store.cache_path)

    def submit(self, spec: OffloadSpec, force: bool = False) -> SubmitReceipt:
        """Admit one spec. Duplicate submissions (same coalesce key as a
        live or DONE job) return that job's id instead of searching
        twice; ``force=True`` runs a fresh job anyway (it still shares
        the fitness cache, so it is mostly hits). FAILED/CANCELLED
        anchors never absorb a submission — resubmitting is the retry
        path."""
        decision = admit(self.normalize(spec), self.policy)
        digest = jb.coalesce_key(decision.spec)
        with self._submit_lock:
            return self._submit_admitted(decision, digest, force)

    def _submit_admitted(self, decision, digest: str,
                         force: bool) -> SubmitReceipt:
        if self.policy.coalesce and not force:
            live = [j for j in self.store.by_digest(digest)
                    if j.state not in (jb.FAILED, jb.CANCELLED)]
            if live:
                anchor = live[0]  # lowest seq: the original submission
                self.store.record_coalesced(anchor.id, digest)
                return SubmitReceipt(job_id=anchor.id, coalesced=True,
                                     digest=digest,
                                     clamped=decision.clamped)
        job_id = self.store.allocate_id(digest)
        job = jb.Job(
            id=job_id, state=jb.QUEUED, digest=digest,
            seq=self.store.next_seq(), clamped=decision.clamped,
            submitted_ts=time.time(),
        )
        self.store.create(decision.spec, job)
        # the job's trace starts here: the service writes the run header
        # (so the file validates stand-alone even if the job never runs)
        # plus the admission record; the Offloader appends its own header
        # and spans later. All wall clocks go under `timing` — service
        # records must not perturb trace digests' determinism rules.
        with trace_mod.TraceWriter(self.store.trace_path(job_id),
                                   clock=self._trace_clock) as w:
            w.run_header(
                program=decision.spec.program, mode=decision.spec.mode,
                fidelity=decision.spec.fidelity,
                spec_digest=_spec_digest(decision.spec), resumed=False,
            )
            w.event("job_submitted", span="service",
                    attrs={"job": job_id, "digest": digest,
                           "seq": job.seq, "forced": bool(force)},
                    timing={"submitted_ts": job.submitted_ts})
            w.event("admission", span="service", attrs={
                "max_in_flight": self.policy.max_in_flight,
                "clamped": {k: list(v)
                            for k, v in sorted(decision.clamped.items())},
            })
        return SubmitReceipt(job_id=job_id, coalesced=False, digest=digest,
                             clamped=decision.clamped)

    # -- queries / control -------------------------------------------------

    def status(self, job_id: str) -> jb.Job:
        return self.store.job(job_id)

    def jobs(self) -> List[jb.Job]:
        return self.store.list_jobs()

    def result(self, job_id: str) -> OffloadResult:
        return self.store.load(job_id)

    def cancel(self, job_id: str) -> jb.Job:
        """Request cancellation. A QUEUED job is finalized by the next
        scheduler pass before it starts; a RUNNING job stops at the next
        stage boundary; a terminal job ignores the request."""
        return self.store.request_cancel(job_id)

    # -- crash recovery ----------------------------------------------------

    def recover(self) -> List[str]:
        """Re-queue every job a dead service left RUNNING (the artifact
        is the job record, so this is a directory scan + transition).
        Also repairs a torn trailing trace line a SIGKILL mid-write can
        leave. Returns the re-queued job ids."""
        out: List[str] = []
        for j in self.store.list_jobs():
            if j.state != jb.RUNNING:
                continue
            _repair_trace_tail(self.store.trace_path(j.id))
            art = self.store.load(j.id)
            with trace_mod.TraceWriter(self.store.trace_path(j.id),
                                       clock=self._trace_clock) as w:
                w.event("job_requeued", span="service",
                        attrs={"job": j.id, "restarts": j.restarts + 1})
                art.trace = w.summary()
            self.store.transition(art, jb.QUEUED, restarted=True)
            out.append(j.id)
        return out

    # -- the scheduler -----------------------------------------------------

    def run(self) -> List[jb.Job]:
        """Recover, then drain the queue: every QUEUED job runs exactly
        once, at most ``policy.max_in_flight`` concurrently, in
        admission order. Returns the final job list. A ServiceCrash
        fault aborts the drain mid-flight (pending jobs stay QUEUED,
        the crashed one stays RUNNING) and re-raises — callers treat it
        as process death."""
        self.recover()
        broker = EvalBroker(self.store.cache_path)
        ex = ThreadPoolExecutor(max_workers=self.policy.max_in_flight)
        try:
            futs = [
                ex.submit(self._run_job_gauged, j.id, broker)
                for j in self.store.list_jobs() if j.state == jb.QUEUED
            ]
            done, _ = wait(futs, return_when=FIRST_EXCEPTION)
            for f in done:
                exc = f.exception()
                if exc is not None:
                    raise exc  # ServiceCrash: simulated death, mid-drain
            ex.shutdown(wait=True)
        except BaseException:
            ex.shutdown(wait=False, cancel_futures=True)
            raise
        finally:
            broker.close()
        return self.store.list_jobs()

    def _run_job_gauged(self, job_id: str, broker: EvalBroker) -> None:
        with self._gauge_lock:
            self._in_flight += 1
            self.max_in_flight_seen = max(self.max_in_flight_seen,
                                          self._in_flight)
        try:
            self._run_job(job_id, broker)
        finally:
            with self._gauge_lock:
                self._in_flight -= 1

    def _run_job(self, job_id: str, broker: EvalBroker) -> None:
        art = self.store.load(job_id)
        job = jb.Job.from_dict(art.job)
        if job.state != jb.QUEUED:
            return  # raced to terminal, or owned elsewhere
        if self.store.cancel_requested(job_id):
            self._finalize(art, jb.CANCELLED,
                           error="cancelled before start")
            return
        self.store.transition(art, jb.RUNNING)
        with trace_mod.TraceWriter(self.store.trace_path(job_id),
                                   clock=self._trace_clock) as w:
            w.event("job_started", span="service",
                    attrs={"job": job_id, "restarts": job.restarts},
                    timing={"queue_wait_s":
                            max(0.0, time.time() - job.submitted_ts)})

        fault = self.fault

        def on_generation(gs) -> None:
            if fault is not None:
                fault.on_generation(job_id, int(gs.generation))

        # the Offloader appends to the same trace file (its writer
        # replays the service's records and continues the sequence); the
        # service writes NOTHING more until the pipeline is done — two
        # live writers on one trace would fork the seq numbering.
        off = Offloader(
            art.spec, artifact=art,
            artifact_path=self.store.artifact_path(job_id),
            trace_path=self.store.trace_path(job_id),
            trace_clock=self._trace_clock,
            on_generation=on_generation,
            cache_factory=lambda ev: broker.open_cache(
                evaluator_fingerprint(ev)),
        )
        try:
            for name in STAGES:
                if art.completed(name):
                    continue
                if self.store.cancel_requested(job_id):
                    self._finalize(art, jb.CANCELLED,
                                   error=f"cancelled before stage {name!r}")
                    return
                if fault is not None:
                    fault.before_stage(job_id, name)
                off.run_stage(name)
                if fault is not None:
                    fault.after_stage(job_id, name)
        except ServiceCrash:
            raise  # job stays RUNNING on disk: that IS the crash state
        except Exception as e:  # noqa: BLE001 — any stage/injected error
            self._finalize(art, jb.FAILED, error=repr(e))
            return
        self._finalize(art, jb.DONE)

    def _finalize(self, art: OffloadResult, state: str,
                  error: Optional[str] = None) -> jb.Job:
        """Terminal bookkeeping: append the ``job_terminal`` trace event
        (with the job's cache hit-rate), re-embed the trace summary, and
        persist the terminal transition — one save, via the store's
        state machine."""
        job_id = art.job["id"]
        hits, evals = _cache_stats(art)
        attrs: Dict[str, Any] = {
            "job": job_id, "state": state,
            "cache_hits": hits, "evaluations": evals,
            "restarts": int(art.job.get("restarts", 0)),
        }
        if hits + evals:
            attrs["hit_rate"] = round(hits / (hits + evals), 4)
        if error is not None:
            attrs["error"] = error
        with trace_mod.TraceWriter(self.store.trace_path(job_id),
                                   clock=self._trace_clock) as w:
            w.event("job_terminal", span="service", attrs=attrs)
            art.trace = w.summary()
        return self.store.transition(art, state, error=error)


def _repair_trace_tail(path: str) -> None:
    """Drop a torn trailing line a SIGKILL mid-write can leave in a
    trace file (every earlier line was flushed whole). Corruption
    anywhere else is NOT repaired — load_trace will reject it loudly."""
    if not os.path.exists(path):
        return
    with open(path, "r", encoding="utf-8") as fh:
        lines = fh.readlines()
    if not lines:
        return
    tail = lines[-1]
    try:
        json.loads(tail)
        complete = tail.endswith("\n")
    except (json.JSONDecodeError, ValueError):
        complete = False
    if complete:
        return
    with open(path, "w", encoding="utf-8") as fh:
        fh.writelines(lines[:-1])
