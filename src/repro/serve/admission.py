"""Admission control for the offload service.

Three concerns, all decided at submit time (before a job record exists)
so every decision is visible in the job trace:

* **in-flight bound** — the service scheduler runs at most
  ``max_in_flight`` jobs concurrently (enforced by the executor width in
  :mod:`repro.serve.offload_service`, recorded here for the trace).
* **budget clamps** — per-request generation/population/measurement
  budgets: a submitted spec asking for more than the policy allows is
  admitted with the field clamped down (never rejected — the paper's
  service converts whatever users submit; the operator just bounds how
  much machine time one request can claim). Clamps are recorded as
  ``{field: [requested, granted]}`` in the job record.
* **duplicate coalescing** — handled by the service via
  :func:`repro.serve.jobs.coalesce_key`; the policy only says whether it
  is on (it is by default — a repeat submission should be one search
  plus cache hits, not two searches).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.offload.spec import OffloadSpec


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Operator knobs (docs/serving.md#admission-knobs). ``None`` for
    any max means "no bound on that field"."""

    max_in_flight: int = 2
    max_generations: Optional[int] = None
    max_population: Optional[int] = None
    max_workers: Optional[int] = None
    max_stability_seeds: Optional[int] = None
    coalesce: bool = True

    def __post_init__(self):
        if self.max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """What admission did to one submission."""

    spec: OffloadSpec  # the (possibly clamped) spec the job will run
    clamped: Dict[str, List[int]]  # field -> [requested, granted]

    @property
    def was_clamped(self) -> bool:
        return bool(self.clamped)


def _clamp(requested: Optional[int], bound: Optional[int]
           ) -> Tuple[Optional[int], bool]:
    """(granted, changed): cap ``requested`` at ``bound``. A request of
    None means "library default", which may exceed the bound — so a
    bounded policy pins None requests to the bound too."""
    if bound is None:
        return requested, False
    if requested is None or requested > bound:
        return bound, True
    return requested, False


def admit(spec: OffloadSpec, policy: AdmissionPolicy) -> AdmissionDecision:
    """Apply the policy's budget clamps to one submitted spec."""
    clamped: Dict[str, List[int]] = {}
    changes: Dict[str, object] = {}

    for field, bound in (("generations", policy.max_generations),
                         ("population", policy.max_population),
                         ("workers", policy.max_workers)):
        requested = getattr(spec, field)
        granted, changed = _clamp(requested, bound)
        if changed:
            changes[field] = granted
            clamped[field] = [requested if requested is not None else -1,
                              granted]

    if policy.max_stability_seeds is not None:
        requested = spec.ga.stability_seeds
        granted, changed = _clamp(requested, policy.max_stability_seeds)
        if changed:
            changes["ga"] = dataclasses.replace(
                spec.ga, stability_seeds=granted)
            clamped["stability_seeds"] = [
                requested if requested is not None else -1, granted]

    out = dataclasses.replace(spec, **changes) if changes else spec
    return AdmissionDecision(spec=out, clamped=clamped)
