"""Serving layer.

Two unrelated tenants share this package:

- :mod:`repro.serve.engine` — the LLM decode-engine demo the seed
  shipped (jax-heavy; driven by :mod:`repro.launch.serve`);
- the **offload service** (docs/serving.md) — :mod:`.offload_service`,
  :mod:`.jobs`, :mod:`.admission`: queue-fed concurrent `Offloader`
  runs over one shared fitness-cache store.

Attribute access is lazy so importing one tenant never pays for (or
requires the dependencies of) the other.
"""
from typing import Any

_SERVICE_EXPORTS = {
    "OffloadService": "offload_service",
    "FaultPlan": "offload_service",
    "ServiceCrash": "offload_service",
    "SubmitReceipt": "offload_service",
    "AdmissionPolicy": "admission",
    "AdmissionDecision": "admission",
    "admit": "admission",
    "Job": "jobs",
    "JobError": "jobs",
    "JobStore": "jobs",
    "coalesce_key": "jobs",
}

__all__ = sorted(_SERVICE_EXPORTS)


def __getattr__(name: str) -> Any:
    mod_name = _SERVICE_EXPORTS.get(name)
    if mod_name is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f"{__name__}.{mod_name}"), name)
