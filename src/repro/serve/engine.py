"""Batched serving engine: prefill + decode over the plan-aware Model.

Continuous-batching-lite: a request queue is packed into fixed decode slots;
finished sequences release their slot, the next prefill fills it. The KV
cache is the Model's (ring- or direct-layout) cache; one jitted decode step
serves the whole slot batch every tick.

Ring-flush contract: for seq-sharded caches (kv heads not shardable), the
decode ring holds the newest tokens; ``RING_SIZE`` decode steps per segment
are guaranteed flush-free, matching the engine's segment length.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    slots: int = 4  # concurrent decode slots
    ctx_len: int = 256  # max context per slot
    greedy: bool = True
    seed: int = 0


class Engine:
    def __init__(
        self,
        cfg: ArchConfig,
        plan: ExecutionPlan,
        params: Any,
        scfg: ServeConfig = ServeConfig(),
        mesh=None,
        interpret: bool = False,
    ):
        assert not cfg.encoder_only, "no autoregressive serving for encoders"
        self.cfg = cfg
        self.scfg = scfg
        self.model = Model(cfg, plan, mesh=mesh, interpret=interpret)
        self.params = params
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._prefill_cache: Dict[int, Any] = {}
        self.cache = None
        self.positions = np.zeros((scfg.slots,), np.int32)
        self.last_token = np.zeros((scfg.slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * scfg.slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request):
        """Single-sequence prefill into the slot's cache rows."""
        prompt = req.prompt[None, :]  # (1, L)
        batch = {"tokens": jnp.asarray(prompt)}
        logits, cache1 = self.model.prefill(
            self.params, batch, ctx_len=self.scfg.ctx_len
        )
        tok = int(jnp.argmax(logits[0, : self.cfg.vocab]))
        if self.cache is None:
            # first prefill defines the batched cache: tile slot-ways
            self.cache = jax.tree.map(
                lambda x: jnp.concatenate([x] * self.scfg.slots, axis=-4)
                if x.ndim >= 4
                else jnp.concatenate([x] * self.scfg.slots, axis=0),
                cache1,
            )

        def write(slot_cache, full):
            idx = [slice(None)] * full.ndim
            axis = full.ndim - 4 if full.ndim >= 4 else 0
            idx[axis] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(slot_cache)

        self.cache = jax.tree.map(write, cache1, self.cache)
        self.slot_req[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_token[slot] = tok
        req.output.append(tok)

    def _fill_slots(self):
        for slot in range(self.scfg.slots):
            if self.slot_req[slot] is None and self.queue:
                self._prefill_one(slot, self.queue.pop(0))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick: fill free slots, run one batched decode step.
        Returns number of active slots served."""
        self._fill_slots()
        active = [s for s in range(self.scfg.slots) if self.slot_req[s]]
        if not active:
            return 0
        tokens = jnp.asarray(self.last_token[:, None])
        positions = jnp.asarray(self.positions[:, None])
        logits, self.cache = self._decode(
            self.params, self.cache, tokens, positions
        )
        nxt = np.asarray(
            jnp.argmax(logits[:, : self.cfg.vocab], axis=-1), np.int32
        )
        for s in active:
            req = self.slot_req[s]
            req.output.append(int(nxt[s]))
            self.positions[s] += 1
            self.last_token[s] = nxt[s]
            hit_limit = len(req.output) >= req.max_new_tokens
            full = self.positions[s] >= self.scfg.ctx_len - 1
            if hit_limit or full:
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        return len(active)

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
