"""Job lifecycle + crash-safe job store for the offload service.

A **job** is one admitted :class:`~repro.offload.spec.OffloadSpec`
submission. Its state record is not a separate database: the resumable
:class:`~repro.offload.result.OffloadResult` artifact carries a ``job``
dict (id, state, restarts, admission clamps, error), so the artifact the
pipeline already saves atomically after every stage IS the job-state
record. Crash recovery falls out: a restarted service scans the jobs
directory, re-queues every artifact whose job is non-terminal, and
``Offloader.resume`` + the shared fitness cache do the rest
(docs/serving.md).

State machine (every write goes through :func:`transition`, which
refuses anything not in :data:`TRANSITIONS`)::

    QUEUED ──> RUNNING ──> DONE
       │          │ ├────> FAILED
       │          │ └────> CANCELLED
       │          └──────> QUEUED      (crash-restart re-queue)
       └─────────────────> CANCELLED   (cancelled before start)

DONE/FAILED/CANCELLED are terminal: no transition leaves them, so a job
reaches exactly one terminal state (property-tested in
tests/test_service_properties.py).

Store layout under one queue directory (filesystem-backed — tests, CI
and the ``serve`` CLI drive it without sockets)::

    <root>/jobs/<id>.offload.json         the artifact == job record
    <root>/jobs/<id>.offload.trace.jsonl  the job's trace (service
                                          events + pipeline spans)
    <root>/jobs/<id>.cancel               cancellation request marker
    <root>/jobs/<id>.coalesced            one line per coalesced
                                          duplicate submission
    <root>/cache/fitness.jsonl            the shared fitness-cache store

Single-writer discipline: only the service process that owns a job's
execution writes its artifact (submission creates it once and never
touches it again; duplicate submissions append to the side-car
``.coalesced`` file instead, and cancellation is a marker file) — so the
atomic tmp+rename saves never race each other.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from typing import Dict, List, Optional

from repro.offload.result import OffloadResult, atomic_json_save
from repro.offload.spec import OffloadSpec

# -- states ----------------------------------------------------------------

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
FAILED = "failed"
CANCELLED = "cancelled"

STATES = (QUEUED, RUNNING, DONE, FAILED, CANCELLED)
TERMINAL = (DONE, FAILED, CANCELLED)

# every legal (from -> to) edge; RUNNING -> QUEUED is the crash-restart
# re-queue (the process died mid-job, nothing terminal happened)
TRANSITIONS: Dict[str, tuple] = {
    QUEUED: (RUNNING, CANCELLED),
    RUNNING: (DONE, FAILED, CANCELLED, QUEUED),
    DONE: (),
    FAILED: (),
    CANCELLED: (),
}


class JobError(RuntimeError):
    """An illegal job operation (invalid transition, unknown job id)."""


def can_transition(state: str, to: str) -> bool:
    if state not in TRANSITIONS:
        raise JobError(f"unknown job state {state!r}")
    if to not in TRANSITIONS:
        raise JobError(f"unknown job state {to!r}")
    return to in TRANSITIONS[state]


def coalesce_key(spec: OffloadSpec) -> str:
    """Digest of the spec's *result-determining* fields: the dedup key
    for duplicate-submission coalescing. Runtime-only knobs that cannot
    change the search result are excluded — ``cache`` (the service
    rewrites it to the shared store anyway) and ``workers`` (pool
    determinism guarantees identical results at any width) — so two
    users asking for the same search coalesce even if their clients
    filled those fields differently."""
    d = spec.to_dict()
    d.pop("cache", None)
    d.pop("workers", None)
    return hashlib.sha256(
        json.dumps(d, sort_keys=True).encode("utf-8")
    ).hexdigest()[:16]


@dataclasses.dataclass
class Job:
    """In-memory view of one artifact's ``job`` record."""

    id: str
    state: str
    digest: str  # coalesce_key of the (normalized) spec
    seq: int  # admission order (scheduler runs lowest first)
    restarts: int = 0  # crash-restart re-queues survived
    clamped: Dict[str, List[int]] = dataclasses.field(default_factory=dict)
    error: Optional[str] = None
    submitted_ts: float = 0.0  # wall clock, informational only

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "Job":
        return cls(
            id=str(d["id"]),
            state=str(d["state"]),
            digest=str(d["digest"]),
            seq=int(d["seq"]),
            restarts=int(d.get("restarts", 0)),
            clamped=dict(d.get("clamped", {})),
            error=d.get("error"),
            submitted_ts=float(d.get("submitted_ts", 0.0)),
        )

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL


class JobStore:
    """The filesystem job store: artifacts-as-job-records under one
    queue directory, plus cancel markers and the coalesce side-cars.

    Thread-safe within a process (submission/scan lock); across
    processes the single-writer discipline above plus atomic saves and
    marker files keep it consistent.
    """

    def __init__(self, root: str):
        self.root = root
        self.jobs_dir = os.path.join(root, "jobs")
        self.cache_path = os.path.join(root, "cache", "fitness.jsonl")
        os.makedirs(self.jobs_dir, exist_ok=True)
        os.makedirs(os.path.dirname(self.cache_path), exist_ok=True)
        self._lock = threading.Lock()

    # -- paths -------------------------------------------------------------

    def artifact_path(self, job_id: str) -> str:
        return os.path.join(self.jobs_dir, f"{job_id}.offload.json")

    def trace_path(self, job_id: str) -> str:
        return os.path.join(self.jobs_dir, f"{job_id}.offload.trace.jsonl")

    def _cancel_path(self, job_id: str) -> str:
        return os.path.join(self.jobs_dir, f"{job_id}.cancel")

    def _coalesced_path(self, job_id: str) -> str:
        return os.path.join(self.jobs_dir, f"{job_id}.coalesced")

    # -- creation / loading ------------------------------------------------

    def create(self, spec: OffloadSpec, job: Job) -> OffloadResult:
        """Persist a fresh QUEUED artifact-as-job-record. Refuses to
        overwrite an existing job id."""
        path = self.artifact_path(job.id)
        with self._lock:
            if os.path.exists(path):
                raise JobError(f"job {job.id!r} already exists")
            art = OffloadResult(spec=spec, path=path, job=job.to_dict())
            art.save()
        return art

    def load(self, job_id: str) -> OffloadResult:
        path = self.artifact_path(job_id)
        if not os.path.exists(path):
            raise JobError(f"unknown job {job_id!r} (no {path})")
        art = OffloadResult.load(path)
        if art.job is None:
            raise JobError(f"artifact {path} carries no job record")
        return art

    def job(self, job_id: str) -> Job:
        return Job.from_dict(self.load(job_id).job)

    def list_jobs(self) -> List[Job]:
        """Every job, in admission (seq) order."""
        out: List[Job] = []
        for name in os.listdir(self.jobs_dir):
            if not name.endswith(".offload.json"):
                continue
            art = OffloadResult.load(os.path.join(self.jobs_dir, name))
            if art.job is not None:
                out.append(Job.from_dict(art.job))
        out.sort(key=lambda j: (j.seq, j.id))
        return out

    def by_digest(self, digest: str) -> List[Job]:
        return [j for j in self.list_jobs() if j.digest == digest]

    def next_seq(self) -> int:
        jobs = self.list_jobs()
        return (max(j.seq for j in jobs) + 1) if jobs else 0

    def allocate_id(self, digest: str) -> str:
        """A fresh job id for this digest: the anchor ``jb-<digest>``,
        or ``jb-<digest>-rN`` when forced duplicates already exist."""
        base = f"jb-{digest[:10]}"
        if not os.path.exists(self.artifact_path(base)):
            return base
        n = 2
        while os.path.exists(self.artifact_path(f"{base}-r{n}")):
            n += 1
        return f"{base}-r{n}"

    # -- state transitions -------------------------------------------------

    def transition(self, art: OffloadResult, to: str,
                   error: Optional[str] = None,
                   restarted: bool = False) -> Job:
        """Validate + apply + persist one state transition on an
        artifact-as-job-record. Raises :class:`JobError` (and leaves the
        record untouched) on an illegal edge."""
        job = Job.from_dict(art.job)
        if not can_transition(job.state, to):
            raise JobError(
                f"job {job.id}: illegal transition {job.state} -> {to}"
            )
        job.state = to
        if error is not None:
            job.error = error
        if restarted:
            job.restarts += 1
        art.job = job.to_dict()
        art.save()
        return job

    # -- cancellation + coalescing markers ---------------------------------

    def request_cancel(self, job_id: str) -> Job:
        """Record a cancellation request (marker file: safe to write
        from any process while the service owns the artifact). The
        service honors it before the next stage; an already-terminal
        job ignores it."""
        job = self.job(job_id)  # raises JobError on unknown id
        with open(self._cancel_path(job_id), "w", encoding="utf-8") as fh:
            fh.write(f"{time.time()}\n")
        return job

    def cancel_requested(self, job_id: str) -> bool:
        return os.path.exists(self._cancel_path(job_id))

    def record_coalesced(self, anchor_id: str, digest: str) -> int:
        """Append one duplicate-submission line to the anchor's
        side-car (never touches the anchor's artifact — it may be
        mid-save by the running service). Returns the duplicate count."""
        path = self._coalesced_path(anchor_id)
        with self._lock:
            with open(path, "a", encoding="utf-8") as fh:
                fh.write(json.dumps(
                    {"digest": digest, "ts": time.time()}
                ) + "\n")
            with open(path, "r", encoding="utf-8") as fh:
                return sum(1 for line in fh if line.strip())

    def coalesced_count(self, job_id: str) -> int:
        path = self._coalesced_path(job_id)
        if not os.path.exists(path):
            return 0
        with open(path, "r", encoding="utf-8") as fh:
            return sum(1 for line in fh if line.strip())


# re-exported for callers that only need the atomic save helper
__all__ = [
    "QUEUED", "RUNNING", "DONE", "FAILED", "CANCELLED",
    "STATES", "TERMINAL", "TRANSITIONS",
    "Job", "JobError", "JobStore",
    "can_transition", "coalesce_key", "atomic_json_save",
]
