"""Mixed-destination subsystem: profiles/topology, N-memory scheduling,
MixedEvaluator parity + admissibility, the mixed-beats-single acceptance
search, and cross-subset fitness-cache sharing."""
import numpy as np
import pytest

from repro.core import evaluator as ev
from repro.core import evalpool as ep
from repro.core import ga, miniapps
from repro.core import transfer as tr
from repro.core.loopir import Loop, LoopClass, LoopProgram, SeqRegion, Var
from repro.destinations import (
    MixedEvaluator,
    build_mixed_schedule,
    default_registry,
)


# ---------------------------------------------------------------------------
# registry + topology
# ---------------------------------------------------------------------------


def test_registry_basics():
    reg = default_registry()
    assert reg.host.name == "cpu"
    assert reg.get("gpu").kind == "gpu"
    with pytest.raises(KeyError):
        reg.get("tpu")


def test_route_direct_and_via_host():
    reg = default_registry()
    assert reg.route("cpu", "gpu") == (("cpu", "gpu"),)
    assert reg.route("gpu", "gpu") == ()
    # no physical gpu<->fpga link: staged through the host
    assert reg.route("gpu", "fpga") == (("gpu", "cpu"), ("cpu", "fpga"))


def test_admissibility_rules():
    reg = default_registry()
    fpga = reg.get("fpga")
    assert fpga.accepts(LoopClass.TIGHT)
    assert fpga.accepts(LoopClass.VECTOR_ONLY)
    # NON_TIGHT compiles only through the DEGRADED fallback (HLS
    # sequentialization): legal, priced painfully, never clamped away
    assert fpga.accepts(LoopClass.NON_TIGHT)
    assert fpga.degraded(LoopClass.NON_TIGHT)
    assert not fpga.degraded(LoopClass.TIGHT)
    gpu = reg.get("gpu")
    assert gpu.accepts(LoopClass.NON_TIGHT)
    assert not gpu.degraded(LoopClass.NON_TIGHT)
    assert not gpu.accepts(LoopClass.NOT_OFFLOADABLE)


def test_degraded_rate_priced_below_host():
    """The degraded NON_TIGHT fallback runs below the host's scalar rate
    and is what rate_for returns for loops of that class."""
    reg = default_registry()
    fpga, cpu = reg.get("fpga"), reg.get("cpu")
    loop = Loop("ragged", LoopClass.NON_TIGHT, 64, 64, 4.0,
                frozenset(), frozenset({"x"}))
    assert fpga.rate_for(loop) < cpu.rate_for(loop)
    # degraded classes don't get the II=1 sequential-carry bonus either
    carry = Loop("ragged_seq", LoopClass.NON_TIGHT, 64, 64, 4.0,
                 frozenset(), frozenset({"x"}), sequential_carry=True)
    assert fpga.rate_for(carry) == fpga.rate_for(loop)


def test_fingerprint_tracks_degraded_rates():
    import dataclasses

    fpga = default_registry().get("fpga")
    tweaked = dataclasses.replace(
        fpga, degraded_rates=((LoopClass.NON_TIGHT, 2.0e9),)
    )
    assert tweaked.fingerprint() != fpga.fingerprint()
    stripped = dataclasses.replace(fpga, degraded_rates=())
    assert stripped.fingerprint() != fpga.fingerprint()


def test_registry_fingerprint_tracks_constants():
    import dataclasses

    from repro.destinations import profiles

    a = default_registry()
    b = default_registry()
    assert a.fingerprint() == b.fingerprint()
    # any profile constant change must change the fingerprint
    fpga = a.get("fpga")
    tweaked = dataclasses.replace(fpga, membw=fpga.membw * 2)
    c = profiles.Registry(
        name=a.name,
        destinations=tuple(
            tweaked if d.name == "fpga" else d for d in a.destinations
        ),
        links=a.links,
    )
    assert c.fingerprint() != a.fingerprint()


# ---------------------------------------------------------------------------
# N-memory schedule
# ---------------------------------------------------------------------------


def _two_loop_program(trip=4):
    """x: written on one device, read on another, every region iteration."""
    vars_ = [Var("x", 1 << 20), Var("y", 1 << 20)]
    loops = (
        Loop("produce", LoopClass.TIGHT, 64, 64, 2.0,
             frozenset(), frozenset({"x"}), parent_seq="it"),
        Loop("consume", LoopClass.VECTOR_ONLY, 64, 64, 2.0,
             frozenset({"x"}), frozenset({"y"}), parent_seq="it"),
    )
    return LoopProgram("twoloop", loops, tuple(vars_),
                       (SeqRegion("it", trip),))


def test_schedule_residency_no_retransfer():
    """A var read twice on the same device transfers once (BULK present)."""
    prog = _two_loop_program(trip=4)
    reg = default_registry()
    sched = build_mixed_schedule(
        prog, {"produce": "gpu", "consume": "gpu"}, reg
    )
    # x never crosses to the host mid-run (produced+consumed on gpu);
    # program end flushes the two device-dirty vars home in ONE batch
    assert sched.bytes_by_link.get(("cpu", "gpu"), 0.0) == 0.0
    assert sched.bytes_by_link[("gpu", "cpu")] == float(2 << 20)
    assert sched.events_by_link[("gpu", "cpu")] == 1.0


def test_schedule_cross_device_routes_through_host():
    prog = _two_loop_program(trip=3)
    reg = default_registry()
    sched = build_mixed_schedule(
        prog, {"produce": "gpu", "consume": "fpga"}, reg
    )
    # x crosses gpu->cpu->fpga every iteration (produce rewrites it)
    mb = float(1 << 20)
    assert sched.bytes_by_link[("gpu", "cpu")] == pytest.approx(3 * mb)
    assert sched.bytes_by_link[("cpu", "fpga")] == pytest.approx(3 * mb)
    # y is written on fpga and flushed home once
    assert sched.bytes_by_link[("fpga", "cpu")] == pytest.approx(mb)


def test_schedule_write_invalidates_other_copies():
    """After the host rewrites x, a device reader must re-transfer it."""
    vars_ = [Var("x", 1 << 20)]
    loops = (
        Loop("host_write", LoopClass.NOT_OFFLOADABLE, 8, 8, 1.0,
             frozenset(), frozenset({"x"}), parent_seq="it"),
        Loop("dev_read", LoopClass.TIGHT, 8, 8, 1.0,
             frozenset({"x"}), frozenset({"x"}), parent_seq="it"),
    )
    prog = LoopProgram("inval", loops, tuple(vars_), (SeqRegion("it", 5),))
    sched = build_mixed_schedule(
        prog, {"host_write": "cpu", "dev_read": "gpu"}, default_registry()
    )
    assert sched.bytes_by_link[("cpu", "gpu")] == pytest.approx(
        5 * float(1 << 20)
    )


# ---------------------------------------------------------------------------
# MixedEvaluator: binary parity, admissibility, canonical keys
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("app", ["himeno", "nasft", "hetero"])
def test_mixed_k2_matches_binary_bulk_evaluator(app):
    """The k=2 cpu+gpu search IS the paper's search: the mixed evaluator
    must reproduce MiniappEvaluator(BULK, staged) to round-off."""
    prog = miniapps.MINIAPPS[app]()
    binary = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)
    mixed = MixedEvaluator(prog, ("cpu", "gpu"))
    rng = np.random.default_rng(7)
    for _ in range(15):
        g = tuple(int(b) for b in rng.integers(0, 2, prog.gene_length))
        assert mixed(g) == pytest.approx(binary(g), rel=1e-12)


def _strict_registry():
    """The default registry with the fpga's degraded NON_TIGHT fallback
    stripped: a hard compile error again (exercises the clamp path)."""
    import dataclasses

    from repro.destinations import profiles

    reg = default_registry()
    strict_fpga = dataclasses.replace(reg.get("fpga"), degraded_rates=())
    return profiles.Registry(
        name="strict",
        destinations=tuple(
            strict_fpga if d.name == "fpga" else d for d in reg.destinations
        ),
        links=reg.links,
    )


def test_inadmissible_placement_falls_back_to_host():
    """A class a destination supports through NEITHER rate table (hard
    compile error) is clamped to the host; degraded classes are NOT."""
    prog = miniapps.nasft_program()
    e = MixedEvaluator(prog, ("cpu", "gpu", "fpga"),
                       registry=_strict_registry())
    genes = tuple(2 for _ in range(prog.gene_length))  # everything -> fpga
    adm = e.admissible(genes)
    for g, loop in zip(adm, prog.offloadable_loops):
        if loop.klass == LoopClass.NON_TIGHT:
            assert g == 0  # strict fpga rejects ragged tiles -> host
        else:
            assert g == 2


def test_degraded_placement_stands_and_costs():
    """With the degraded fallback, a NON_TIGHT loop PLACED on the fpga
    stays there (no clamping) and prices worse than leaving it home."""
    prog = miniapps.nasft_program()
    e = MixedEvaluator(prog, ("cpu", "gpu", "fpga"))
    genes = tuple(2 for _ in range(prog.gene_length))
    adm = e.admissible(genes)
    assert all(g == 2 for g in adm)  # nothing clamped any more
    # pricing: flipping ONE ragged loop from host to fpga on an
    # otherwise-host placement must cost more than keeping it home
    idx = next(i for i, l in enumerate(prog.offloadable_loops)
               if l.klass == LoopClass.NON_TIGHT)
    host_only = [0] * prog.gene_length
    degraded = list(host_only)
    degraded[idx] = 2
    assert e(tuple(degraded)) > e(tuple(host_only))


def test_ga_avoids_degraded_placement_when_host_cheaper():
    """The GA prices the painful-but-legal fallback and routes around
    it: on a tiny program whose only searchable choice is one ragged
    loop, the best placement keeps it on the host."""
    # compute-bound: the degraded flop rate (below the host's) decides,
    # not the fpga's better memory bandwidth
    vars_ = [Var("x", 1 << 20), Var("y", 1 << 20)]
    loops = (
        Loop("ragged", LoopClass.NON_TIGHT, 256, 256, 2000.0,
             frozenset({"x"}), frozenset({"y"}), parent_seq="it"),
    )
    prog = LoopProgram("oneragged", loops, tuple(vars_),
                       (SeqRegion("it", 10),))
    e = MixedEvaluator(prog, ("cpu", "fpga"))
    params = ga.GAParams(population=4, generations=6, seed=0,
                         timeout_s=1e6, alleles=e.k)
    res = ga.run_ga(e, prog.gene_length, params)
    assert e.admissible(res.best_genes) == (0,)  # stays home
    assert res.best_time_s == pytest.approx(e((0,)))


def test_cache_key_is_subset_independent():
    prog = miniapps.hetero_program()
    small = MixedEvaluator(prog, ("cpu", "fpga"))
    full = MixedEvaluator(prog, ("cpu", "gpu", "fpga"))
    n = prog.gene_length
    g_small = tuple([1] + [0] * (n - 1))  # loop 0 -> fpga (index 1 of small)
    g_full = tuple([2] + [0] * (n - 1))  # loop 0 -> fpga (index 2 of full)
    assert small.cache_key(g_small) == full.cache_key(g_full)
    assert small.fingerprint() == full.fingerprint()
    # and the evaluations agree too: same placement, same machine
    assert small(g_small) == pytest.approx(full(g_full), rel=1e-12)


def test_fingerprint_distinguishes_programs():
    a = MixedEvaluator(miniapps.himeno_program(), ("cpu", "gpu"))
    b = MixedEvaluator(miniapps.hetero_program(), ("cpu", "gpu"))
    assert a.fingerprint() != b.fingerprint()


def test_fingerprint_distinguishes_program_shapes():
    """Same app name at another grid/trip count must NOT share cached
    fitness values — the times differ by orders of magnitude."""
    big = MixedEvaluator(miniapps.hetero_program(), ("cpu", "gpu"))
    small = MixedEvaluator(
        miniapps.hetero_program(grid=(32, 32, 32), frames=5), ("cpu", "gpu")
    )
    assert big.fingerprint() != small.fingerprint()
    # the binary evaluator keys on the same structural digest
    ea = ev.MiniappEvaluator(miniapps.himeno_program())
    eb = ev.MiniappEvaluator(miniapps.himeno_program(grid=(64, 64, 64)))
    assert ea.fingerprint() != eb.fingerprint()
    same = ev.MiniappEvaluator(miniapps.himeno_program())
    assert ea.fingerprint() == same.fingerprint()


def test_destinations_must_start_with_host():
    with pytest.raises(AssertionError):
        MixedEvaluator(miniapps.hetero_program(), ("gpu", "cpu"))


# ---------------------------------------------------------------------------
# k-ary GA wiring (plain tests; the hypothesis property tests for the
# operators themselves live in test_genome_ga.py behind the dev extra)
# ---------------------------------------------------------------------------


def test_ga_kary_alleles_threaded_through():
    """alleles=3: the GA explores destination indices and the winning
    genome stays inside the alphabet."""
    from repro.core import genome as G

    def tri_time(genes):
        # destination 2 fastest, 1 middling, 0 slow — optimum all-2s
        return 10.0 - sum(genes) / len(genes)

    p = ga.GAParams(population=12, generations=16, seed=0, alleles=3)
    r = ga.run_ga(tri_time, 8, p)
    assert all(0 <= g < 3 for g in r.best_genes)
    assert sum(r.best_genes) >= 14  # ~all genes found destination 2
    pop = G.initial_population(np.random.default_rng(0), 12, 24, k=3)
    assert len(set(pop)) == 24
    assert {x for g in pop for x in g} <= {0, 1, 2}


def test_ga_default_alleles_binary_unchanged():
    """alleles=2 (the default) is the pre-k-ary GA: identical results."""

    def onemax_time(genes):
        return 10.0 - 9.0 * sum(genes) / len(genes)

    p2 = ga.GAParams(population=8, generations=8, seed=42)
    explicit = ga.GAParams(population=8, generations=8, seed=42, alleles=2)
    assert ga.run_ga(onemax_time, 10, p2).best_genes == \
        ga.run_ga(onemax_time, 10, explicit).best_genes


# ---------------------------------------------------------------------------
# acceptance: mixed beats the best single destination; caches shared
# ---------------------------------------------------------------------------


def _search(prog, subset, seed=0, pool=None):
    e = MixedEvaluator(prog, subset)
    params = ga.GAParams(population=24, generations=24, seed=seed,
                         timeout_s=1e6, alleles=e.k)
    if pool is None:
        return ga.run_ga(e, prog.gene_length, params)
    return ga.run_ga(None, prog.gene_length, params, pool=pool)


def test_mixed_destination_beats_best_single():
    """The headline claim (same seed, same generations, same population):
    one genome over all three backends finds a strictly faster plan than
    the best either single-backend search reaches."""
    prog = miniapps.hetero_program()
    gpu_only = _search(prog, ("cpu", "gpu"))
    fpga_only = _search(prog, ("cpu", "fpga"))
    mixed = _search(prog, ("cpu", "gpu", "fpga"))
    best_single = min(gpu_only.best_time_s, fpga_only.best_time_s)
    assert mixed.best_time_s < best_single
    # and the winning plan actually uses >= 2 non-host destinations
    e = MixedEvaluator(prog, ("cpu", "gpu", "fpga"))
    used = set(e.admissible(mixed.best_genes)) - {0}
    assert len(used) >= 2


def test_cross_subset_searches_share_fitness_cache(tmp_path):
    """A second search over a DIFFERENT destination subset gets persistent
    cache hits for every genome whose placement falls entirely within the
    shared destinations."""
    path = str(tmp_path / "mixed.jsonl")
    prog = miniapps.hetero_program()

    e_small = MixedEvaluator(prog, ("cpu", "gpu"))
    cache1 = ep.FitnessCache(path, fingerprint=e_small.fingerprint())
    with ep.EvalPool(e_small, cache=cache1) as pool1:
        r1 = _search(prog, ("cpu", "gpu"), pool=pool1)
    assert r1.evaluations > 0

    # restart against the same file with the WIDER subset
    e_full = MixedEvaluator(prog, ("cpu", "gpu", "fpga"))
    assert e_full.fingerprint() == e_small.fingerprint()
    cache2 = ep.FitnessCache(path, fingerprint=e_full.fingerprint())
    assert cache2.loaded == r1.evaluations  # all binary measurements replay

    # the binary best re-expressed in the k=3 alphabet (gpu is index 1 in
    # both subsets) is served from disk; an fpga placement is a miss
    with ep.EvalPool(e_full, cache=cache2) as pool2:
        times, tel = pool2.evaluate_generation(
            [r1.best_genes, tuple([2] * prog.gene_length)],
            timeout_s=1e6, penalty_time_s=1e9,
        )
    assert tel.cache_hits == 1 and tel.evaluated == 1
    assert times[0] == pytest.approx(r1.best_time_s, rel=1e-12)

    # a whole warm mixed search: identical results (the cache never
    # perturbs the GA's RNG stream), and it can only do better than cold
    # — how much better is placement-dependent (a random k=3 genome
    # rarely lands entirely inside the binary subset; the deterministic
    # hit/miss pattern above is the hard guarantee)
    cache3 = ep.FitnessCache(path, fingerprint=e_full.fingerprint())
    with ep.EvalPool(e_full, cache=cache3) as pool3:
        r3 = _search(prog, ("cpu", "gpu", "fpga"), pool=pool3)
    cold = _search(prog, ("cpu", "gpu", "fpga"))
    assert r3.best_genes == cold.best_genes
    assert r3.best_time_s == cold.best_time_s
    assert r3.evaluations <= cold.evaluations
    assert r3.cache_hits >= cold.cache_hits


def test_one_cache_object_serves_pools_over_different_subsets():
    """A shared FitnessCache must never be repurposed by a pool: the same
    raw genome means gpu in one subset and fpga in another, so the pools'
    evaluator-derived keys (not a mutated cache key_fn) must disambiguate."""
    prog = miniapps.hetero_program()
    e_gpu = MixedEvaluator(prog, ("cpu", "gpu"))
    e_fpga = MixedEvaluator(prog, ("cpu", "fpga"))
    cache = ep.FitnessCache()  # one in-memory cache, two pools
    g = tuple([1] + [0] * (prog.gene_length - 1))

    t_gpu, _ = ep.EvalPool(e_gpu, cache=cache).evaluate_generation(
        [g], 1e6, 1e9
    )
    t_fpga, tel = ep.EvalPool(e_fpga, cache=cache).evaluate_generation(
        [g], 1e6, 1e9
    )
    assert tel.evaluated == 1 and tel.cache_hits == 0  # no false hit
    assert t_gpu[0] == pytest.approx(e_gpu(g), rel=1e-12)
    assert t_fpga[0] == pytest.approx(e_fpga(g), rel=1e-12)
    assert t_gpu[0] != t_fpga[0]


def test_clamped_duplicates_share_one_measurement():
    """Two genomes whose placements clamp to the same admissible plan
    canonicalize identically and must be measured once per generation
    (strict registry: degraded acceptance would keep them distinct)."""
    prog = miniapps.nasft_program()
    e = MixedEvaluator(prog, ("cpu", "gpu", "fpga"),
                       registry=_strict_registry())
    i = next(
        i for i, l in enumerate(prog.offloadable_loops)
        if l.klass == LoopClass.NON_TIGHT
    )
    a = (0,) * prog.gene_length
    b = a[:i] + (2,) + a[i + 1:]  # fpga rejects NON_TIGHT -> clamps to host
    assert e.cache_key(a) == e.cache_key(b)
    with ep.EvalPool(e) as pool:
        times, tel = pool.evaluate_generation([a, b], 1e6, 1e9)
    assert tel.unique == 1 and tel.evaluated == 1 and tel.cache_hits == 1
    assert times[0] == times[1]


def test_mixed_search_deterministic_per_seed():
    prog = miniapps.hetero_program()
    a = _search(prog, ("cpu", "gpu", "fpga"), seed=5)
    b = _search(prog, ("cpu", "gpu", "fpga"), seed=5)
    assert a.best_genes == b.best_genes
    assert a.best_time_s == b.best_time_s
