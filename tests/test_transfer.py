"""Transfer-scheduling invariants (paper §3.3), incl. hypothesis tests over
randomly generated loop programs."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (dev extra)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import transfer as tr
from repro.core.loopir import Loop, LoopClass, LoopProgram, SeqRegion, Var


def _mk_prog(n_loops, n_vars, region_trip, edges, classes, globals_mask):
    vars_ = [
        Var(f"v{i}", nbytes=(i + 1) * 1000, is_global=bool(globals_mask[i]),
            init_external=bool(globals_mask[i]))
        for i in range(n_vars)
    ]
    loops = []
    for i in range(n_loops):
        reads = frozenset(f"v{j}" for j in edges[i][0])
        writes = frozenset(f"v{j}" for j in edges[i][1])
        loops.append(
            Loop(
                name=f"l{i}",
                klass=classes[i],
                trip=4,
                inner_trip=8,
                flops_per_iter=2.0,
                reads=reads,
                writes=writes,
                parent_seq="r" if i % 2 == 0 and region_trip > 1 else None,
            )
        )
    # keep region loops contiguous (the IR executes regions as blocks)
    loops.sort(key=lambda l: (l.parent_seq is None, l.name))
    return LoopProgram(
        name="synth",
        loops=tuple(loops),
        vars=tuple(vars_),
        seq_regions=(SeqRegion("r", region_trip),) if region_trip > 1 else (),
    )


@st.composite
def programs(draw):
    n_loops = draw(st.integers(1, 8))
    n_vars = draw(st.integers(1, 5))
    region_trip = draw(st.sampled_from([1, 3, 10]))
    edges = []
    for _ in range(n_loops):
        reads = draw(st.sets(st.integers(0, n_vars - 1), max_size=3))
        writes = draw(st.sets(st.integers(0, n_vars - 1), max_size=2))
        edges.append((reads, writes))
    classes = [
        draw(st.sampled_from([LoopClass.TIGHT, LoopClass.NON_TIGHT,
                              LoopClass.VECTOR_ONLY]))
        for _ in range(n_loops)
    ]
    globals_mask = [draw(st.booleans()) for _ in range(n_vars)]
    return _mk_prog(n_loops, n_vars, region_trip, edges, classes, globals_mask)


@st.composite
def program_and_genes(draw):
    prog = draw(programs())
    genes = tuple(
        draw(st.integers(0, 1)) for _ in range(prog.gene_length)
    )
    return prog, genes


@given(program_and_genes())
@settings(max_examples=120, deadline=None)
def test_all_zero_genes_no_transfers(pg):
    prog, _ = pg
    sched = tr.build_schedule(prog, (0,) * prog.gene_length, tr.TransferMode.BULK)
    assert sched.total_bytes == 0
    assert sched.h2d_count == 0 and sched.d2h_count == 0


@given(program_and_genes())
@settings(max_examples=120, deadline=None)
def test_bulk_never_more_bytes_than_nest(pg):
    """The paper's claim: program-wide residency only removes transfers."""
    prog, genes = pg
    bulk = tr.build_schedule(prog, genes, tr.TransferMode.BULK, staged=True)
    nest = tr.build_schedule(prog, genes, tr.TransferMode.NEST, staged=True)
    assert bulk.h2d_bytes <= nest.h2d_bytes + 1e-9
    assert bulk.d2h_bytes <= nest.d2h_bytes + 1e-9


@given(program_and_genes())
@settings(max_examples=120, deadline=None)
def test_nest_never_more_explicit_bytes_than_naive(pg):
    prog, genes = pg
    nest = tr.build_schedule(prog, genes, tr.TransferMode.NEST, staged=True)
    naive = tr.build_schedule(prog, genes, tr.TransferMode.NAIVE, staged=True)
    assert nest.h2d_bytes <= naive.h2d_bytes + 1e-9
    assert nest.d2h_bytes <= naive.d2h_bytes + 1e-9


@given(program_and_genes())
@settings(max_examples=120, deadline=None)
def test_staged_removes_auto_sync(pg):
    prog, genes = pg
    for mode in tr.TransferMode:
        s_on = tr.build_schedule(prog, genes, mode, staged=True)
        s_off = tr.build_schedule(prog, genes, mode, staged=False)
        assert s_on.auto_sync_bytes == 0
        assert s_off.auto_sync_bytes >= 0
        # staging changes ONLY the auto-sync component
        assert s_on.h2d_bytes == s_off.h2d_bytes
        assert s_on.d2h_bytes == s_off.d2h_bytes


@given(program_and_genes())
@settings(max_examples=80, deadline=None)
def test_gpu_written_live_data_returns_to_host(pg):
    """Every var written ONLY on the accelerator must be copied back at
    least once under BULK (end-of-program flush)."""
    prog, genes = pg
    offload = prog.genes_to_offloads(genes)
    sched = tr.build_schedule(prog, genes, tr.TransferMode.BULK)
    gpu_written = set()
    cpu_touch_after = set()
    for loop in prog.loops:
        if offload[loop.name]:
            gpu_written |= loop.writes
        else:
            cpu_touch_after |= loop.reads | loop.writes
    final_gpu_only = gpu_written - cpu_touch_after
    if final_gpu_only:
        assert sched.d2h_bytes > 0


def test_present_elision_two_consecutive_gpu_reads():
    """A var read by two consecutive offloaded loops crosses once (BULK)."""
    v = Var("x", 1000)
    l1 = Loop("a", LoopClass.TIGHT, 2, 2, 1.0, frozenset({"x"}), frozenset())
    l2 = Loop("b", LoopClass.TIGHT, 2, 2, 1.0, frozenset({"x"}), frozenset())
    prog = LoopProgram("p", (l1, l2), (v,))
    bulk = tr.build_schedule(prog, (1, 1), tr.TransferMode.BULK)
    assert bulk.h2d_count == 1
    nest = tr.build_schedule(prog, (1, 1), tr.TransferMode.NAIVE)
    assert nest.h2d_count == 2


def test_cpu_write_invalidates_device_copy():
    v = Var("x", 1000)
    g1 = Loop("a", LoopClass.TIGHT, 2, 2, 1.0, frozenset({"x"}), frozenset())
    c = Loop("c", LoopClass.NOT_OFFLOADABLE, 2, 2, 1.0, frozenset(),
             frozenset({"x"}))
    g2 = Loop("b", LoopClass.TIGHT, 2, 2, 1.0, frozenset({"x"}), frozenset())
    prog = LoopProgram("p", (g1, c, g2), (v,))
    bulk = tr.build_schedule(prog, (1, 1), tr.TransferMode.BULK)
    assert bulk.h2d_count == 2  # re-transferred after the CPU write


def test_nest_mode_flushes_region_written_vars_every_iteration():
    """The Jacobi ping-pong: p written on GPU inside the region re-syncs
    per iteration under NEST but stays resident under BULK."""
    p = Var("p", 1_000_000)
    stencil = Loop("st", LoopClass.TIGHT, 4, 4, 1.0, frozenset({"p"}),
                   frozenset({"p"}), parent_seq="it")
    prog = LoopProgram("h", (stencil,), (p,), (SeqRegion("it", 50),))
    nest = tr.build_schedule(prog, (1,), tr.TransferMode.NEST)
    bulk = tr.build_schedule(prog, (1,), tr.TransferMode.BULK)
    assert nest.d2h_count == 50  # one flush per iteration
    assert nest.h2d_count == 50  # re-validated per iteration
    assert bulk.h2d_count == 1  # in once
    assert bulk.d2h_count == 1  # final result back once


def test_nest_mode_hoists_readonly_arrays():
    """[33] hoists read-only coefficient arrays out of the region."""
    a = Var("a", 500_000)
    stencil = Loop("st", LoopClass.TIGHT, 4, 4, 1.0, frozenset({"a"}),
                   frozenset(), parent_seq="it")
    prog = LoopProgram("h", (stencil,), (a,), (SeqRegion("it", 50),))
    nest = tr.build_schedule(prog, (1,), tr.TransferMode.NEST)
    assert nest.h2d_count == 1  # transferred once, stays resident
    assert nest.d2h_count == 0


def test_auto_sync_small_unsafe_vars_only():
    big = Var("big", 100 << 20, is_global=True, init_external=True)
    small = Var("small", 1024, is_global=True, init_external=True)
    l = Loop("a", LoopClass.TIGHT, 2, 2, 1.0, frozenset({"big", "small"}),
             frozenset())
    prog = LoopProgram("p", (l,), (big, small))
    s = tr.build_schedule(prog, (1,), tr.TransferMode.NEST, staged=False)
    assert s.auto_sync_bytes == 2 * 1024  # only the small parameter leaks
