"""Pallas kernel validation: interpret-mode kernel body vs pure-jnp oracle,
swept over shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd import ssd_pallas

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=2e-2, rtol=2e-2)}


def _qkv(rng, B, S, H, K, D, dtype):
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention: shape x dtype x mask sweeps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S", [64, 128, 256])
@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (4, 1)])
def test_flash_attention_causal_gqa(rng, S, H, K):
    q, k, v = _qkv(rng, 2, S, H, K, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(rng, dtype):
    q, k, v = _qkv(rng, 2, 128, 4, 2, 64, dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True).astype(dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        **TOL[dtype],
    )


@pytest.mark.parametrize("D", [32, 64, 80, 128])
def test_flash_attention_head_dim_padding(rng, D):
    """Non-lane-multiple head dims go through ops' pad/unpad path."""
    q, k, v = _qkv(rng, 1, 128, 2, 2, D, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, **TOL[jnp.float32])


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_local_window(rng, window):
    q, k, v = _qkv(rng, 1, 128, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(
        q, k, v, causal=True, local_window=window, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, local_window=window)
    np.testing.assert_allclose(out, want, **TOL[jnp.float32])


def test_flash_attention_softcap(rng):
    q, k, v = _qkv(rng, 1, 128, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(
        q, k, v, causal=True, logit_softcap=30.0, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, logit_softcap=30.0)
    np.testing.assert_allclose(out, want, **TOL[jnp.float32])


def test_flash_attention_bidirectional(rng):
    q, k, v = _qkv(rng, 1, 128, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, want, **TOL[jnp.float32])


@pytest.mark.parametrize("block", [64, 128])
def test_flash_attention_block_shapes(rng, block):
    """BlockSpec tiling must not change results."""
    q, k, v = _qkv(rng, 1, 256, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(
        q, k, v, causal=True, block_q=block, block_k=block, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, **TOL[jnp.float32])


def test_chunked_reference_matches_dense_reference(rng):
    """The CPU-lowering path (attention_chunked) is itself oracle-exact."""
    q, k, v = _qkv(rng, 2, 128, 4, 2, 64, jnp.float32)
    for kwargs in [dict(causal=True), dict(causal=False),
                   dict(causal=True, local_window=32),
                   dict(causal=True, logit_softcap=20.0)]:
        got = ref.attention_chunked(q, k, v, chunk=64, **kwargs)
        want = ref.attention_ref(q, k, v, **kwargs)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD (Mamba-2 state-space duality) kernel
# ---------------------------------------------------------------------------


def _ssd_inputs(rng, B, S, H, P, N, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(B, S, H)), dtype)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, size=(H,)), dtype)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    return x, dt, A, Bm, Cm


def _ssd_sequential(x, dt, A, Bm, Cm):
    """O(S) scalar-recurrence oracle (independent of the chunked ref)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    y = np.zeros((B, S, H, P), np.float64)
    state = np.zeros((B, H, P, N), np.float64)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    Bf = np.asarray(Bm, np.float64)
    Cf = np.asarray(Cm, np.float64)
    for t in range(S):
        decay = np.exp(Af[None, :] * dtf[:, t])  # (B, H)
        state = state * decay[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xf[:, t] * dtf[:, t][..., None], Bf[:, t]
        )
        y[:, t] = np.einsum("bhpn,bn->bhp", state, Cf[:, t])
    return y


@pytest.mark.parametrize("S,chunk", [(64, 16), (96, 32), (128, 128)])
def test_ssd_kernel_vs_sequential(rng, S, chunk):
    x, dt, A, Bm, Cm = _ssd_inputs(rng, 2, S, 2, 16, 16)
    got = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    want = _ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_ssd_ref_matches_sequential(rng):
    x, dt, A, Bm, Cm = _ssd_inputs(rng, 1, 64, 2, 8, 8)
    got = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=16)
    want = _ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_ssd_kernel_ragged_padding(rng):
    """S not a multiple of chunk exercises the zero-dt padding path."""
    x, dt, A, Bm, Cm = _ssd_inputs(rng, 1, 50, 2, 8, 8)
    got = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    want = _ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_ssd_decode_matches_scan_tail(rng):
    """One-token recurrence continues the scan exactly."""
    x, dt, A, Bm, Cm = _ssd_inputs(rng, 1, 33, 2, 8, 8)
    full = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=8, interpret=True)
    # state after S-1 tokens via the sequential oracle
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    state = np.zeros((1, H, P, N), np.float64)
    for t in range(S - 1):
        decay = np.exp(np.asarray(A, np.float64)[None, :] * np.asarray(dt)[:, t])
        state = state * decay[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn",
            np.asarray(x, np.float64)[:, t] * np.asarray(dt, np.float64)[:, t][..., None],
            np.asarray(Bm, np.float64)[:, t],
        )
    y_last, _ = ops.ssd_decode(
        x[:, -1], dt[:, -1], A, Bm[:, -1], Cm[:, -1],
        jnp.asarray(state, jnp.float32),
    )
    np.testing.assert_allclose(
        np.asarray(y_last), np.asarray(full[:, -1]), atol=2e-4, rtol=2e-3
    )


# ---------------------------------------------------------------------------
# decode attention (layers-level fused region)
# ---------------------------------------------------------------------------


def test_decode_attention_matches_full_attention(rng):
    from repro.models import layers as L

    B, S, H, K, D = 2, 32, 4, 2, 16
    q, k, v = _qkv(rng, B, S + 1, H, K, D, jnp.float32)
    # full attention over S+1 tokens
    full = ref.attention_ref(q, k, v, causal=True)
    # cache the first S tokens, decode token S
    cache = {
        "k": jnp.pad(k[:, :S], ((0, 0), (0, 8), (0, 0), (0, 0))),
        "v": jnp.pad(v[:, :S], ((0, 0), (0, 8), (0, 0), (0, 0))),
    }
    pos = jnp.full((B, 1), S, jnp.int32)
    out, _ = L.decode_attention(
        q[:, S:], k[:, S:], v[:, S:], cache, pos,
        local_window=0, logit_softcap=0.0,
    )
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), np.asarray(full[:, S]), atol=3e-5, rtol=3e-5
    )
