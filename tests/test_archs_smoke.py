"""Per-architecture smoke tests: reduced same-family config, one forward +
train step on CPU, asserting output shapes + finite values (assignment
requirement), plus prefill/decode consistency for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import ALL_SHAPES
from repro.core import analysis
from repro.models.model import Model, padded_vocab
from repro.optim.adamw import adamw
from repro.train import train_step as ts


def _batch(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    tgt = rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
    if cfg.family == "encoder":
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)), jnp.float32
            ),
            "targets": jnp.asarray(tgt),
        }
    if cfg.family == "vlm":
        pv = cfg.frontend_positions
        return {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, size=(B, S - pv)).astype(np.int32)
            ),
            "vision": jnp.asarray(
                rng.standard_normal((B, pv, cfg.d_model)), jnp.float32
            ),
            "targets": jnp.asarray(tgt[:, : S - pv]),
        }
    return {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
        ),
        "targets": jnp.asarray(tgt),
    }


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = get_arch(arch_id).reduced()
            plan = analysis.build_plan(cfg, None, n_groups=2)
            model = Model(cfg, plan)
            params = jax.jit(model.init)(jax.random.key(0))
            cache[arch_id] = (cfg, model, params)
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(models, arch_id):
    cfg, model, params = models(arch_id)
    batch = _batch(cfg)
    logits, caches, aux = model.forward(params, batch, mode="train")
    B = 2
    S = 32
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_no_nans(models, arch_id):
    cfg, model, params = models(arch_id)
    opt = adamw(1e-3)
    step = jax.jit(ts.make_train_step(model, opt))
    state = opt.init(params)
    batch = _batch(cfg)
    new_params, new_state, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize(
    "arch_id",
    [a for a in ARCH_IDS if not get_arch(a).encoder_only],
)
def test_prefill_then_decode_matches_full_forward(models, arch_id):
    """Strong correctness check: prefill(S) + decode(token S) must equal the
    full forward over S+1 tokens at the last position."""
    cfg, model, params = models(arch_id)
    # B=4, not 2: bf16 near-tied router scores can flip experts in BOTH
    # rows of a 2-row batch between the two compiled paths (seen on
    # llama4-maverick in full-suite runs), tripping the majority check
    # below. Four rows make a full-batch flip vanishingly unlikely while
    # keeping the same <=50% tolerance per row.
    B, S = 4, 16
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, size=(B, S + 1)).astype(np.int32)
    batch_full = {"tokens": jnp.asarray(toks)}
    if cfg.family == "vlm":
        vision = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_positions, cfg.d_model)),
            jnp.float32,
        )
        batch_full["vision"] = vision
    logits_full, _, _ = model.forward(params, batch_full, mode="prefill")

    batch_prefill = {"tokens": jnp.asarray(toks[:, :S])}
    if cfg.family == "vlm":
        batch_prefill["vision"] = vision
    _, cache = model.prefill(params, batch_prefill, ctx_len=S + 8)
    offset = cfg.frontend_positions if cfg.family == "vlm" else 0
    pos = jnp.full((B, 1), S + offset, jnp.int32)
    logits_dec, _ = model.decode_step(
        params, cache, jnp.asarray(toks[:, S : S + 1]), pos
    )
    got = np.asarray(logits_dec, np.float32)
    want = np.asarray(logits_full[:, -1], np.float32)
    if cfg.moe is not None:
        # top-k routing is discontinuous: near-tied router scores may flip
        # an expert between the two compiled paths (bf16-ulp differences in
        # the hidden state), changing that row's logits wholesale. Require
        # the MAJORITY of rows to match; flipped rows are expected MoE
        # behavior, not a cache bug.
        row_mism = np.mean(
            np.abs(got - want) > 3e-2 + 3e-2 * np.abs(want), axis=-1
        )
        assert np.mean(row_mism > 0.10) <= 0.5, f"row mismatch {row_mism}"
    else:
        np.testing.assert_allclose(got, want, atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_cache_shapes_match_templates(models, arch_id):
    cfg, model, params = models(arch_id)
    if cfg.encoder_only:
        pytest.skip("no decode for encoders")
    cache = model.init_cache(batch=2, ctx_len=32)
    structs = model.cache_shape_structs(batch=2, ctx_len=32)
    got = jax.tree.map(lambda x: (x.shape, str(x.dtype)), cache)
    want = jax.tree.map(lambda s: (s.shape, str(s.dtype)), structs)
    assert got == want


def test_shape_applicability_rules():
    """Assignment: encoder skips decode; long_500k only for sub-quadratic."""
    names = {s.name for s in ALL_SHAPES}
    assert names == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    hubert = get_arch("hubert-xlarge")
    assert {s.name for s in hubert.shapes()} == {"train_4k", "prefill_32k"}
    for aid in ("mamba2-1.3b", "zamba2-1.2b", "gemma2-27b"):
        assert "long_500k" in {s.name for s in get_arch(aid).shapes()}, aid
    for aid in ("glm4-9b", "stablelm-3b", "llama4-maverick-400b-a17b"):
        assert "long_500k" not in {s.name for s in get_arch(aid).shapes()}


def test_total_runnable_cells():
    from repro.configs.base import all_cells

    cells = all_cells()
    assert len(cells) == 32  # 40 - 8 principled skips


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_assigned_config_values(arch_id):
    """Exact assignment-sheet values survive in the full configs."""
    cfg = get_arch(arch_id)
    expect = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }[arch_id]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expect


def test_moe_configs():
    moon = get_arch("moonshot-v1-16b-a3b")
    assert (moon.moe.num_experts, moon.moe.top_k) == (64, 6)
    llama = get_arch("llama4-maverick-400b-a17b")
    assert (llama.moe.num_experts, llama.moe.top_k) == (128, 1)


def test_ssm_configs():
    assert get_arch("mamba2-1.3b").ssm.state_dim == 128
    assert get_arch("zamba2-1.2b").ssm.state_dim == 64
