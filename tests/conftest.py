import os

# Tests run on the single real CPU device (the dry-run subprocess sets its
# own 512-device flag). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
