"""Docs integrity: the suite under docs/ (and README.md) must not
reference modules, paths or link targets that don't exist — the same
check the CI fast tier runs via scripts/check_docs.py."""
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import check_docs  # noqa: E402


def test_docs_suite_exists():
    for name in ("architecture.md", "destinations.md", "pipeline.md"):
        assert (REPO / "docs" / name).is_file(), name
    # README points into the suite
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    assert "docs/pipeline.md" in readme
    assert "docs/architecture.md" in readme


def test_no_dangling_references():
    errors = check_docs.check_all()
    assert not errors, "\n".join(errors)


def test_checker_catches_dangling_link(tmp_path):
    """The checker itself must actually fail on a bad reference."""
    bad = tmp_path / "bad.md"
    bad.write_text("see [x](does-not-exist.md) and "
                   "`src/repro/nonesuch.py` and `repro.nonesuch`\n",
                   encoding="utf-8")
    errors = check_docs.check_file(bad)
    # the tmp file is outside the repo; path rendering still works
    joined = "\n".join(str(e) for e in errors)
    assert "does-not-exist.md" in joined
    assert "src/repro/nonesuch.py" in joined
    assert "repro.nonesuch" in joined
