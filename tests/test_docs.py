"""Docs integrity: the suite under docs/ (and README.md) must not
reference modules, paths or link targets that don't exist — the same
check the CI fast tier runs via scripts/check_docs.py."""
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import check_docs  # noqa: E402


def test_docs_suite_exists():
    for name in ("architecture.md", "destinations.md", "pipeline.md",
                 "benchmarks.md", "observability.md"):
        assert (REPO / "docs" / name).is_file(), name
    # README points into the suite
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    assert "docs/pipeline.md" in readme
    assert "docs/architecture.md" in readme
    assert "docs/benchmarks.md" in readme
    assert "docs/observability.md" in readme


def test_observability_doc_is_cross_linked_and_complete():
    """docs/observability.md documents the trace schema and the quality
    metrics, and the rest of the suite points at it."""
    obs = (REPO / "docs" / "observability.md").read_text(encoding="utf-8")
    for required in ("trace.jsonl", "digest", "pass@k", "spearman",
                     "kendall", "allele entropy", "budget",
                     "ga.diversity"):
        assert required.lower() in obs.lower(), required
    for doc in ("architecture.md", "pipeline.md", "benchmarks.md"):
        text = (REPO / "docs" / doc).read_text(encoding="utf-8")
        assert "observability.md" in text, \
            f"{doc} must link observability.md"


def test_benchmarks_doc_is_cross_linked_and_complete():
    """The sweep cookbook must stay wired into the doc suite and keep
    documenting the trajectory schema + regression semantics."""
    bench = (REPO / "docs" / "benchmarks.md").read_text(encoding="utf-8")
    for required in ("BENCH_sweep.json", "--smoke", "leaderboard",
                     "best_time_s", "rel_tolerance", "exit code"):
        assert required.lower() in bench.lower(), required
    for doc in ("architecture.md", "pipeline.md"):
        text = (REPO / "docs" / doc).read_text(encoding="utf-8")
        assert "benchmarks.md" in text, f"{doc} must link benchmarks.md"


def test_serving_doc_is_cross_linked_and_complete():
    """docs/serving.md documents the job lifecycle, admission knobs,
    crash recovery and the CLI cookbook, and the suite points at it."""
    srv = (REPO / "docs" / "serving.md").read_text(encoding="utf-8")
    for required in ("queued", "running", "cancelled", "coalesc",
                     "max_in_flight", "crash", "resume", "hit rate",
                     "serve submit", "serve run", "fault", "fingerprint"):
        assert required.lower() in srv.lower(), required
    for doc in ("architecture.md", "pipeline.md"):
        text = (REPO / "docs" / doc).read_text(encoding="utf-8")
        assert "serving.md" in text, f"{doc} must link serving.md"
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    assert "docs/serving.md" in readme


def test_roadmap_is_reference_checked():
    """ROADMAP.md is in the checker's file set (its stale /root/related
    references were the ISSUE-6 docs fix; keep it honest), and no doc
    points at the /root/related mirror that doesn't exist in checkouts."""
    checked = {p.name for p in check_docs.checked_files()}
    assert "ROADMAP.md" in checked
    for f in check_docs.checked_files():
        assert "/root/related" not in f.read_text(encoding="utf-8"), f


def test_cli_verbs_document_exit_codes(capsys):
    """Every `python -m repro.offload` verb documents its exit codes in
    its --help epilog, from the one EXIT_CODES table."""
    from repro.offload.__main__ import EXIT_CODES, main

    assert set(EXIT_CODES) == {"run", "resume", "report", "trace",
                               "calibrate", "sweep", "serve"}
    for verb, codes in EXIT_CODES.items():
        assert codes[0][0] == 0, f"{verb} must document success"
        assert any(c == 2 for c, _ in codes), \
            f"{verb} must document the argparse usage-error exit"
        with pytest.raises(SystemExit) as ei:
            main([verb, "--help"])
        assert ei.value.code == 0
        out = capsys.readouterr().out
        assert "exit codes:" in out, verb
        for code, meaning in codes:
            assert f"\n  {code}  " in out, (verb, code)
    # the sweep regression verdict keeps its own, documented code
    assert any(c == 3 for c, _ in EXIT_CODES["sweep"])


def test_no_dangling_references():
    errors = check_docs.check_all()
    assert not errors, "\n".join(errors)


def test_checker_catches_dangling_link(tmp_path):
    """The checker itself must actually fail on a bad reference."""
    bad = tmp_path / "bad.md"
    bad.write_text("see [x](does-not-exist.md) and "
                   "`src/repro/nonesuch.py` and `repro.nonesuch`\n",
                   encoding="utf-8")
    errors = check_docs.check_file(bad)
    # the tmp file is outside the repo; path rendering still works
    joined = "\n".join(str(e) for e in errors)
    assert "does-not-exist.md" in joined
    assert "src/repro/nonesuch.py" in joined
    assert "repro.nonesuch" in joined
