"""Observability layer (ISSUE 7): structured pipeline tracing
(src/repro/offload/trace.py), search-quality metrics in the report stage
(src/repro/offload/quality.py via the Offloader), the ga.diversity
selection knob, and the `python -m repro.offload trace` CLI verb.

The load-bearing guarantees:

- two identical modeled runs produce traces with IDENTICAL content
  digests (timing is excluded by construction), and the artifact embeds
  that digest;
- with tracing on and ga.diversity unset, the search payload (winner,
  history, evaluator fingerprint) is byte-identical to an untraced run —
  observability must never perturb the search;
- a zero-generation search records an explicit no-winner payload and the
  report renders a clear "no generations" line;
- the report stage carries pass@k winner stability and rank fidelity,
  and the stability gate turns excessive spread into a stage failure.
"""
import dataclasses
import itertools
import json
import os

import pytest

from repro.core import ga
from repro.offload import trace as tm
from repro.offload.__main__ import main
from repro.offload.pipeline import Offloader, render_report
from repro.offload.result import OffloadResult, StageFailure
from repro.offload.spec import GAControls, OffloadSpec


def _clock():
    """A deterministic injected clock: 0.0, 0.5, 1.0, ..."""
    c = itertools.count()
    return lambda: next(c) * 0.5


def _run(tmp_path, name, spec, **kw):
    path = str(tmp_path / f"{name}.offload.json")
    off = Offloader(spec, artifact_path=path, trace_clock=_clock(), **kw)
    off.run()
    return off.result, path


SPEC = OffloadSpec(program="himeno", mode="binary")


# ---------------------------------------------------------------------------
# trace determinism + round-trip
# ---------------------------------------------------------------------------


def test_two_identical_runs_same_digest(tmp_path):
    r1, p1 = _run(tmp_path / "a", "x", SPEC)
    r2, p2 = _run(tmp_path / "b", "x", SPEC)
    t1 = tm.load_trace(tm.default_trace_path(p1))
    t2 = tm.load_trace(tm.default_trace_path(p2))
    assert t1.digest == t2.digest
    # record-by-record: identical modulo the clock-derived keys
    assert [tm.strip_timing(r) for r in t1.records] == \
           [tm.strip_timing(r) for r in t2.records]
    # the artifact embeds exactly this digest
    assert r1.trace["digest"] == t1.digest
    assert r1.trace["records"] == len(t1.records)
    assert r1.trace["path"] == os.path.basename(tm.default_trace_path(p1))
    # and it survives the artifact's own JSON round-trip
    assert OffloadResult.load(p1).trace == r1.trace


def test_trace_structure_and_span_order(tmp_path):
    _, path = _run(tmp_path, "x", SPEC)
    tr = tm.load_trace(tm.default_trace_path(path))
    assert tr.records[0]["kind"] == "run"
    assert tr.records[0]["schema"] == tm.TRACE_SCHEMA
    assert tr.records[0]["resumed"] is False
    assert [s["name"] for s in tr.spans()] == [
        "calibrate", "analyze", "seed", "search", "verify", "report"]
    assert all(s["status"] == "done" for s in tr.spans())
    # one generation event per GA generation, telemetry attached
    gens = [e for e in tr.events("search") if e["name"] == "generation"]
    n_gens = len(tr.spans()[3]["attrs"])  # sanity: attrs present
    assert n_gens > 0
    assert len(gens) == tr.spans()[3]["attrs"]["generations"]
    for e in gens:
        a = e["attrs"]
        for key in ("generation", "best_time_s", "median_time_s",
                    "best_fitness", "median_fitness", "allele_entropy",
                    "evaluated", "cache_hits", "dedup_ratio"):
            assert key in a, key
        assert 0.0 <= a["allele_entropy"] <= 1.0
        # the pool's generation wall clock is timing, never attrs
        assert "wall_s" in e.get("timing", {})
    # the report stage evented its stability re-searches
    assert any(e["name"] == "stability_search" for e in tr.events("report"))


def _scrub_wall(obj):
    """Drop measured wall-clock fields — the only legitimately
    nondeterministic payload content."""
    if isinstance(obj, dict):
        return {k: _scrub_wall(v) for k, v in obj.items()
                if "wall_s" not in k}
    if isinstance(obj, list):
        return [_scrub_wall(v) for v in obj]
    return obj


def test_tracing_does_not_perturb_search(tmp_path):
    traced, _ = _run(tmp_path / "on", "x", SPEC)
    off = Offloader(SPEC, artifact_path=str(tmp_path / "off.offload.json"),
                    trace=False)
    untraced = off.run()
    assert not os.path.exists(
        tm.default_trace_path(str(tmp_path / "off.offload.json")))
    assert untraced.trace is None
    assert _scrub_wall(traced.stage("search").payload) == \
        _scrub_wall(untraced.stage("search").payload)


def test_resume_appends_second_run_header(tmp_path):
    path = str(tmp_path / "x.offload.json")
    off = Offloader(SPEC, artifact_path=path, trace_clock=_clock())
    off.run(until="seed")
    off2 = Offloader.resume(path, trace_clock=_clock())
    res = off2.run()
    tr = tm.load_trace(tm.default_trace_path(path))
    runs = [r for r in tr.records if r["kind"] == "run"]
    assert [r["resumed"] for r in runs] == [False, True]
    # seq stayed contiguous across processes and the digest matches
    assert res.trace["digest"] == tr.digest
    rendered = tm.render_trace(tr, artifact=res)
    assert "run 2 (resumed" in rendered
    assert "matches" in rendered


def test_load_trace_rejects_corruption(tmp_path):
    path = str(tmp_path / "t.trace.jsonl")
    w = tm.TraceWriter(path, clock=_clock())
    w.run_header(program="p", mode="binary", fidelity="modeled",
                 spec_digest="d", resumed=False)
    w.span("analyze", 0.0, 1.0, "done")
    w.close()
    recs = tm.load_trace(path).records  # sane baseline

    with open(path, "a", encoding="utf-8") as fh:  # truncated tail line
        fh.write('{"seq": 2, "kind": "span"')
    with pytest.raises(tm.TraceError):
        tm.load_trace(path)

    bad = str(tmp_path / "gap.trace.jsonl")
    with open(bad, "w", encoding="utf-8") as fh:
        fh.write(json.dumps(recs[0]) + "\n")
        skipped = dict(recs[1], seq=5)
        fh.write(json.dumps(skipped) + "\n")
    with pytest.raises(tm.TraceError, match="seq"):
        tm.load_trace(bad)

    empty = str(tmp_path / "empty.trace.jsonl")
    open(empty, "w").close()
    with pytest.raises(tm.TraceError, match="empty"):
        tm.load_trace(empty)

    noheader = str(tmp_path / "nh.trace.jsonl")
    with open(noheader, "w", encoding="utf-8") as fh:
        fh.write(json.dumps({"seq": 0, "kind": "span", "name": "x",
                             "status": "done", "t0": 0, "t1": 1}) + "\n")
    with pytest.raises(tm.TraceError, match="run header"):
        tm.load_trace(noheader)


def test_default_trace_path():
    assert tm.default_trace_path("a/b.offload.json") == \
        "a/b.offload.trace.jsonl"
    assert tm.default_trace_path("plain") == "plain.trace.jsonl"


def test_in_memory_artifact_traces_nothing():
    off = Offloader(SPEC)  # no artifact path, no trace path
    res = off.run()
    assert res.trace is None  # silently disabled, pipeline unharmed
    assert res.completed("report")


# ---------------------------------------------------------------------------
# satellite: telemetry persisted in the search payload
# ---------------------------------------------------------------------------


def test_generation_telemetry_persisted(tmp_path):
    res, path = _run(tmp_path, "x", SPEC)
    p = res.stage("search").payload
    tel = p["telemetry"]
    assert len(tel) == len(p["history"]) > 0
    for row in tel:  # row index == generation index
        for key in ("submitted", "unique", "cache_hits", "evaluated",
                    "timeouts", "dedup_ratio", "hit_rate"):
            assert key in row, key
    assert sum(r["evaluated"] for r in tel) == p["evaluations"]
    # the final population (and its times) round-trip for rank metrics
    assert len(p["final_population"]) == p["ga"]["population"]
    assert len(p["final_times_s"]) == p["ga"]["population"]
    assert p["ga"]["allele_names"] == ["cpu", "gpu"]
    loaded = OffloadResult.load(path)
    assert loaded.stage("search").payload == p


# ---------------------------------------------------------------------------
# satellite: zero-generation searches
# ---------------------------------------------------------------------------


def test_zero_generation_report(tmp_path, capsys):
    spec = dataclasses.replace(SPEC, generations=0)
    res, path = _run(tmp_path, "zg", spec)
    p = res.stage("search").payload
    assert p["best_time_s"] is None
    assert p["best_genes"] == []
    assert p["history"] == [] and p["final_population"] == []
    assert res.best_time_s is None and res.speedup is None
    assert "no winner to verify" in res.stage("verify").payload["note"]
    text = res.stage("report").payload["text"]
    assert "no generations" in text
    assert "placement:" not in text
    q = res.stage("report").payload["quality"]
    assert "zero generations" in q["stability"]["skipped"]
    assert "skipped" in q["rank"]
    # the CLI report verb renders the same line from the saved artifact
    assert main(["report", "--artifact", path]) == 0
    assert "no generations" in capsys.readouterr().out
    # render_report also handles a LOADED artifact (quality from payload)
    assert "no generations" in render_report(OffloadResult.load(path))


# ---------------------------------------------------------------------------
# quality metrics in the report stage
# ---------------------------------------------------------------------------


def test_stability_section_contents(tmp_path):
    res, _ = _run(tmp_path, "x", SPEC)
    q = res.stage("report").payload["quality"]
    st = q["stability"]
    assert st["k"] == 3  # default GAControls.stability_seeds
    assert st["reused_recorded"] is True  # seed 0 came from the search
    assert st["winners"][0]["reused"] is True
    assert st["winners"][0]["best_time_s"] == res.best_time_s
    assert {w["seed"] for w in st["winners"]} == {0, 1, 2}
    assert 0.0 <= st["pass_at_k"] <= 1.0
    assert st["rel_spread"] >= 0.0
    assert "pass@" in res.stage("report").payload["text"]


def test_stability_gate_fails_report_stage(tmp_path):
    base, _ = _run(tmp_path / "base", "x", SPEC)
    spread = base.stage("report").payload["quality"]["stability"][
        "rel_spread"]
    assert spread > 0.0  # deterministic modeled search: pinned behavior
    spec = dataclasses.replace(SPEC, ga=GAControls(stability_gate=spread / 2))
    path = str(tmp_path / "gated.offload.json")
    off = Offloader(spec, artifact_path=path, trace_clock=_clock())
    with pytest.raises(StageFailure, match="stability gate"):
        off.run()
    rec = off.result.stages["report"]
    assert rec.status == "failed"
    # the quality numbers are still recorded alongside the failure
    assert rec.payload["quality"]["stability"]["rel_spread"] == spread
    # and a permissive gate passes
    ok_spec = dataclasses.replace(SPEC, ga=GAControls(stability_gate=1.0))
    res, _ = _run(tmp_path / "ok", "x", ok_spec)
    assert res.completed("report")


def test_stability_disabled_and_injected_evaluator_skips(tmp_path):
    spec = dataclasses.replace(SPEC, ga=GAControls(stability_seeds=1))
    res, _ = _run(tmp_path / "off", "x", spec)
    st = res.stage("report").payload["quality"]["stability"]
    assert "skipped" in st and "stability_seeds" in st["skipped"]

    calls = []

    def injected(genes):
        calls.append(tuple(genes))
        return 1.0 + sum(genes) * 0.01

    off = Offloader(SPEC, evaluator=injected)
    res = off.run()
    q = res.stage("report").payload["quality"]
    assert "injected" in q["stability"]["skipped"]
    assert "injected" in q["rank"]["skipped"]


def test_rank_probe_measures_two_projections(tmp_path):
    spec = dataclasses.replace(SPEC, ga=GAControls(rank_probe=True))
    res, path = _run(tmp_path, "rp", spec)
    rk = res.stage("report").payload["quality"]["rank"]
    assert "skipped" not in rk
    assert rk["n"] == res.stage("search").payload["ga"]["population"]
    assert rk["spearman"] is not None
    assert -1.0 <= rk["spearman"] <= 1.0
    assert rk["distinct_measured"] <= 2  # two wall-clocked projections
    assert rk["reference"] == "model:quadro-p4000"
    tr = tm.load_trace(tm.default_trace_path(path))
    probes = [e for e in tr.events("report") if e["name"] == "rank_probe"]
    assert 1 <= len(probes) <= 2
    assert "rank fidelity spearman" in res.stage("report").payload["text"]


def test_rank_skipped_without_probe_or_implementation(tmp_path):
    res, _ = _run(tmp_path / "a", "x", SPEC)
    rk = res.stage("report").payload["quality"]["rank"]
    assert "rank_probe" in rk["skipped"]
    arch = OffloadSpec(program="arch:stablelm-3b", mode="binary",
                       ga=GAControls(rank_probe=True))
    res, _ = _run(tmp_path / "b", "x", arch)
    rk = res.stage("report").payload["quality"]["rank"]
    assert "no runnable implementation" in rk["skipped"]


# ---------------------------------------------------------------------------
# ga.diversity: off by default, byte-identical when unset
# ---------------------------------------------------------------------------


def _toy_pool_run(diversity):
    params = ga.GAParams.for_gene_length(
        6, seed=7, timeout_s=1e6, penalty_time_s=1e6, alleles=2,
        diversity=diversity,
    )
    evaluate = lambda genes: 1.0 + sum(genes) * 0.1  # noqa: E731
    return ga.run_ga(evaluate, 6, params)


def test_diversity_zero_is_byte_identical():
    a = _toy_pool_run(0.0)
    b = _toy_pool_run(0.0)
    assert a.best_genes == b.best_genes
    assert [h.population for h in a.history] == \
           [h.population for h in b.history]
    # the dataclass default IS 0.0: an unset spec changes nothing
    assert ga.GAParams.for_gene_length(6, seed=7, timeout_s=1, penalty_time_s=1).diversity == 0.0
    assert OffloadSpec(program="himeno", mode="binary").ga.diversity == 0.0


def test_diversity_changes_selection_only_when_set():
    base = _toy_pool_run(0.0)
    shared = _toy_pool_run(1.5)
    # same RNG stream, same generation 0 (selection happens after)
    assert base.history[0].population == shared.history[0].population
    # ...but fitness sharing must steer later generations differently
    assert [h.population for h in base.history] != \
           [h.population for h in shared.history]
    with pytest.raises(ValueError, match="diversity"):
        _toy_pool_run(-0.5)


def test_diversity_threads_through_the_spec(tmp_path):
    spec = dataclasses.replace(SPEC, ga=GAControls(diversity=1.0))
    res, _ = _run(tmp_path, "div", spec)
    assert res.stage("search").payload["ga"]["diversity"] == 1.0
    # spec JSON round-trip keeps the knob (dict -> GAControls coercion)
    spec2 = OffloadSpec.from_dict(json.loads(spec.to_json()))
    assert spec2.ga == GAControls(diversity=1.0)
    assert spec2 == spec


def test_fast_search_knobs_thread_through_the_spec(tmp_path):
    spec = dataclasses.replace(
        SPEC, ga=GAControls(steady_state=True), generations=4)
    res, _ = _run(tmp_path, "steady", spec)
    ga_payload = res.stage("search").payload["ga"]
    assert ga_payload["steady_state"] is True
    spec2 = OffloadSpec.from_dict(json.loads(spec.to_json()))
    assert spec2.ga.steady_state and spec2 == spec
    # knobs-off searches must not even carry the keys: payload and spec
    # JSON stay byte-identical to pre-fast-search artifacts
    base, _ = _run(tmp_path, "base", SPEC)
    assert "steady_state" not in base.stage("search").payload["ga"]
    assert "batch" not in base.stage("search").payload["ga"]
    d = json.loads(SPEC.to_json())
    assert "steady_state" not in d["ga"] and "batch" not in d["ga"]


# ---------------------------------------------------------------------------
# the trace CLI verb
# ---------------------------------------------------------------------------


def test_trace_cli_renders_budget_attribution(tmp_path, capsys):
    _, path = _run(tmp_path, "x", SPEC)
    assert main(["trace", "--artifact", path]) == 0
    out = capsys.readouterr().out
    assert "budget attribution:" in out
    assert "measurement concentration" in out
    assert "artifact digest" in out and "matches" in out
    for stage in ("calibrate", "analyze", "seed", "search", "verify",
                  "report"):
        assert stage in out
    # the evalpool's per-generation clocks (recorded under the events'
    # digest-exempt timing sub-dict) must actually be RENDERED: the
    # barrier-idle / lane-starvation column was recorded but invisible
    assert "idle_s" in out
    assert "eval_s" in out


def test_trace_cli_exit_codes(tmp_path, capsys):
    _, path = _run(tmp_path, "x", SPEC)
    trace_path = tm.default_trace_path(path)

    os.rename(trace_path, trace_path + ".gone")
    assert main(["trace", "--artifact", path]) == 1  # missing file
    os.rename(trace_path + ".gone", trace_path)

    with open(trace_path, "a", encoding="utf-8") as fh:
        fh.write("not json\n")
    assert main(["trace", "--artifact", path]) == 1  # malformed

    # a VALID but foreign/stale trace: digest mismatch against the
    # artifact's embedded digest
    other = str(tmp_path / "other.trace.jsonl")
    w = tm.TraceWriter(other, clock=_clock())
    w.run_header(program="himeno", mode="binary", fidelity="modeled",
                 spec_digest="feedface", resumed=False)
    w.close()
    capsys.readouterr()
    assert main(["trace", "--artifact", path, "--trace", other]) == 1
    assert "does not match" in capsys.readouterr().err
