"""Fast-search substrate: batch-pricing parity + steady-state GA
properties (docs/pipeline.md "Fast search").

Seeded property tests (no hypothesis in the image; every random draw is
pinned by seed, so failures replay exactly):

- the vectorized :class:`BatchMixedEvaluator` prices random genomes
  identically to the scalar :class:`MixedEvaluator` oracle to round-off,
  over unbounded and capacity-bounded registries and over
  block-substitution genomes;
- cache identity (fingerprint + canonical keys) is unchanged by the
  batch subclass, so batch and scalar searches share one fitness cache;
- the steady-state GA spends its evaluation budget exactly, never loses
  the best-so-far genome, and emits the same one-row-per-generation
  telemetry/history shape as the generational loop;
- the ``OffloadSpec.ga`` fast-search knobs serialize only when set
  (knobs-off spec digests stay byte-identical to prior artifacts) and
  the full pipeline completes + verifies with both knobs on.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.blocks import BatchBlockMixedEvaluator, BlockMixedEvaluator
from repro.core import ga, miniapps
from repro.core.evalpool import EvalPool
from repro.destinations import (
    BatchMixedEvaluator,
    MixedEvaluator,
    get_registry,
)
from repro.offload import Offloader, OffloadSpec
from repro.offload.spec import GAControls

RTOL = 1e-12  # far under the pipeline's 1e-9 verify tolerance

REGISTRIES = ("quadro-p4000", "p4000-constrained", "tpu-v5e-host")
PROGRAMS = ("hetero", "himeno", "nasft")


def _genomes(rng, gene_length, k, n):
    return [
        tuple(int(x) for x in rng.integers(0, k, gene_length))
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# parity with the scalar oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("regname", REGISTRIES)
@pytest.mark.parametrize("pname", PROGRAMS)
def test_batch_pricing_matches_scalar_oracle(regname, pname):
    reg = get_registry(regname)
    names = tuple(d.name for d in reg.destinations)
    prog = miniapps.MINIAPPS[pname]()
    scalar = MixedEvaluator(prog, names, registry=reg)
    batch = BatchMixedEvaluator(prog, names, registry=reg)
    rng = np.random.default_rng(20260809)
    genomes = _genomes(rng, prog.gene_length, scalar.k, 48)
    got = batch.evaluate_batch(genomes)
    want = [scalar(g) for g in genomes]
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g == pytest.approx(w, rel=RTOL)


def test_bounded_registry_falls_back_to_exact_scalar_pricing():
    # a capacity-bounded searched destination has per-genome eviction
    # state: the batch path degrades to per-genome scalar calls, so the
    # numbers are EQUAL, not just close
    reg = get_registry("p4000-constrained")
    names = tuple(d.name for d in reg.destinations)
    prog = miniapps.hetero_program()
    scalar = MixedEvaluator(prog, names, registry=reg)
    batch = BatchMixedEvaluator(prog, names, registry=reg)
    assert batch._scalar_only
    rng = np.random.default_rng(7)
    genomes = _genomes(rng, prog.gene_length, scalar.k, 16)
    assert batch.evaluate_batch(genomes) == [scalar(g) for g in genomes]


def test_batch_pricing_matches_scalar_on_block_genomes():
    scalar = BlockMixedEvaluator(miniapps.hetero_program())
    batch = BatchBlockMixedEvaluator(miniapps.hetero_program())
    assert batch.gene_length == scalar.gene_length
    rng = np.random.default_rng(99)
    genomes = _genomes(rng, scalar.gene_length, scalar.k, 48)
    got = batch.evaluate_batch(genomes)
    want = [scalar(g) for g in genomes]
    for g, w in zip(got, want):
        assert g == pytest.approx(w, rel=RTOL)


def test_batch_subclass_keeps_cache_identity():
    prog = miniapps.hetero_program()
    scalar = MixedEvaluator(prog)
    batch = BatchMixedEvaluator(prog)
    assert batch.fingerprint() == scalar.fingerprint()
    rng = np.random.default_rng(3)
    for genes in _genomes(rng, prog.gene_length, scalar.k, 8):
        assert batch.cache_key(genes) == scalar.cache_key(genes)
        # scalar __call__ is inherited untouched — the verify oracle
        assert batch(genes) == scalar(genes)


def test_batch_empty_population_and_subset_destinations():
    prog = miniapps.hetero_program()
    batch = BatchMixedEvaluator(prog, ("cpu", "gpu"))
    assert batch.evaluate_batch([]) == []
    scalar = MixedEvaluator(prog, ("cpu", "gpu"))
    rng = np.random.default_rng(11)
    genomes = _genomes(rng, prog.gene_length, 2, 16)
    got = batch.evaluate_batch(genomes)
    want = [scalar(g) for g in genomes]
    for g, w in zip(got, want):
        assert g == pytest.approx(w, rel=RTOL)


def test_evalpool_batch_path_agrees_with_scalar_pool():
    # one pool over the batch evaluator, one over the scalar: identical
    # per-generation times through evaluate_generation
    prog = miniapps.hetero_program()
    scalar = MixedEvaluator(prog)
    batch = BatchMixedEvaluator(prog)
    rng = np.random.default_rng(5)
    popn = _genomes(rng, prog.gene_length, scalar.k, 24)
    with EvalPool(scalar) as p1, EvalPool(batch) as p2:
        t1, tel1 = p1.evaluate_generation(popn, 1e6, 1000.0)
        t2, tel2 = p2.evaluate_generation(popn, 1e6, 1000.0)
    assert t1 == pytest.approx(t2, rel=RTOL)
    assert (tel1.submitted, tel1.unique, tel1.cache_hits) == \
        (tel2.submitted, tel2.unique, tel2.cache_hits)


# ---------------------------------------------------------------------------
# steady-state GA properties
# ---------------------------------------------------------------------------


def _steady_run(workers, seed=0, pop=10, gens=5):
    prog = miniapps.hetero_program()
    ev = MixedEvaluator(prog)
    pool = EvalPool(ev, workers=workers)
    params = ga.GAParams(population=pop, generations=gens, seed=seed,
                         alleles=ev.k, steady_state=True)
    res = ga.run_ga(None, prog.gene_length, params, pool=pool)
    return res, pool, params, ev


@pytest.mark.parametrize("workers", [1, 4])
def test_steady_state_budget_is_exact(workers):
    res, pool, params, _ = _steady_run(workers)
    tot = pool.totals()
    budget = params.population * params.generations
    assert tot.submitted == budget
    # every submission resolves to a fresh measurement or a hit — no
    # double counting, nothing dropped
    assert tot.evaluated + tot.cache_hits == tot.submitted
    assert res.evaluations == tot.evaluated


@pytest.mark.parametrize("workers", [1, 4])
def test_steady_state_never_loses_the_best(workers):
    res, pool, params, ev = _steady_run(workers, seed=2)
    bests = [h.best_time_s for h in res.history]
    assert all(b2 <= b1 for b1, b2 in zip(bests, bests[1:]))
    assert res.best_time_s == bests[-1]
    # the reported winner re-prices to exactly its reported time
    assert ev(res.best_genes) == pytest.approx(res.best_time_s, rel=RTOL)


def test_steady_state_history_shape_matches_generational():
    res, pool, params, _ = _steady_run(1)
    assert len(res.history) == params.generations
    assert len(pool.history) == params.generations
    for h in res.history:
        assert len(h.times) == params.population
        assert len(h.population) == params.population
    # telemetry rows carry the idle attribution key (rendered by the
    # trace CLI budget table)
    assert all("idle_wall_s" in t.row() for t in pool.history)


def test_steady_state_inline_is_deterministic():
    r1, *_ = _steady_run(1, seed=4)
    r2, *_ = _steady_run(1, seed=4)
    assert r1.best_genes == r2.best_genes
    assert r1.best_time_s == r2.best_time_s
    assert [h.best_time_s for h in r1.history] == \
        [h.best_time_s for h in r2.history]


def test_steady_state_single_generation_falls_back_to_barrier():
    # generations=1 has no steady tail; the dispatch must not engage
    prog = miniapps.hetero_program()
    ev = MixedEvaluator(prog)
    params = ga.GAParams(population=6, generations=1, seed=0,
                         alleles=ev.k, steady_state=True)
    res = ga.run_ga(ev, prog.gene_length, params)
    assert len(res.history) == 1


# ---------------------------------------------------------------------------
# spec serialization + full pipeline
# ---------------------------------------------------------------------------


def test_fast_search_knobs_serialize_only_when_set():
    off = OffloadSpec(program="hetero", mode="mixed")
    d = off.to_dict()
    assert "steady_state" not in d["ga"]
    assert "batch" not in d["ga"]
    assert OffloadSpec.from_dict(d) == off

    on = OffloadSpec(program="hetero", mode="mixed",
                     ga=GAControls(steady_state=True, batch=True))
    d = on.to_dict()
    assert d["ga"]["steady_state"] is True
    assert d["ga"]["batch"] is True
    rt = OffloadSpec.from_dict(d)
    assert rt.ga.steady_state and rt.ga.batch
    assert rt == on


def test_pipeline_with_both_knobs_completes_and_verifies():
    spec = OffloadSpec(
        program="hetero", mode="mixed", population=10, generations=6,
        ga=GAControls(steady_state=True, batch=True, stability_seeds=0),
    )
    res = Offloader(spec).run()
    assert res.completed("verify")
    v = res.stage("verify").payload
    assert v["consistent"] is True
    s = res.stage("search").payload
    assert s["ga"]["steady_state"] is True
    assert s["ga"]["batch"] is True
    # the scalar oracle re-measured the batch-priced winner within the
    # pipeline's tolerance
    assert v["re_measured_s"] == pytest.approx(s["best_time_s"], rel=1e-9)


def test_batch_knob_alone_reproduces_the_scalar_search_winner():
    base = OffloadSpec(program="hetero", mode="mixed", population=10,
                       generations=6, ga=GAControls(stability_seeds=0))
    fast = OffloadSpec(program="hetero", mode="mixed", population=10,
                       generations=6,
                       ga=GAControls(batch=True, stability_seeds=0))
    r1 = Offloader(base).run(until="search").stage("search").payload
    r2 = Offloader(fast).run(until="search").stage("search").payload
    # same RNG stream, same (to round-off) fitness values -> same winner
    assert r1["best_genes"] == r2["best_genes"]
    assert r1["best_time_s"] == pytest.approx(r2["best_time_s"], rel=RTOL)
