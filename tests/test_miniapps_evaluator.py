"""Miniapp structure, analytic evaluator, PCAST, and fig.5 endpoint bands."""
import numpy as np
import pytest

from repro.core import evaluator as ev
from repro.core import ga, miniapps, pcast
from repro.core import transfer as tr
from repro.core.loopir import LoopClass


# ---------------------------------------------------------------------------
# structure (paper counts)
# ---------------------------------------------------------------------------


def test_himeno_gene_length_is_13():
    prog = miniapps.himeno_program()
    assert prog.gene_length == 13


def test_nasft_has_82_loops_65_offloadable():
    prog = miniapps.nasft_program()
    assert len(prog.loops) == 82
    assert prog.gene_length == 65


def test_himeno_driver_excluded_from_genes():
    prog = miniapps.himeno_program()
    names = [l.name for l in prog.offloadable_loops]
    assert "jacobi_driver" not in names
    assert "jacobi_stencil" in names


def test_programs_validate_wellformed():
    for make in (miniapps.himeno_program, miniapps.nasft_program):
        prog = make()
        assert prog.total_flops() > 0
        # every region name resolves
        for l in prog.loops:
            prog.region_trip(l.parent_seq)


def test_genes_to_offloads_mapping():
    prog = miniapps.himeno_program()
    genes = [0] * prog.gene_length
    genes[prog.gene_length - 1] = 1
    off = prog.genes_to_offloads(genes)
    assert sum(off.values()) == 1


# ---------------------------------------------------------------------------
# analytic evaluator
# ---------------------------------------------------------------------------


def test_cpu_only_time_has_no_transfer_or_accel():
    prog = miniapps.himeno_program()
    bd = ev.predict_time(prog, (0,) * prog.gene_length)
    assert bd.accel_s == 0.0
    assert bd.transfer_s == 0.0
    assert bd.cpu_s > 0.0


def test_kernels_only_masks_non_tight_genes():
    prog = miniapps.nasft_program()
    e = ev.MiniappEvaluator(prog, kernels_only=True)
    genes = (1,) * prog.gene_length
    masked = e.admissible(genes)
    for g, l in zip(masked, prog.offloadable_loops):
        if l.klass != LoopClass.TIGHT:
            assert g == 0
        else:
            assert g == 1


def test_vector_only_loops_run_at_vector_rate():
    prog = miniapps.himeno_program()
    loop = next(l for l in prog.loops if l.klass == LoopClass.VECTOR_ONLY)
    hw = ev.QUADRO_P4000
    t = ev.loop_time(prog, loop, offloaded=True, hw=hw)
    # vector rate bound at least: cannot be faster than kernels-rate time
    t_flops_kernels = loop.total_flops / hw.accel_flops_kernels
    assert t >= t_flops_kernels


def test_offloading_stencil_beats_cpu_only():
    prog = miniapps.himeno_program()
    e = ev.MiniappEvaluator(prog)
    cpu = e((0,) * prog.gene_length)
    all_on = e((1,) * prog.gene_length)
    assert all_on < cpu / 5


# ---------------------------------------------------------------------------
# fig. 5 endpoints (the paper's result bands, via the real GA)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "app,prev_band,prop_band",
    [
        ("himeno", (4.0, 6.5), (12.0, 19.0)),  # paper: 4.8 / 15.4
        ("nasft", (3.5, 6.5), (7.5, 12.5)),  # paper: 5.4 / 10.0
    ],
)
def test_fig5_speedup_bands(app, prev_band, prop_band):
    prog = miniapps.MINIAPPS[app]()
    n = prog.gene_length
    cpu = ev.predict_time(prog, (0,) * n).total_s
    params = ga.GAParams.for_gene_length(n, seed=0)

    prev = ev.MiniappEvaluator(
        prog, tr.TransferMode.NEST, staged=False, kernels_only=True
    )
    r_prev = ga.run_ga(prev, n, params)
    s_prev = cpu / r_prev.best_time_s
    assert prev_band[0] <= s_prev <= prev_band[1], s_prev

    prop = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)
    r_prop = ga.run_ga(prop, n, params)
    s_prop = cpu / r_prop.best_time_s
    assert prop_band[0] <= s_prop <= prop_band[1], s_prop
    # the paper's core claim: proposed strictly beats previous
    assert s_prop > s_prev


# ---------------------------------------------------------------------------
# runnable implementations + PCAST
# ---------------------------------------------------------------------------


def test_himeno_pcast_jit_vs_numpy():
    p_j, g_j = miniapps.himeno_run(grid=(9, 9, 17), nn=3, jit_stencil=True)
    p_n, g_n = miniapps.himeno_run(grid=(9, 9, 17), nn=3, jit_stencil=False)
    rep = pcast.compare(
        {"p": p_n, "gosa": np.float32(g_n)},
        {"p": p_j, "gosa": np.float32(g_j)},
    )
    assert rep.ok, rep.describe()


def test_himeno_gosa_decreases():
    _, g3 = miniapps.himeno_run(grid=(9, 9, 17), nn=3)
    _, g12 = miniapps.himeno_run(grid=(9, 9, 17), nn=12)
    assert g12 < g3  # Jacobi converges on this SPD problem


def test_nasft_pcast_jit_vs_numpy():
    s_j = miniapps.nasft_run(grid=(8, 8, 8), niter=2, jit_fft=True)
    s_n = miniapps.nasft_run(grid=(8, 8, 8), niter=2, jit_fft=False)
    rep = pcast.compare({"chk": s_n}, {"chk": s_j})
    assert rep.ok, rep.describe()


# ---------------------------------------------------------------------------
# PCAST itself
# ---------------------------------------------------------------------------


def test_pcast_detects_differences():
    a = {"x": np.ones((4, 4), np.float32)}
    b = {"x": np.ones((4, 4), np.float32) * 1.5}
    rep = pcast.compare(a, b)
    assert not rep.ok
    assert rep.leaves[0].n_mismatch == 16


def test_pcast_dtype_aware_tolerance():
    import jax.numpy as jnp

    a = {"x": np.ones((8,), np.float32)}
    # bf16-level noise passes under bf16 tolerances, fails under f32
    noisy = (np.ones((8,)) * (1 + 5e-3)).astype(np.float32)
    assert not pcast.compare(a, {"x": noisy}).ok
    a16 = {"x": jnp.asarray(np.ones(8), jnp.bfloat16)}
    b16 = {"x": jnp.asarray(np.ones(8) * (1 + 5e-3), jnp.bfloat16)}
    assert pcast.compare(a16, b16).ok


def test_pcast_report_format():
    rep = pcast.compare({"x": np.zeros(3)}, {"x": np.zeros(3)})
    text = rep.describe()
    assert "PASS" in text and "max_rel" in text
