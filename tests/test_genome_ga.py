"""GA + genome invariants (unit + hypothesis property tests)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (dev extra)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ga, genome as G


# ---------------------------------------------------------------------------
# genome operators
# ---------------------------------------------------------------------------


@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_random_genome_shape_and_values(length, seed):
    g = G.random_genome(np.random.default_rng(seed), length)
    assert len(g) == length
    assert set(g) <= {0, 1}


@given(st.integers(2, 64), st.integers(0, 2**31 - 1))
def test_single_point_crossover_preserves_columns(length, seed):
    rng = np.random.default_rng(seed)
    a = G.random_genome(rng, length)
    b = G.random_genome(rng, length)
    ca, cb = G.crossover(rng, a, b, rate=1.0)
    for i in range(length):
        assert {ca[i], cb[i]} == {a[i], b[i]}


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_uniform_crossover_preserves_columns(length, seed):
    rng = np.random.default_rng(seed)
    a = G.random_genome(rng, length)
    b = G.random_genome(rng, length)
    ca, cb = G.uniform_crossover(rng, a, b, rate=1.0)
    for i in range(length):
        assert {ca[i], cb[i]} == {a[i], b[i]}


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_crossover_rate_zero_is_identity(length, seed):
    rng = np.random.default_rng(seed)
    a = G.random_genome(rng, length)
    b = G.random_genome(rng, length)
    assert G.crossover(rng, a, b, rate=0.0) == (a, b)
    assert G.uniform_crossover(rng, a, b, rate=0.0) == (a, b)


@given(st.integers(1, 128), st.integers(0, 2**31 - 1))
def test_mutate_zero_rate_identity_and_one_rate_flips_all(length, seed):
    rng = np.random.default_rng(seed)
    g = G.random_genome(rng, length)
    assert G.mutate(rng, g, 0.0) == g
    flipped = G.mutate(rng, g, 1.0)
    assert all(x != y for x, y in zip(g, flipped))


@given(st.integers(2, 32), st.integers(0, 2**31 - 1))
def test_roulette_pick_returns_member(n, seed):
    rng = np.random.default_rng(seed)
    pop = [G.random_genome(rng, 8) for _ in range(n)]
    fit = list(rng.random(n))
    assert G.roulette_pick(rng, pop, fit) in pop


def test_roulette_prefers_high_fitness():
    rng = np.random.default_rng(0)
    pop = [(0,), (1,)]
    fit = [0.01, 0.99]
    picks = [G.roulette_pick(rng, pop, fit) for _ in range(2000)]
    assert picks.count((1,)) > 1700


def test_initial_population_unique_when_space_allows():
    rng = np.random.default_rng(0)
    pop = G.initial_population(rng, 16, 12)
    assert len(set(pop)) == 12


# ---------------------------------------------------------------------------
# GA engine
# ---------------------------------------------------------------------------


def _onemax_time(genes):
    """More 1s -> faster. Optimum all-ones."""
    return 10.0 - 9.0 * sum(genes) / len(genes)


def test_ga_fitness_transform():
    assert ga.fitness_of_time(4.0) == pytest.approx(0.5)
    assert ga.fitness_of_time(100.0) == pytest.approx(0.1)


def test_ga_finds_onemax_optimum():
    p = ga.GAParams(population=12, generations=16, seed=0)
    r = ga.run_ga(_onemax_time, 12, p)
    assert sum(r.best_genes) >= 11  # ~optimal


def test_ga_best_time_monotone_nonincreasing():
    p = ga.GAParams(population=8, generations=10, seed=1)
    r = ga.run_ga(_onemax_time, 10, p)
    best = [h.best_time_s for h in r.history]
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(best, best[1:]))


def test_ga_deterministic_per_seed():
    p = ga.GAParams(population=8, generations=8, seed=42)
    r1 = ga.run_ga(_onemax_time, 10, p)
    r2 = ga.run_ga(_onemax_time, 10, p)
    assert r1.best_genes == r2.best_genes
    assert r1.best_time_s == r2.best_time_s


def test_ga_timeout_penalty_applied():
    calls = {}

    def ev(genes):
        calls[genes] = calls.get(genes, 0) + 1
        return 500.0  # above timeout_s=180 -> penalized to 1000

    p = ga.GAParams(population=4, generations=3, seed=0)
    r = ga.run_ga(ev, 6, p)
    assert r.best_time_s == p.penalty_time_s


def test_ga_nonfinite_time_penalized():
    def ev(genes):
        return float("inf")

    p = ga.GAParams(population=4, generations=2, seed=0)
    r = ga.run_ga(ev, 4, p)
    assert r.best_time_s == p.penalty_time_s


def test_ga_cache_reuses_measurements():
    evals = []

    def ev(genes):
        evals.append(genes)
        return _onemax_time(genes)

    p = ga.GAParams(population=10, generations=10, seed=0)
    r = ga.run_ga(ev, 6, p)  # only 64 distinct genomes exist
    assert len(evals) == len(set(evals))  # every evaluation is a new genome
    assert r.cache_hits > 0


def test_ga_params_paper_rule():
    h = ga.GAParams.for_gene_length(13)
    assert (h.population, h.generations) == (10, 10)
    f = ga.GAParams.for_gene_length(65)
    assert (f.population, f.generations) == (30, 20)
    tiny = ga.GAParams.for_gene_length(4)
    assert tiny.population <= 4 and tiny.generations <= 4


def test_ga_paper_constants():
    p = ga.GAParams(population=10, generations=10)
    assert p.crossover_rate == 0.9
    assert p.mutation_rate == 0.05
    assert p.timeout_s == 180.0
    assert p.penalty_time_s == 1000.0
