"""GA + genome invariants (unit + hypothesis property tests)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (dev extra)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ga, genome as G


# ---------------------------------------------------------------------------
# genome operators
# ---------------------------------------------------------------------------


@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_random_genome_shape_and_values(length, seed):
    g = G.random_genome(np.random.default_rng(seed), length)
    assert len(g) == length
    assert set(g) <= {0, 1}


@given(st.integers(2, 64), st.integers(0, 2**31 - 1))
def test_single_point_crossover_preserves_columns(length, seed):
    rng = np.random.default_rng(seed)
    a = G.random_genome(rng, length)
    b = G.random_genome(rng, length)
    ca, cb = G.crossover(rng, a, b, rate=1.0)
    for i in range(length):
        assert {ca[i], cb[i]} == {a[i], b[i]}


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_uniform_crossover_preserves_columns(length, seed):
    rng = np.random.default_rng(seed)
    a = G.random_genome(rng, length)
    b = G.random_genome(rng, length)
    ca, cb = G.uniform_crossover(rng, a, b, rate=1.0)
    for i in range(length):
        assert {ca[i], cb[i]} == {a[i], b[i]}


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_crossover_rate_zero_is_identity(length, seed):
    rng = np.random.default_rng(seed)
    a = G.random_genome(rng, length)
    b = G.random_genome(rng, length)
    assert G.crossover(rng, a, b, rate=0.0) == (a, b)
    assert G.uniform_crossover(rng, a, b, rate=0.0) == (a, b)


@given(st.integers(1, 128), st.integers(0, 2**31 - 1))
def test_mutate_zero_rate_identity_and_one_rate_flips_all(length, seed):
    rng = np.random.default_rng(seed)
    g = G.random_genome(rng, length)
    assert G.mutate(rng, g, 0.0) == g
    flipped = G.mutate(rng, g, 1.0)
    assert all(x != y for x, y in zip(g, flipped))


@given(st.integers(2, 32), st.integers(0, 2**31 - 1))
def test_roulette_pick_returns_member(n, seed):
    rng = np.random.default_rng(seed)
    pop = [G.random_genome(rng, 8) for _ in range(n)]
    fit = list(rng.random(n))
    assert G.roulette_pick(rng, pop, fit) in pop


def test_roulette_prefers_high_fitness():
    rng = np.random.default_rng(0)
    pop = [(0,), (1,)]
    fit = [0.01, 0.99]
    picks = [G.roulette_pick(rng, pop, fit) for _ in range(2000)]
    assert picks.count((1,)) > 1700


def test_initial_population_unique_when_space_allows():
    rng = np.random.default_rng(0)
    pop = G.initial_population(rng, 16, 12)
    assert len(set(pop)) == 12


# ---------------------------------------------------------------------------
# k-ary genome operators (mixed-destination search)
# ---------------------------------------------------------------------------


def _binary_random_genome(rng, length):
    """The pre-k-ary binary operator, verbatim (bit-for-bit reference)."""
    return tuple(int(b) for b in rng.integers(0, 2, size=length))


def _binary_mutate(rng, g, rate):
    """The pre-k-ary binary operator, verbatim (bit-for-bit reference)."""
    flips = rng.random(len(g)) < rate
    return tuple(int(b) ^ int(f) for b, f in zip(g, flips))


@given(st.integers(1, 128), st.integers(2, 9), st.integers(0, 2**31 - 1))
def test_random_genome_kary_allele_validity(length, k, seed):
    g = G.random_genome(np.random.default_rng(seed), length, k)
    assert len(g) == length
    assert all(0 <= x < k for x in g)


@given(st.integers(1, 64), st.integers(2, 9), st.integers(0, 2**31 - 1),
       st.floats(0.0, 1.0))
def test_mutate_kary_preserves_allele_validity(length, k, seed, rate):
    rng = np.random.default_rng(seed)
    g = G.random_genome(rng, length, k)
    m = G.mutate(rng, g, rate, k)
    assert len(m) == length
    assert all(0 <= x < k for x in m)


@given(st.integers(1, 64), st.integers(3, 9), st.integers(0, 2**31 - 1))
def test_mutate_kary_rate_one_never_self_mutates(length, k, seed):
    """A mutated gene must land on one of the k-1 OTHER alleles (the
    k-ary generalization of the binary flip)."""
    rng = np.random.default_rng(seed)
    g = G.random_genome(rng, length, k)
    m = G.mutate(rng, g, 1.0, k)
    assert all(x != y for x, y in zip(g, m))
    assert G.mutate(rng, g, 0.0, k) == g


@given(st.integers(2, 64), st.integers(3, 9), st.integers(0, 2**31 - 1))
def test_crossover_kary_preserves_columns(length, k, seed):
    """Crossover is allele-agnostic: each child column holds one of the
    two parent values, for any alphabet size."""
    rng = np.random.default_rng(seed)
    a = G.random_genome(rng, length, k)
    b = G.random_genome(rng, length, k)
    for op in (G.crossover, G.uniform_crossover):
        ca, cb = op(rng, a, b, rate=1.0)
        for i in range(length):
            assert {ca[i], cb[i]} == {a[i], b[i]}


@given(st.integers(1, 128), st.integers(0, 2**31 - 1),
       st.floats(0.0, 1.0))
def test_k2_operators_bit_identical_to_binary(length, seed, rate):
    """k=2 must reproduce the pre-k-ary binary operators bit-for-bit
    under the same seed — same RNG draws, same outputs — so existing
    searches and persisted fitness caches are unchanged."""
    r_new, r_old = np.random.default_rng(seed), np.random.default_rng(seed)
    g_new = G.random_genome(r_new, length, 2)
    g_old = _binary_random_genome(r_old, length)
    assert g_new == g_old
    assert G.mutate(r_new, g_new, rate, 2) == _binary_mutate(
        r_old, g_old, rate
    )
    # generator states still aligned after both ops
    assert r_new.integers(0, 1 << 30) == r_old.integers(0, 1 << 30)


@given(st.integers(1, 16), st.integers(1, 20), st.integers(0, 2**31 - 1))
def test_initial_population_k2_bit_identical(length, size, seed):
    r_new, r_old = np.random.default_rng(seed), np.random.default_rng(seed)
    pop = G.initial_population(r_new, length, size, 2)
    # reference: the pre-k-ary loop, verbatim
    ref, seen, attempts = [], set(), 0
    while len(ref) < size:
        g = _binary_random_genome(r_old, length)
        attempts += 1
        if g in seen and attempts < 20 * size and length > 1:
            continue
        seen.add(g)
        ref.append(g)
    assert pop == ref


# (plain, non-hypothesis k-ary wiring tests live in test_destinations.py
# so they run even where the hypothesis dev extra is absent)


# ---------------------------------------------------------------------------
# GA engine
# ---------------------------------------------------------------------------


def _onemax_time(genes):
    """More 1s -> faster. Optimum all-ones."""
    return 10.0 - 9.0 * sum(genes) / len(genes)


def test_ga_fitness_transform():
    assert ga.fitness_of_time(4.0) == pytest.approx(0.5)
    assert ga.fitness_of_time(100.0) == pytest.approx(0.1)


def test_ga_finds_onemax_optimum():
    p = ga.GAParams(population=12, generations=16, seed=0)
    r = ga.run_ga(_onemax_time, 12, p)
    assert sum(r.best_genes) >= 11  # ~optimal


def test_ga_best_time_monotone_nonincreasing():
    p = ga.GAParams(population=8, generations=10, seed=1)
    r = ga.run_ga(_onemax_time, 10, p)
    best = [h.best_time_s for h in r.history]
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(best, best[1:]))


def test_ga_deterministic_per_seed():
    p = ga.GAParams(population=8, generations=8, seed=42)
    r1 = ga.run_ga(_onemax_time, 10, p)
    r2 = ga.run_ga(_onemax_time, 10, p)
    assert r1.best_genes == r2.best_genes
    assert r1.best_time_s == r2.best_time_s


def test_ga_timeout_penalty_applied():
    calls = {}

    def ev(genes):
        calls[genes] = calls.get(genes, 0) + 1
        return 500.0  # above timeout_s=180 -> penalized to 1000

    p = ga.GAParams(population=4, generations=3, seed=0)
    r = ga.run_ga(ev, 6, p)
    assert r.best_time_s == p.penalty_time_s


def test_ga_nonfinite_time_penalized():
    def ev(genes):
        return float("inf")

    p = ga.GAParams(population=4, generations=2, seed=0)
    r = ga.run_ga(ev, 4, p)
    assert r.best_time_s == p.penalty_time_s


def test_ga_cache_reuses_measurements():
    evals = []

    def ev(genes):
        evals.append(genes)
        return _onemax_time(genes)

    p = ga.GAParams(population=10, generations=10, seed=0)
    r = ga.run_ga(ev, 6, p)  # only 64 distinct genomes exist
    assert len(evals) == len(set(evals))  # every evaluation is a new genome
    assert r.cache_hits > 0


def test_ga_params_paper_rule():
    h = ga.GAParams.for_gene_length(13)
    assert (h.population, h.generations) == (10, 10)
    f = ga.GAParams.for_gene_length(65)
    assert (f.population, f.generations) == (30, 20)
    tiny = ga.GAParams.for_gene_length(4)
    assert tiny.population <= 4 and tiny.generations <= 4


def test_ga_paper_constants():
    p = ga.GAParams(population=10, generations=10)
    assert p.crossover_rate == 0.9
    assert p.mutation_rate == 0.05
    assert p.timeout_s == 180.0
    assert p.penalty_time_s == 1000.0
