"""Grouped (GShard-layout) MoE dispatch == baseline global dispatch, on a
real multi-device mesh (subprocess with 8 host devices).

When capacity is never exceeded the two paths compute the same function;
the grouped path merely shards it. Loss gradients must also agree.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.core import analysis
from repro.launch.mesh import make_debug_mesh
from repro.models import moe as moe_mod
from repro.models.sharding import MeshCtx

mesh = make_debug_mesh(model=2, data=4)
cfg = get_arch("moonshot-v1-16b-a3b").reduced()
# ample capacity so neither path drops tokens (E=4 reduced, top_k=2)
moe_mod_CAP = moe_mod.CAPACITY_FACTOR
moe_mod.CAPACITY_FACTOR = 4.0

mctx = MeshCtx(mesh)
rng = jax.random.key(0)
params = moe_mod.moe_init(rng, cfg)
B, S, d = 8, 16, cfg.d_model
x = jax.random.normal(jax.random.key(1), (B, S, d), jnp.bfloat16) * 0.3

plan_base = analysis.build_plan(cfg, mesh, optimized=False)
plan_opt = analysis.build_plan(cfg, mesh, optimized=True)
u_base = plan_base.unit("g0/moe")
u_opt = plan_opt.unit("g0/moe")

def run(unit):
    def loss(params, x):
        y, aux = moe_mod.moe_apply(params, x, cfg, mctx, unit)
        return (y.astype(jnp.float32) ** 2).sum(), y
    with mesh:
        xin = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        (l, y), g = jax.jit(
            jax.value_and_grad(loss, has_aux=True)
        )(params, xin)
    return float(l), np.asarray(y, np.float32), jax.tree.map(
        lambda a: np.asarray(a, np.float32), g)

l1, y1, g1 = run(u_base)
l2, y2, g2 = run(u_opt)
moe_mod.CAPACITY_FACTOR = moe_mod_CAP

np.testing.assert_allclose(y1, y2, atol=3e-2, rtol=3e-2)
assert abs(l1 - l2) / max(abs(l1), 1e-6) < 2e-2, (l1, l2)
for (p1, a), (p2, b) in zip(
    jax.tree_util.tree_leaves_with_path(g1),
    jax.tree_util.tree_leaves_with_path(g2),
):
    np.testing.assert_allclose(a, b, atol=5e-2, rtol=5e-2,
                               err_msg=str(p1))
print("GROUPED_EQUIV_OK")
"""


@pytest.mark.slow
def test_grouped_dispatch_matches_global_on_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    # forced host devices only exist on the CPU platform; pinning it also
    # skips the slow TPU-backend probe on containers with libtpu present
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "GROUPED_EQUIV_OK" in out.stdout
