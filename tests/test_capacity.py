"""Capacity-aware residency: per-destination memory limits + eviction.

Covers the PR's hard guarantees:

- with every capacity unset, the N-memory schedule is BYTE-IDENTICAL to
  the pre-capacity implementation (a verbatim copy of it lives below as
  the regression oracle) and the unbounded fingerprints don't move, so
  existing persistent fitness caches stay valid;
- eviction is deterministic furthest-next-use with writeback traffic
  priced through the topology;
- a loop whose working set exceeds its destination's capacity streams
  per execution (never an infinite evict loop);
- capacity exactly equal to the working set evicts nothing;
- the machine-registry knob (``OffloadSpec.hw``) threads capacities
  through the pipeline, and the capacity-aware GA routes around
  thrashing on the constrained machine.
"""
import dataclasses
import itertools
from typing import Dict, Set, Tuple

import numpy as np
import pytest

from repro.core import ga, miniapps
from repro.core.loopir import Loop, LoopClass, LoopProgram, SeqRegion, Var
from repro.core.transfer import dynamic_events
from repro.destinations import (
    MixedEvaluator,
    build_mixed_schedule,
    constrained_registry,
    default_registry,
    get_registry,
    gpu_destination,
    host_destination,
    profiles,
    tpu_host_registry,
)
from repro.destinations.schedule import MixedSchedule

MB = 1 << 20


# ---------------------------------------------------------------------------
# the pre-capacity (PR 3) schedule builder, copied VERBATIM as the
# unbounded-parity oracle: with every capacity unset, the capacity-aware
# implementation must reproduce it byte-for-byte
# ---------------------------------------------------------------------------


def _pr3_build_mixed_schedule(prog, placement, registry) -> MixedSchedule:
    host = registry.host.name
    sched = MixedSchedule()
    valid: Dict[str, Set[str]] = {v.name: {host} for v in prog.vars}
    dirty_dev: Dict[str, str] = {}

    for kind, loop, times in dynamic_events(prog, boundaries=False):
        if kind != "loop":
            continue
        assert loop is not None
        dest = placement[loop.name]
        moved: Dict[Tuple[str, str], float] = {}
        for vn in sorted(loop.reads):
            if dest in valid[vn]:
                continue
            src = host if host in valid[vn] else sorted(valid[vn])[0]
            nbytes = prog.var(vn).nbytes
            for hop in registry.route(src, dest):
                moved[hop] = moved.get(hop, 0.0) + nbytes
                valid[vn].add(hop[1])
        for vn in sorted(loop.writes):
            valid[vn] = {dest}
            if dest == host:
                dirty_dev.pop(vn, None)
            else:
                dirty_dev[vn] = dest
        for pair, b in moved.items():
            sched._add(pair, b * times)
            sched._add_event(pair, times)

    end_moved: Dict[Tuple[str, str], float] = {}
    for vn in sorted(dirty_dev):
        if host in valid[vn]:
            continue
        nbytes = prog.var(vn).nbytes
        for hop in registry.route(dirty_dev[vn], host):
            end_moved[hop] = end_moved.get(hop, 0.0) + nbytes
    for pair, b in end_moved.items():
        sched._add(pair, b)
        sched._add_event(pair, 1.0)
    return sched


@pytest.mark.parametrize("app", ["himeno", "nasft", "hetero"])
def test_unbounded_schedule_parity_with_pr3(app):
    """Every capacity unset: byte-identical per-link totals vs the
    verbatim pre-capacity builder, over random placements."""
    prog = miniapps.MINIAPPS[app]()
    reg = default_registry()
    names = [d.name for d in reg.destinations]
    rng = np.random.default_rng(3)
    for _ in range(25):
        placement = {
            l.name: names[int(g)] if l.offloadable else "cpu"
            for l, g in zip(prog.loops,
                            rng.integers(0, len(names), len(prog.loops)))
        }
        new = build_mixed_schedule(prog, placement, reg)
        old = _pr3_build_mixed_schedule(prog, placement, reg)
        assert new.bytes_by_link == old.bytes_by_link
        assert new.events_by_link == old.events_by_link
        assert new.total_evicted_bytes == 0.0
        assert new.total_spilled_bytes == 0.0
        assert new.seconds(reg) == old.seconds(reg)


def test_unbounded_fingerprints_unchanged():
    """Unbounded profiles fingerprint WITHOUT a capacity term, so the
    persistent fitness caches keyed before this PR stay valid; bounded
    profiles (and registries holding them) fingerprint differently."""
    reg = default_registry()
    assert all("mem=" not in d.fingerprint() for d in reg.destinations)
    con = constrained_registry()
    assert "mem=" in con.get("gpu").fingerprint()
    assert con.fingerprint() != reg.fingerprint()
    gpu = reg.get("gpu")
    bounded = dataclasses.replace(gpu, memory_bytes=1e9)
    assert bounded.fingerprint() != gpu.fingerprint()
    # capacity VALUE is covered too
    assert dataclasses.replace(gpu, memory_bytes=2e9).fingerprint() \
        != bounded.fingerprint()


def test_unbounded_evaluator_parity_search_level():
    """A default-registry mixed search is unaffected by the capacity
    machinery: same fitnesses as the PR-3 oracle on every genome the GA
    visits implies the identical search; spot-check the evaluator."""
    prog = miniapps.hetero_program()
    e = MixedEvaluator(prog, ("cpu", "gpu", "fpga"))
    rng = np.random.default_rng(11)
    for _ in range(20):
        g = tuple(int(x) for x in rng.integers(0, 3, prog.gene_length))
        place = e.placement(g)
        old = _pr3_build_mixed_schedule(prog, place, e.registry)
        assert e.breakdown(g).schedule.bytes_by_link == old.bytes_by_link


# ---------------------------------------------------------------------------
# eviction mechanics on hand-built programs
# ---------------------------------------------------------------------------


def _dev_registry(capacity: float, link_bw: float = 7.694e9
                  ) -> profiles.Registry:
    """host + one bounded gpu-like device, direct links both ways."""
    link = profiles.Link(bw=link_bw, latency=2.0e-5)
    return profiles.Registry(
        name="captest",
        destinations=(
            host_destination(),
            gpu_destination(name="dev", memory_bytes=capacity),
        ),
        links=(("cpu", "dev", link), ("dev", "cpu", link)),
    )


def _prog(loops, vars_, regions=()):
    return LoopProgram("captest", tuple(loops), tuple(vars_),
                       tuple(regions))


def _L(name, reads, writes, parent=None, klass=LoopClass.TIGHT):
    return Loop(name, klass, 8, 8, 2.0, frozenset(reads),
                frozenset(writes), parent_seq=parent)


def test_furthest_next_use_eviction_and_writeback():
    """cap = 2 vars; the victim is the resident var with the furthest
    next use ON that device, and a sole-copy victim is written back."""
    vars_ = [Var("a", MB), Var("b", MB), Var("c", MB)]
    loops = [
        _L("w_a", [], ["a"]),
        _L("w_b", [], ["b"]),
        _L("r_c", ["c"], []),   # overflow: evict a or b
        _L("r_a", ["a"], []),   # a is used sooner than b -> b evicted
    ]
    prog = _prog(loops, vars_)
    reg = _dev_registry(2 * MB)
    sched = build_mixed_schedule(
        prog, {l.name: "dev" for l in loops}, reg
    )
    # b (furthest next use: never again) was evicted, written back (sole
    # copy), and a stayed resident: no re-fetch for a
    assert sched.evict_bytes_by_dest == {"dev": float(MB)}
    assert sched.bytes_by_link[("cpu", "dev")] == float(MB)  # c only
    # b's writeback + end-of-program flush of dirty a
    assert sched.bytes_by_link[("dev", "cpu")] == float(2 * MB)
    assert not sched.oversubscribed

    # flip the last reader to b: now a is the furthest-next-use victim
    loops2 = loops[:3] + [_L("r_b", ["b"], [])]
    prog2 = _prog(loops2, vars_)
    sched2 = build_mixed_schedule(
        prog2, {l.name: "dev" for l in loops2}, reg
    )
    assert sched2.evict_bytes_by_dest == {"dev": float(MB)}
    # a written back on eviction; b never leaves, stays resident; b is
    # still dirty at the end -> flushed once
    assert sched2.bytes_by_link[("dev", "cpu")] == float(2 * MB)


def test_streaming_loops_do_not_pin_residency():
    """Furthest-next-use must ignore future touches by oversubscribed
    (streaming) loops: they stage from the host every execution and
    never read the device copy, so a var whose only upcoming use is a
    streaming loop is the furthest-next-use victim."""
    vars_ = [Var("x", MB), Var("y", MB), Var("mid", MB), Var("big", 3 * MB)]
    loops = [
        _L("w_x", [], ["x"]),
        _L("w_y", [], ["y"]),
        # overflow: one of x/y must go. x's next touch is only the
        # STREAMING loop (working set 4 MB > 2 MB cap); y's is resident.
        _L("r_mid", ["mid"], []),
        _L("stream_x", ["x", "big"], []),
        _L("r_y", ["y"], []),
    ]
    prog = _prog(loops, vars_)
    reg = _dev_registry(2 * MB)
    sched = build_mixed_schedule(prog, {l.name: "dev" for l in loops}, reg)
    assert sched.oversubscribed == ["stream_x"]
    # x was evicted (its device copy is useless to stream_x), y stayed
    # and is never re-fetched: cpu->dev carries mid (1) + stream_x's
    # staged reads (x + big, 4) and nothing else. Counting the streaming
    # touch as a use would evict y instead and re-fetch it (6 MB here).
    assert sched.evict_bytes_by_dest == {"dev": float(MB)}
    assert sched.spill_bytes_by_dest == {"dev": float(4 * MB)}
    assert sched.bytes_by_link[("cpu", "dev")] == float(5 * MB)


def test_exact_fit_capacity_no_eviction():
    """Capacity exactly equal to the live working set: zero evictions,
    and the schedule equals the unbounded one byte-for-byte."""
    vars_ = [Var("x", MB), Var("y", MB)]
    loops = [
        _L("produce", ["x"], ["y"], parent="it"),
        _L("consume", ["y"], ["y"], parent="it"),
    ]
    prog = _prog(loops, vars_, [SeqRegion("it", 4)])
    placement = {l.name: "dev" for l in loops}
    tight = build_mixed_schedule(prog, placement, _dev_registry(2 * MB))
    unbounded = build_mixed_schedule(prog, placement, _dev_registry(0.0))
    assert tight.total_evicted_bytes == 0.0
    assert tight.total_spilled_bytes == 0.0
    assert tight.bytes_by_link == unbounded.bytes_by_link
    assert tight.events_by_link == unbounded.events_by_link


def test_single_tensor_larger_than_capacity_streams():
    """A working set that can never fit streams per execution — host
    fallback semantics, priced, and guaranteed to terminate."""
    vars_ = [Var("big", 8 * MB), Var("out", MB)]
    loops = [_L("huge", ["big"], ["out"], parent="it")]
    prog = _prog(loops, vars_, [SeqRegion("it", 5)])
    reg = _dev_registry(4 * MB)
    sched = build_mixed_schedule(prog, {"huge": "dev"}, reg)
    assert sched.oversubscribed == ["huge"]
    # reads staged in and writes returned on EVERY execution
    assert sched.bytes_by_link[("cpu", "dev")] == float(5 * 8 * MB)
    assert sched.bytes_by_link[("dev", "cpu")] == float(5 * MB)
    assert sched.spill_bytes_by_dest == {"dev": float(5 * 9 * MB)}
    assert sched.total_evicted_bytes == 0.0
    # behind a link narrower than the host's own memory bandwidth, the
    # per-execution streaming prices worse than staying home, and the GA
    # retreats to the host
    narrow = _dev_registry(4 * MB, link_bw=2.0e9)
    e = MixedEvaluator(prog, ("cpu", "dev"), registry=narrow)
    assert e((1,)) > e((0,))
    res = ga.run_ga(e, 1, ga.GAParams(population=4, generations=4,
                                      seed=0, alleles=2))
    assert e.admissible(res.best_genes) == (0,)


def test_thrash_cycle_priced_per_iteration():
    """Two loops alternately overflowing a 1-var device: the eviction
    ping-pong recurs every region iteration and is charged that way."""
    vars_ = [Var("x", MB), Var("y", MB)]
    loops = [
        _L("lx", ["x"], ["x"], parent="it"),
        _L("ly", ["y"], ["y"], parent="it"),
    ]
    prog = _prog(loops, vars_, [SeqRegion("it", 5)])
    reg = _dev_registry(MB)
    placement = {"lx": "dev", "ly": "dev"}
    sched = build_mixed_schedule(prog, placement, reg)
    # first iter: ly evicts x (1). steady iters (x4): lx evicts y, ly
    # evicts x -> 8. total 9 evictions of 1 MB
    assert sched.total_evicted_bytes == float(9 * MB)
    # deterministic: same placement, same schedule
    again = build_mixed_schedule(prog, placement, reg)
    assert again.bytes_by_link == sched.bytes_by_link
    assert again.evict_bytes_by_dest == sched.evict_bytes_by_dest
    # and strictly more expensive than the unbounded model's view
    unb = build_mixed_schedule(prog, placement, _dev_registry(0.0))
    assert sched.seconds(reg) > unb.seconds(reg)


# ---------------------------------------------------------------------------
# machine registries + the spec knob
# ---------------------------------------------------------------------------


def test_get_registry_and_tpu_machine_shape():
    with pytest.raises(ValueError):
        get_registry("nonesuch")
    assert get_registry("quadro-p4000").fingerprint() == \
        default_registry().fingerprint()
    tpu = tpu_host_registry()
    assert tpu.host.kind == "host"
    devs = [d for d in tpu.destinations if d.kind == "tpu"]
    assert len(devs) == 2 and all(d.bounded for d in devs)
    # no direct device-device link: staged through the host
    assert tpu.route("tpu0", "tpu1") == (("tpu0", "cpu"), ("cpu", "tpu1"))


def test_constrained_machine_changes_winning_placement():
    """The PR's acceptance search: on the constrained machine the GA
    must beat what the unbounded winner actually achieves there, with a
    different placement and without the unbounded plan's streaming."""
    from repro.offload import Offloader, OffloadSpec

    prog = miniapps.hetero_program()
    con_eval = MixedEvaluator(prog, ("cpu", "gpu", "fpga"),
                              registry=constrained_registry())
    # the unbounded search's winner (cold 24x24 seed 0, cf. PR-2/3
    # figures): stencil pipeline on the GPU
    g_unb = (1, 0, 1, 1, 1, 2, 2, 2, 2, 2, 2, 0)
    repriced = con_eval(g_unb)
    bd_unb = con_eval.breakdown(g_unb).schedule
    assert bd_unb.total_spilled_bytes > 0  # stencils stream on the 45 MB card

    spec = OffloadSpec(program="hetero", mode="mixed",
                       hw="p4000-constrained", warm_start=True,
                       population=24, generations=24)
    res = Offloader(spec).run(until="search")
    assert res.best_time_s < repriced
    assert tuple(res.best_genes) != g_unb
    r = res.stage("search").payload["residency"]
    assert r["capacities"] == {
        "gpu": profiles.CONSTRAINED_GPU_BYTES,
        "fpga": profiles.CONSTRAINED_FPGA_BYTES,
    }
    assert r["spilled_bytes"] == 0.0  # routed around the thrash
    # the machine name is frozen in the spec -> artifact identity
    assert res.stage("analyze").payload["machine"] == "p4000-constrained"


def test_unknown_machine_name_rejected():
    from repro.offload import Offloader, OffloadSpec

    spec = OffloadSpec(program="hetero", mode="mixed", hw="nonesuch")
    with pytest.raises(ValueError, match="unknown machine"):
        Offloader(spec).adapter


def test_destination_registry_mismatch_is_a_spec_error():
    """hw="tpu-v5e-host" with the default (cpu,gpu,fpga) destinations
    must fail with a ValueError naming the machine's destinations, not
    a KeyError from deep inside the evaluator."""
    from repro.offload import Offloader, OffloadSpec

    spec = OffloadSpec(program="hetero", mode="mixed", hw="tpu-v5e-host")
    with pytest.raises(ValueError, match="tpu0"):
        Offloader(spec).adapter


def test_eviction_repoints_dirty_owner_over_direct_device_link():
    """A no-writeback eviction (another device still holds the copy via
    a direct device-device link, no host copy) must repoint the dirty
    owner so the end flush routes from a device that still has it."""
    link = profiles.Link(bw=7.694e9, latency=2.0e-5)
    fast = profiles.Link(bw=3.0e10, latency=1.0e-6)
    reg = profiles.Registry(
        name="dd-link",
        destinations=(
            host_destination(),
            gpu_destination(name="d1", memory_bytes=2 * MB),
            gpu_destination(name="d2"),
        ),
        links=(
            ("cpu", "d1", link), ("d1", "cpu", link),
            ("cpu", "d2", link), ("d2", "cpu", link),
            ("d1", "d2", fast),  # direct: no host staging
        ),
    )
    vars_ = [Var("v", MB), Var("a", MB), Var("b", MB)]
    loops = [
        _L("w_v", [], ["v"]),          # d1 writes v: dirty at d1
        _L("r_v", ["v"], [], ),        # d2 reads v over the direct link
        _L("w_a", [], ["a"]),          # d1 fills up...
        _L("w_b", [], ["b"]),          # ...and evicts v (no writeback:
    ]                                  # d2 still holds it)
    prog = _prog(loops, vars_)
    placement = {"w_v": "d1", "r_v": "d2", "w_a": "d1", "w_b": "d1"}
    sched = build_mixed_schedule(prog, placement, reg)
    # v was dropped from d1 without a writeback...
    assert sched.evict_bytes_by_dest == {"d1": float(MB)}
    # ...and the end flush brings v home from d2 (the surviving owner),
    # alongside d1's dirty a and b
    assert sched.bytes_by_link.get(("d2", "cpu")) == float(MB)
    assert sched.bytes_by_link.get(("d1", "cpu")) == float(2 * MB)


def test_report_states_eviction_bytes():
    """Offload report: the tpu machine's winner accepts bounded thrash
    and the report stage must state the eviction traffic."""
    from repro.offload import Offloader, OffloadSpec
    from repro.offload.pipeline import render_report

    spec = OffloadSpec(program="hetero", mode="mixed", hw="tpu-v5e-host",
                       destinations=("cpu", "tpu0", "tpu1"),
                       population=10, generations=8, warm_start=True)
    res = Offloader(spec).run(until="report")
    r = res.stage("search").payload["residency"]
    assert r["evicted_bytes"] > 0
    text = res.stage("report").payload["text"]
    assert "evicted" in text and "capacities" in text
    assert f"{r['evicted_bytes']/1e6:.1f} MB" in text
