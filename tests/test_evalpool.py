"""Evaluation pool: dedup, persistent cache round-trip, timeout penalty,
pool-size GA equivalence, and the pooled wall-clock win."""
import threading
import time

import pytest

from repro.core import evalpool as ep
from repro.core import evaluator as ev
from repro.core import ga, miniapps
from repro.core import transfer as tr


def _onemax_time(genes):
    return 10.0 - 9.0 * sum(genes) / len(genes)


# ---------------------------------------------------------------------------
# dedup + cache accounting
# ---------------------------------------------------------------------------


def test_dedup_within_generation():
    calls = []

    def evaluate(genes):
        calls.append(genes)
        return _onemax_time(genes)

    pool = ep.EvalPool(evaluate)
    pop = [(0, 1), (1, 1), (0, 1), (1, 1), (0, 1)]  # 2 unique of 5
    times, tel = pool.evaluate_generation(pop, 180.0, 1000.0)
    assert len(calls) == 2
    assert tel.submitted == 5 and tel.unique == 2
    assert tel.evaluated == 2 and tel.cache_hits == 3
    assert tel.dedup_ratio == pytest.approx(0.6)
    # results in population order, duplicates identical
    assert times[0] == times[2] == times[4]
    assert times[1] == times[3]


def test_cross_generation_cache_serves_repeats():
    calls = []

    def evaluate(genes):
        calls.append(genes)
        return _onemax_time(genes)

    pool = ep.EvalPool(evaluate)
    pool.evaluate_generation([(0, 0), (1, 1)], 180.0, 1000.0)
    _, tel = pool.evaluate_generation([(0, 0), (1, 0)], 180.0, 1000.0)
    assert len(calls) == 3  # (0,0) served from cache
    assert tel.cache_hits == 1 and tel.evaluated == 1


# ---------------------------------------------------------------------------
# persistent cache: round-trip across a simulated restart
# ---------------------------------------------------------------------------


def test_cache_roundtrip_across_restart(tmp_path):
    path = str(tmp_path / "fitness.jsonl")
    prog = miniapps.himeno_program()
    e = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)
    params = ga.GAParams(population=8, generations=4, seed=3)

    cache1 = ep.FitnessCache(path, fingerprint=e.fingerprint())
    with ep.EvalPool(e, cache=cache1) as pool1:
        r1 = ga.run_ga(None, prog.gene_length, params, pool=pool1)
    assert r1.evaluations > 0

    # "restart": new cache object replays the JSONL file
    cache2 = ep.FitnessCache(path, fingerprint=e.fingerprint())
    assert cache2.loaded == r1.evaluations
    with ep.EvalPool(e, cache=cache2) as pool2:
        r2 = ga.run_ga(None, prog.gene_length, params, pool=pool2)
    assert r2.evaluations == 0  # everything served from disk
    assert r2.best_genes == r1.best_genes
    assert r2.best_time_s == r1.best_time_s


def test_cached_hit_revalidated_against_current_timeout(tmp_path):
    path = str(tmp_path / "fitness.jsonl")
    c1 = ep.FitnessCache(path, fingerprint="fp")
    c1.put((0, 1), 500.0)  # measured under a permissive timeout
    c1.close()
    c2 = ep.FitnessCache(path, fingerprint="fp")
    with ep.EvalPool(lambda g: 1.0, cache=c2) as pool:
        times, tel = pool.evaluate_generation(
            [(0, 1)], timeout_s=180.0, penalty_time_s=1000.0
        )
    assert times == [1000.0]  # stale 500s hit scores as penalty now
    assert tel.cache_hits == 1 and tel.evaluated == 0


def test_cache_fingerprint_isolation(tmp_path):
    path = str(tmp_path / "fitness.jsonl")
    c1 = ep.FitnessCache(path, fingerprint="cfg-a")
    c1.put((1, 0, 1), 2.5)
    c1.close()
    # same file, different evaluator configuration: entry must not leak
    c2 = ep.FitnessCache(path, fingerprint="cfg-b")
    assert c2.get((1, 0, 1)) is None
    c3 = ep.FitnessCache(path, fingerprint="cfg-a")
    assert c3.get((1, 0, 1)) == 2.5


def test_penalized_records_not_replayed_on_resume(tmp_path):
    path = str(tmp_path / "fitness.jsonl")
    calls = []

    def flaky(genes):
        calls.append(genes)
        if len(calls) == 1:
            return 500.0  # transient overtime on the very first measurement
        return 1.0

    cache1 = ep.FitnessCache(path, fingerprint="fp")
    with ep.EvalPool(flaky, cache=cache1) as pool:
        times, _ = pool.evaluate_generation(
            [(0,), (1,)], timeout_s=180.0, penalty_time_s=1000.0
        )
    assert times == [1000.0, 1.0]

    # restart: the good measurement is replayed, the penalty is not
    cache2 = ep.FitnessCache(path, fingerprint="fp")
    assert cache2.get((1,)) == 1.0
    assert cache2.get((0,)) is None
    with ep.EvalPool(flaky, cache=cache2) as pool:
        times, tel = pool.evaluate_generation(
            [(0,), (1,)], timeout_s=180.0, penalty_time_s=1000.0
        )
    assert times == [1.0, 1.0]  # re-measured clean this time
    assert tel.cache_hits == 1 and tel.evaluated == 1


def test_pool_close_leaves_caller_cache_open(tmp_path):
    """A caller-owned cache survives its pool: it may be serving other
    pools (cross-subset sharing), so only pool-built caches close with
    the pool."""
    path = str(tmp_path / "fitness.jsonl")
    cache = ep.FitnessCache(path, fingerprint="fp")
    with ep.EvalPool(lambda g: 1.0, cache=cache) as pool:
        pool.evaluate_generation([(0,)], 180.0, 1000.0)
    # still open: a second pool over the same cache keeps persisting
    with ep.EvalPool(lambda g: 2.0, cache=cache) as pool:
        pool.evaluate_generation([(1,)], 180.0, 1000.0)
    cache.close()
    replay = ep.FitnessCache(path, fingerprint="fp")
    assert replay.get((0,)) == 1.0 and replay.get((1,)) == 2.0


def test_cache_tolerates_corrupt_trailing_line(tmp_path):
    path = str(tmp_path / "fitness.jsonl")
    c1 = ep.FitnessCache(path, fingerprint="fp")
    c1.put((0, 1), 1.25)
    c1.put((1, 1), 0.75)
    c1.close()
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"v": 1, "fp": "fp", "genes": "10", "t": 3.')  # killed write
    c2 = ep.FitnessCache(path, fingerprint="fp")
    assert len(c2) == 2
    assert c2.get((0, 1)) == 1.25


# ---------------------------------------------------------------------------
# timeout -> penalty propagation
# ---------------------------------------------------------------------------


def test_overtime_measurement_penalized_in_pool():
    def evaluate(genes):
        return 500.0  # above timeout_s=180

    with ep.EvalPool(evaluate, workers=2) as pool:
        times, tel = pool.evaluate_generation(
            [(0,), (1,)], timeout_s=180.0, penalty_time_s=1000.0
        )
    assert times == [1000.0, 1000.0]
    assert tel.timeouts == 2


def test_hung_measurement_penalized_at_deadline():
    done = threading.Event()

    def evaluate(genes):
        if genes == (1,):
            done.wait(5.0)  # hangs well past the timeout
        return 0.01

    with ep.EvalPool(evaluate, workers=2) as pool:
        t0 = time.monotonic()
        times, tel = pool.evaluate_generation(
            [(0,), (1,)], timeout_s=0.3, penalty_time_s=1000.0
        )
        wall = time.monotonic() - t0
    done.set()
    assert times[0] == 0.01
    assert times[1] == 1000.0
    assert tel.timeouts == 1
    assert wall < 4.0  # scored at the deadline, not at straggler finish


def test_queued_individuals_requeued_not_penalized_after_hang():
    done = threading.Event()

    def evaluate(genes):
        if genes in ((0,), (1,)):
            done.wait(10.0)  # occupies both workers past the deadline
        return 0.01

    # workers=2: (0,) and (1,) hang, so (2,) and (3,) never start before
    # the deadline; they must be re-measured on a fresh executor, not
    # penalized unmeasured
    with ep.EvalPool(evaluate, workers=2) as pool:
        times, tel = pool.evaluate_generation(
            [(0,), (1,), (2,), (3,)], timeout_s=0.2, penalty_time_s=1000.0
        )
    done.set()
    assert times[0] == 1000.0 and times[1] == 1000.0
    assert times[2] == 0.01 and times[3] == 0.01
    assert tel.timeouts == 2


def test_crashing_measurement_penalized():
    def evaluate(genes):
        if sum(genes) == 0:
            raise RuntimeError("compile error analogue")
        return 1.0

    for workers in (1, 3):
        with ep.EvalPool(evaluate, workers=workers) as pool:
            times, tel = pool.evaluate_generation(
                [(0, 0), (1, 0)], timeout_s=180.0, penalty_time_s=1000.0
            )
        assert times == [1000.0, 1.0]


def test_ga_timeout_penalty_through_pool():
    def evaluate(genes):
        return float("inf")

    p = ga.GAParams(population=4, generations=2, seed=0)
    with ep.EvalPool(evaluate, workers=2) as pool:
        r = ga.run_ga(None, 4, p, pool=pool)
    assert r.best_time_s == p.penalty_time_s


# ---------------------------------------------------------------------------
# GA equivalence: same seed => same best individual, pool size 1 vs N
# ---------------------------------------------------------------------------


def test_ga_pool_size_equivalence_miniapp():
    prog = miniapps.himeno_program()
    e = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)
    params = ga.GAParams(population=16, generations=10, seed=0)

    serial = ga.run_ga(e, prog.gene_length, params)
    with ep.EvalPool(e, workers=4) as pool:
        pooled = ga.run_ga(None, prog.gene_length, params, pool=pool)

    assert pooled.best_genes == serial.best_genes
    assert pooled.best_time_s == serial.best_time_s
    assert [h.best_time_s for h in pooled.history] == \
        [h.best_time_s for h in serial.history]
    # the pooled cache must do at least as well as the in-memory serial one
    assert pooled.cache_hits >= serial.cache_hits


def test_batched_evaluator_path_used():
    class Batched:
        def __init__(self):
            self.batch_calls = 0
            self.point_calls = 0

        def __call__(self, genes):
            self.point_calls += 1
            return _onemax_time(genes)

        def evaluate_batch(self, genes_list):
            self.batch_calls += 1
            return [_onemax_time(g) for g in genes_list]

    e = Batched()
    with ep.EvalPool(e) as pool:
        times, tel = pool.evaluate_generation(
            [(0, 1), (1, 1), (0, 1)], 180.0, 1000.0
        )
    assert e.batch_calls == 1 and e.point_calls == 0
    assert tel.evaluated == 2


# ---------------------------------------------------------------------------
# process-pool path: MeasuredEvaluator on real miniapp runs (ROADMAP item)
# ---------------------------------------------------------------------------


def test_measured_evaluator_through_process_pool():
    """The paper's real measurement loop, parallelized: a picklable
    module-level run_fn (miniapps.HimenoRunFn) wall-clocked by
    MeasuredEvaluator inside EvalPool(executor="process") workers. The
    pool spawns (not forks) so the parent's JAX/XLA state can't deadlock
    the children."""
    run_fn = miniapps.HimenoRunFn(grid=(9, 9, 17), nn=2)
    e = ev.MeasuredEvaluator(run_fn, tag=run_fn.tag)
    assert "himeno" in ep.evaluator_fingerprint(e)

    prog = miniapps.himeno_program()
    n = prog.gene_length
    off = (0,) * n
    on = tuple(1 for _ in range(n))
    with ep.EvalPool(e, workers=2, executor="process") as pool:
        times, tel = pool.evaluate_generation(
            [off, on, off], timeout_s=300.0, penalty_time_s=1000.0
        )
    assert tel.evaluated == 2 and tel.cache_hits == 1
    assert tel.timeouts == 0
    assert all(0.0 < t < 300.0 for t in times)
    assert times[0] == times[2]


def test_run_fns_are_picklable():
    import pickle

    for fn in (miniapps.HimenoRunFn(), miniapps.NasftRunFn()):
        clone = pickle.loads(pickle.dumps(ev.MeasuredEvaluator(fn,
                                                               tag=fn.tag)))
        assert clone.tag == fn.tag


# ---------------------------------------------------------------------------
# wall-clock: >= 3x per-generation improvement at pool size 4
# ---------------------------------------------------------------------------


def test_pooled_generation_wall_clock_speedup():
    delay = 0.05

    def slow_eval(genes):
        time.sleep(delay)
        return _onemax_time(genes)

    pop = [tuple(int(b) for b in format(i, "04b")) for i in range(12)]

    with ep.EvalPool(slow_eval, workers=1) as pool:
        _, tel1 = pool.evaluate_generation(pop, 180.0, 1000.0)
    with ep.EvalPool(slow_eval, workers=4) as pool:
        _, tel4 = pool.evaluate_generation(pop, 180.0, 1000.0)

    assert tel1.evaluated == tel4.evaluated == 12
    assert tel1.wall_s / tel4.wall_s >= 3.0


# ---------------------------------------------------------------------------
# multi-owner store: the serving layer shares ONE file across pools
# ---------------------------------------------------------------------------


def test_two_caches_two_threads_hammer_one_store(tmp_path):
    """Regression for the multi-owner hazard: two cache objects (as two
    concurrent service jobs would hold) appending to one store must
    never tear a line or lose a record — O_APPEND + flock + one write
    per record."""
    path = str(tmp_path / "fitness.jsonl")
    caches = [ep.FitnessCache(path, fingerprint=f"fp-{i}")
              for i in range(2)]
    n = 200

    def hammer(idx):
        for j in range(n):
            caches[idx].put((idx, j), float(j) + 0.5)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for c in caches:
        c.close()
    with open(path, encoding="utf-8") as fh:
        lines = fh.readlines()
    assert len(lines) == 2 * n
    import json as _json

    for line in lines:
        assert line.endswith("\n"), "torn (unterminated) record"
        _json.loads(line)
    for i in range(2):
        replay = ep.FitnessCache(path, fingerprint=f"fp-{i}")
        assert len(replay) == n
        assert replay.get((i, n - 1)) == float(n - 1) + 0.5
        replay.close()


def test_cache_refcount_and_idempotent_close(tmp_path):
    path = str(tmp_path / "fitness.jsonl")
    cache = ep.FitnessCache(path, fingerprint="fp")
    assert cache.retain() is cache
    cache.close()  # releases the retain(); construction ref remains
    cache.put((0,), 1.0)  # descriptor must still be open
    cache.close()
    assert cache._fd is None
    cache.close()  # double-close is a no-op, never an OSError
    cache.close()
    replay = ep.FitnessCache(path, fingerprint="fp")
    assert replay.get((0,)) == 1.0


def test_broker_shares_views_per_fingerprint(tmp_path):
    path = str(tmp_path / "fitness.jsonl")
    with ep.EvalBroker(path) as broker:
        a = broker.open_cache("fp-x")
        b = broker.open_cache("fp-x")
        other = broker.open_cache("fp-y")
        assert a is b and a is not other
        # a measurement one job pays is the sibling's hit IMMEDIATELY —
        # in memory, not only after a file re-read
        a.put((1, 0), 3.25)
        assert b.get((1, 0)) == 3.25
        assert other.get((1, 0)) is None  # fingerprints stay isolated
        assert broker.stats() == {"fp-x": 1, "fp-y": 0}
        # a stage closing "its" cache releases one reference only:
        # the shared view stays usable for the sibling and the broker
        b.close()
        a.put((1, 1), 4.5)
        other.close()
    # broker.close() released ITS references; `a` is still retained by
    # this caller (two open_cache calls, one close so far)
    assert other._fd is None and a._fd is not None
    a.close()
    assert a._fd is None
    replay = ep.FitnessCache(path, fingerprint="fp-x")
    assert len(replay) == 2


def test_broker_view_held_by_stage_survives_broker_close(tmp_path):
    # an in-flight stage's retained view outlives broker.close(): the
    # descriptor closes only when the LAST owner releases
    path = str(tmp_path / "fitness.jsonl")
    broker = ep.EvalBroker(path)
    view = broker.open_cache("fp")
    broker.close()
    view.put((7,), 7.0)  # still open: the stage holds a reference
    view.close()
    assert view._fd is None


# ---------------------------------------------------------------------------
# steady-state sessions: continuous evaluation over shared stores
# ---------------------------------------------------------------------------


class _FpOnemax:
    """onemax with the fingerprint the persistent cache demands."""

    def __call__(self, genes):
        return _onemax_time(genes)

    def fingerprint(self):
        return "steady-onemax"


def test_steady_session_dedup_joins_inflight_measurement():
    calls = []
    started = threading.Event()

    def evaluate(genes):
        calls.append(genes)
        started.set()
        time.sleep(0.05)
        return _onemax_time(genes)

    with ep.EvalPool(evaluate, workers=2) as pool:
        with pool.steady_session(180.0, 1000.0) as ses:
            ses.submit((0, 1))
            started.wait(timeout=5.0)
            ses.submit((0, 1))  # identical genome mid-measurement
            r1 = ses.collect()
            r2 = ses.collect()
            tel = ses.cut()
    assert len(calls) == 1  # the duplicate joined, never re-measured
    assert r1[1] == r2[1] == _onemax_time((0, 1))
    assert tel.submitted == 2 and tel.unique == 1
    assert tel.evaluated == 1 and tel.cache_hits == 1


def test_steady_session_timeout_scores_penalty_once():
    release = threading.Event()

    def evaluate(genes):
        release.wait(timeout=5.0)  # hangs past the session deadline
        return 1.0

    with ep.EvalPool(evaluate, workers=2) as pool:
        with pool.steady_session(0.05, 1000.0) as ses:
            ses.submit((1, 0))
            genes, t = ses.collect()
            assert genes == (1, 0) and t == 1000.0
            release.set()  # the straggler finishes late...
            time.sleep(0.1)
            tel = ses.cut()
    # ...and its late result was discarded: one timeout, no extra
    # result, nothing double-counted
    assert tel.timeouts == 1 and tel.evaluated == 1
    assert tel.submitted == 1


def test_steady_session_collect_without_work_raises():
    with ep.EvalPool(_FpOnemax()) as pool:
        with pool.steady_session(180.0, 1000.0) as ses:
            with pytest.raises(RuntimeError, match="no submission"):
                ses.collect()


def test_steady_sessions_hammer_one_broker_store(tmp_path):
    """Eight steady sessions (eight threads, one shared EvalBroker view)
    hammering one JSONL store: no torn lines, per-session telemetry adds
    up exactly, and the store replays to the distinct key set."""
    path = str(tmp_path / "fitness.jsonl")
    n_threads, n_each = 8, 60
    import random

    with ep.EvalBroker(path) as broker:
        view = broker.open_cache("steady-onemax")
        tels = [None] * n_threads
        errors = []

        def hammer(idx):
            try:
                rng = random.Random(idx)
                with ep.EvalPool(_FpOnemax(), cache=view) as pool:
                    with pool.steady_session(180.0, 1000.0) as ses:
                        for _ in range(n_each):
                            # a small genome space forces cross-session
                            # collisions: simultaneous misses, hits on
                            # another session's fresh measurement
                            ses.submit((rng.randint(0, 1),
                                        rng.randint(0, 1),
                                        rng.randint(0, 1)))
                            ses.collect()
                        tels[idx] = ses.cut()
            except Exception as e:  # pragma: no cover - failure detail
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        view.close()
    assert not errors
    # per-session accounting: every submission resolved exactly once
    for tel in tels:
        assert tel is not None
        assert tel.submitted == n_each
        assert tel.evaluated + tel.cache_hits == tel.submitted
        assert tel.timeouts == 0
    total_evaluated = sum(t.evaluated for t in tels)
    import json as _json

    with open(path, encoding="utf-8") as fh:
        lines = fh.readlines()
    # one whole line per fresh measurement — atomic appends, no tearing
    assert len(lines) == total_evaluated
    keys = set()
    for line in lines:
        assert line.endswith("\n"), "torn (unterminated) record"
        keys.add(_json.loads(line)["genes"])
    replay = ep.FitnessCache(path, fingerprint="steady-onemax")
    assert len(replay) == len(keys)
    replay.close()


def test_evaluator_fingerprints_distinguish_configs():
    prog = miniapps.himeno_program()
    a = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)
    b = ev.MiniappEvaluator(prog, tr.TransferMode.NEST, staged=False,
                            kernels_only=True)
    assert a.fingerprint() != b.fingerprint()
    assert ep.evaluator_fingerprint(a) == a.fingerprint()
    # a fingerprint-less callable is refused outright: keying the
    # persistent cache on a bare name would let two differently-
    # configured instances share measurements
    with pytest.raises(TypeError, match="fingerprint"):
        ep.evaluator_fingerprint(_onemax_time)
