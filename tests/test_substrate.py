"""Substrate tests: checkpointing, fault tolerance, data pipeline, plans."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import TRAIN_4K, ShapeConfig
from repro.core import analysis
from repro.core.plan import Directive, ExecutionPlan, UnitPlan
from repro.data.pipeline import DataConfig, Pipeline, SyntheticLM
from repro.runtime import fault
from repro.runtime.monitor import Monitor


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"mu": jnp.ones((8, 4)), "count": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(10, state, metadata={"loss": 1.5})
    restored = ck.restore(10, jax.tree.map(jnp.zeros_like, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ck.metadata(10)["loss"] == 1.5


def test_checkpoint_async_equivalent(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state(1)
    ck.save_async(20, state)
    res = ck.wait()
    assert res.step == 20
    restored = ck.restore(20, jax.tree.map(jnp.zeros_like, state))
    np.testing.assert_array_equal(
        np.asarray(state["params"]["w"]), np.asarray(restored["params"]["w"])
    )


def test_checkpoint_async_snapshot_isolated_from_mutation(tmp_path):
    """The async save must capture values at call time, not at write time."""
    ck = Checkpointer(str(tmp_path))
    state = {"x": jnp.ones((4,))}
    ck.save_async(1, state)
    state["x"] = state["x"] * 100  # mutate the pytree afterwards
    ck.wait()
    restored = ck.restore(1, {"x": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(4))


def test_checkpoint_dtype_and_shape_validation(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ck.restore(1, {"x": jnp.zeros((5,))})
    with pytest.raises(KeyError):
        ck.restore(1, {"y": jnp.zeros((4,))})


def test_manager_retention_and_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=10, keep_last=2,
                            keep_every=40, async_save=False)
    state = {"x": jnp.zeros((2,))}
    for step in (10, 20, 30, 40, 50):
        mgr.save(step, {"x": jnp.full((2,), float(step))})
    steps = mgr.ckpt.steps()
    assert 40 in steps and 50 in steps  # keep_last=2 + keep_every=40
    assert 10 not in steps and 20 not in steps
    restored_step, restored = mgr.restore_latest(state)
    assert restored_step == 50
    np.testing.assert_array_equal(np.asarray(restored["x"]), [50.0, 50.0])


def test_manager_skips_corrupt_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=10, async_save=False)
    state = {"x": jnp.zeros((2,))}
    mgr.save(10, {"x": jnp.ones((2,))})
    mgr.save(20, {"x": jnp.full((2,), 2.0)})
    # corrupt the latest
    idx = os.path.join(str(tmp_path), "step_00000020", "index.json")
    with open(idx, "w") as f:
        f.write("{broken")
    restored_step, restored = mgr.restore_latest(state)
    assert restored_step == 10
    np.testing.assert_array_equal(np.asarray(restored["x"]), [1.0, 1.0])


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_heartbeat_eviction_after_two_misses():
    reg = fault.HeartbeatRegistry(3, deadline_s=1.0, max_missed=2)
    t = 100.0
    for h in range(3):
        reg.beat(h, t)
    assert reg.sweep(t + 0.5) == []
    reg.beat(0, t + 1.2)
    reg.beat(1, t + 1.2)
    assert reg.sweep(t + 1.5) == []  # host 2 suspect (1 miss)
    assert reg.hosts[2].state == fault.HostState.SUSPECT
    evicted = reg.sweep(t + 3.0)
    assert evicted == [2]
    assert reg.survivors() == [0, 1]


def test_heartbeat_suspect_recovers():
    reg = fault.HeartbeatRegistry(2, deadline_s=1.0, max_missed=2)
    reg.beat(0, 0.0)
    reg.beat(1, 0.0)
    reg.sweep(1.5)  # both suspect
    reg.beat(1, 1.6)
    assert reg.hosts[1].state == fault.HostState.HEALTHY
    assert reg.hosts[1].missed == 0


def test_evicted_host_needs_admit():
    reg = fault.HeartbeatRegistry(1, deadline_s=1.0, max_missed=1)
    reg.beat(0, 0.0)
    reg.sweep(10.0)
    assert reg.survivors() == []
    reg.beat(0, 11.0)  # beats from evicted hosts ignored
    assert reg.survivors() == []
    reg.admit(0, 12.0)
    assert reg.survivors() == [0]


def test_straggler_detection_ewma():
    det = fault.StragglerDetector(4, z_threshold=1.5, patience=2)
    for _ in range(6):
        verdicts = det.observe([1.0, 1.0, 1.0, 3.0])
    assert verdicts[3].is_straggler
    assert not any(v.is_straggler for v in verdicts[:3])


def test_skip_and_rescale():
    assert fault.skip_and_rescale(8, 2) == pytest.approx(8 / 6)
    with pytest.raises(ValueError):
        fault.skip_and_rescale(4, 4)


def test_elastic_mesh_plan():
    p = fault.plan_elastic_mesh(512, 16)
    assert p.shape == (32, 16)
    p2 = fault.plan_elastic_mesh(500, 16)  # 12 devices idle
    assert p2.shape == (31, 16)
    assert p2.n_devices == 496
    with pytest.raises(ValueError):
        fault.plan_elastic_mesh(8, 16)


def test_fault_coordinator_recovery_event():
    fc = fault.FaultCoordinator(n_hosts=4, devices_per_host=4,
                                model_parallel=4)
    assert fc.current_plan().shape == (4, 4)
    ev = fc.on_step(1, {0: 0.1, 1: 0.1, 2: 0.1, 3: 0.1})
    assert ev is None
    fc.fail_host(3)
    ev = fc.on_step(2, {0: 0.1, 1: 0.1, 2: 0.1})
    assert ev is not None
    assert 3 in ev.evicted_hosts
    assert fc.current_plan().shape == (3, 4)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_deterministic_per_step_host():
    src = SyntheticLM(100, seed=5)
    a = src.batch(3, 0, (4, 16))
    b = src.batch(3, 0, (4, 16))
    c = src.batch(4, 0, (4, 16))
    d = src.batch(3, 1, (4, 16))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert not np.array_equal(a, d)


def test_pipeline_resume_replays_same_stream():
    cfg = get_arch("stablelm-3b").reduced()
    import dataclasses
    shape = dataclasses.replace(TRAIN_4K, seq_len=16, global_batch=4)
    p1 = Pipeline(cfg, shape, DataConfig(seed=9), start_step=5)
    p2 = Pipeline(cfg, shape, DataConfig(seed=9), start_step=5)
    b1 = next(iter(p1))
    b2 = next(iter(p2))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_prefetch_matches_sync():
    cfg = get_arch("stablelm-3b").reduced()
    import dataclasses
    shape = dataclasses.replace(TRAIN_4K, seq_len=16, global_batch=4)
    sync = Pipeline(cfg, shape, DataConfig(seed=3))
    pre = Pipeline(cfg, shape, DataConfig(seed=3)).start()
    it_s, it_p = iter(sync), iter(pre)
    try:
        for _ in range(3):
            bs, bp = next(it_s), next(it_p)
            np.testing.assert_array_equal(bs["tokens"], bp["tokens"])
    finally:
        pre.stop()


def test_pipeline_host_sharding_splits_batch():
    cfg = get_arch("stablelm-3b").reduced()
    import dataclasses
    shape = dataclasses.replace(TRAIN_4K, seq_len=16, global_batch=8)
    p = Pipeline(cfg, shape, DataConfig(seed=3), host_index=1, n_hosts=4)
    b = next(iter(p))
    assert b["tokens"].shape[0] == 2


def test_synthetic_has_learnable_structure():
    src = SyntheticLM(50, seed=1)
    toks = src.batch(0, 0, (64, 32))
    nxt_pred = (5 * toks[:, :-1] + 7) % 50
    agree = (toks[:, 1:] == nxt_pred).mean()
    assert agree > 0.4  # planted bigram signal present


# ---------------------------------------------------------------------------
# plans + analysis (directive assignment)
# ---------------------------------------------------------------------------


def test_plan_genes_roundtrip():
    plan = analysis.build_plan(get_arch("stablelm-3b"), None)
    genes = plan.genes()
    flipped = tuple(1 - g for g in genes)
    plan2 = plan.with_genes(flipped)
    assert plan2.genes() == flipped
    assert plan.genes() == genes  # frozen


def test_plan_rejects_duplicate_units():
    u = UnitPlan("a", Directive.KERNELS)
    with pytest.raises(ValueError):
        ExecutionPlan(units=(u, u))


def test_previous_method_plan_offloads_only_kernels_units():
    plan = analysis.previous_method_plan(get_arch("gemma2-27b"), None)
    for unit in plan.units:
        if unit.directive != Directive.KERNELS:
            assert not unit.offload
        assert not unit.bulk_gather and not unit.keep_sharded
        assert not unit.staged


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_build_units_covers_model_groups(arch_id):
    cfg = get_arch(arch_id)
    units = analysis.build_units(cfg, None)
    names = {u.name for u in units}
    if cfg.family in ("ssm", "hybrid"):
        assert any(n.endswith("/ssd") for n in names)
    if cfg.moe is not None:
        assert any(n.endswith("/moe") for n in names)
    if cfg.family != "encoder":
        assert "embed" in names
    assert "unembed" in names


def test_applicability_notes_mention_family_constraints():
    notes_ssm = analysis.applicability_notes(get_arch("mamba2-1.3b"))
    assert any("attention-free" in n for n in notes_ssm)
    notes_enc = analysis.applicability_notes(get_arch("hubert-xlarge"))
    assert any("encoder-only" in n for n in notes_enc)


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------


def test_monitor_summary():
    m = Monitor()
    for i in range(3):
        m.start_step()
        time.sleep(0.001)
        m.end_step(i, loss=1.0, tokens=100)
    s = m.summary()
    assert s["steps"] == 3
    assert s["tokens_per_s"] > 0
    assert s["loss_ewma"] == pytest.approx(1.0)
