"""Dry-run integration: the full lower+compile+roofline path on a small
host-device mesh in a subprocess (the 512-device production matrix runs via
``python -m repro.launch.dryrun --all --both-meshes``; see EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch import dryrun
from repro.launch.mesh import make_debug_mesh

mesh = make_debug_mesh(model=4, data=2)
recs = []
for arch, shape in [("stablelm-3b", "train_4k"),
                    ("mamba2-1.3b", "decode_32k"),
                    ("moonshot-v1-16b-a3b", "train_4k")]:
    rec = dryrun.run_cell(arch, shape, multi_pod=False, mesh=mesh,
                          verbose=False)
    recs.append({k: rec[k] for k in ("arch", "shape", "mesh", "roofline")})
print("JSON" + json.dumps(recs))
"""


@pytest.mark.slow
def test_dryrun_small_mesh_cells():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    # forced host devices only exist on the CPU platform; pinning it also
    # skips the slow TPU-backend probe on containers with libtpu present
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    payload = [l for l in out.stdout.splitlines() if l.startswith("JSON")]
    assert payload, out.stdout
    recs = json.loads(payload[0][4:])
    assert len(recs) == 3
    for rec in recs:
        rl = rec["roofline"]
        assert rl["t_step_s"] > 0
        assert rl["bottleneck"] in ("compute", "memory", "collective")
        assert 0 < rl["useful_flops_ratio"] <= 1.5
