"""Roofline machinery: HLO cost parser (handcrafted + real modules) and the
three-term model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    cost_analysis_dict,
    parse_hlo_costs,
)

# ---------------------------------------------------------------------------
# parser on handcrafted HLO
# ---------------------------------------------------------------------------

HLO_DOT = """
ENTRY %main (a: f32[128,256], b: f32[256,512]) -> f32[128,512] {
  %a = f32[128,256] parameter(0)
  %b = f32[256,512] parameter(1)
  ROOT %dot.1 = f32[128,512] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_parser_dot_flops():
    costs = parse_hlo_costs(HLO_DOT)
    assert costs.flops == 2 * 128 * 256 * 512


HLO_COLLECTIVE = """
ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024] parameter(0)
  %ag = f32[4096] all-gather(%p), replica_groups={}, dimensions={0}
  %sl = f32[1024] slice(%ag), slice={[0:1024]}
  ROOT %ar = f32[1024] all-reduce(%sl), to_apply=%add
}
"""


def test_parser_collective_bytes():
    costs = parse_hlo_costs(HLO_COLLECTIVE)
    assert costs.coll_count["all-gather"] == 1
    assert costs.coll_count["all-reduce"] == 1
    # all-gather operand 1024 f32 = 4096 B; all-reduce operand = 4096 B
    assert costs.coll_bytes["all-gather"] == 4096
    assert costs.coll_bytes["all-reduce"] == 4096


HLO_WHILE = """
%body (x: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %x = (s32[], f32[64,64]) parameter(0)
  %m = f32[64,64] get-tuple-element(%x), index=1
  %d = f32[64,64] dot(%m, %m), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%x), index=0
  ROOT %t = (s32[], f32[64,64]) tuple(%i, %d)
}

%cond (x: (s32[], f32[64,64])) -> pred[] {
  %x = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%x), index=0
  %k = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (m0: f32[64,64]) -> f32[64,64] {
  %m0 = f32[64,64] parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[64,64]) tuple(%c0, %m0)
  %w = (s32[], f32[64,64]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[64,64] get-tuple-element(%w), index=1
}
"""


def test_parser_while_trip_count_multiplication():
    costs = parse_hlo_costs(HLO_WHILE)
    # 12 iterations x dot(64x64 @ 64x64)
    assert costs.flops == 12 * 2 * 64 * 64 * 64


def test_parser_kernel_scope_bytes_separated():
    hlo = """
ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024] parameter(0)
  %b = f32[1024] add(%a, %a), metadata={op_name="KERNEL_flash/add"}
  ROOT %c = f32[1024] multiply(%b, %b)
}
"""
    costs = parse_hlo_costs(hlo)
    assert costs.kernel_ref_bytes == 4096  # the KERNEL_-scoped add output
    assert costs.bytes_accessed == 4096  # the plain multiply output


# ---------------------------------------------------------------------------
# parser on REAL compiled modules (single CPU device)
# ---------------------------------------------------------------------------


def test_parser_real_matmul_module():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(lambda x: x @ x).lower(a).compile()
    costs = parse_hlo_costs(compiled.as_text())
    want = 2 * 256**3
    assert want * 0.9 <= costs.flops <= want * 1.1
    ca = cost_analysis_dict(compiled)
    if ca.get("flops"):
        assert costs.flops == pytest.approx(ca["flops"], rel=0.1)


def test_parser_real_scan_module_trip_counts():
    """cost_analysis undercounts while bodies; our parser must not."""

    def f(x):
        def body(c, _):
            return c @ c, ()

        out, _ = jax.lax.scan(body, x, None, length=12)
        return out

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(a).compile()
    costs = parse_hlo_costs(compiled.as_text())
    want = 12 * 2 * 128**3
    assert want * 0.9 <= costs.flops <= want * 1.15


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


def test_roofline_terms_and_bottleneck():
    rl = Roofline(
        flops_per_dev=PEAK_FLOPS,  # 1 s of compute
        bytes_per_dev=HBM_BW / 2,  # 0.5 s of memory
        collective_bytes_per_dev=ICI_BW / 4,  # 0.25 s of collective
        collective_count=10,
        n_devices=4,
        model_flops=2 * PEAK_FLOPS,  # 0.5 s ideal at 4 devices
    )
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(0.5)
    assert rl.t_collective == pytest.approx(0.25)
    assert rl.bottleneck == "compute"
    assert rl.t_step == pytest.approx(1.25)
    assert rl.useful_flops_ratio == pytest.approx(0.5)
    # ideal = 2*PEAK/(4*PEAK) = 0.5 s -> fraction 0.4
    assert rl.roofline_fraction == pytest.approx(0.5 / 1.25)


def test_roofline_overlap_hides_collective():
    rl = Roofline(
        flops_per_dev=PEAK_FLOPS,
        bytes_per_dev=0,
        collective_bytes_per_dev=ICI_BW,
        collective_count=1,
        n_devices=1,
        model_flops=PEAK_FLOPS,
        overlap=0.8,
    )
    assert rl.t_step == pytest.approx(1.0 + 0.2)


def test_model_flops_dense_vs_moe():
    from repro.configs import get_arch
    from repro.configs.base import TRAIN_4K
    from repro.launch.roofline import model_flops

    dense = get_arch("stablelm-3b")
    moe = get_arch("moonshot-v1-16b-a3b")
    fd = model_flops(dense, TRAIN_4K)
    fm = model_flops(moe, TRAIN_4K)
    # MoE uses ACTIVE params: far fewer FLOPs than its total param count
    assert fm < 6 * moe.n_params() * TRAIN_4K.global_batch * TRAIN_4K.seq_len
    assert fd > 6 * dense.n_params() * TRAIN_4K.global_batch * \
        TRAIN_4K.seq_len * 0.9
