"""Property tests for the job state machine (src/repro/serve/jobs.py).

Under arbitrary operation interleavings the lifecycle must never reach
an invalid transition, and every job ends in exactly one terminal state.
Uses hypothesis when installed; otherwise replays seeded random
interleavings through the same checkers so the invariants stay covered
on a bare interpreter (same pattern as test_quality_properties.py).
"""
import random

import pytest

from repro.offload.spec import OffloadSpec
from repro.serve import jobs as jb

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

# an "event" is what the service may attempt; its target state is fixed.
# Whether the attempt is LEGAL depends on the current state — that is
# exactly what TRANSITIONS arbitrates.
EVENTS = {
    "start": jb.RUNNING,
    "cancel": jb.CANCELLED,
    "complete": jb.DONE,
    "fail": jb.FAILED,
    "crash_requeue": jb.QUEUED,
}
STATES = (jb.QUEUED, jb.RUNNING, jb.DONE, jb.FAILED, jb.CANCELLED)


# ---------------------------------------------------------------------------
# checkers (shared between hypothesis and the seeded fallback)
# ---------------------------------------------------------------------------


def check_interleaving(events):
    """Apply an arbitrary event sequence to a fresh job model."""
    state = jb.QUEUED
    terminal_entries = 0
    for ev in events:
        target = EVENTS[ev]
        if jb.can_transition(state, target):
            state = target
            if state in jb.TERMINAL:
                terminal_entries += 1
        else:
            # an illegal attempt must not corrupt anything: the state
            # survives and stays a known state
            assert state in STATES
    # terminal states are absorbing: entered at most once, ever
    assert terminal_entries <= 1
    if state in jb.TERMINAL:
        assert not any(jb.can_transition(state, t) for t in STATES)
    else:
        # every live state has a legal path to exactly the documented set
        assert set(jb.TRANSITIONS[state]) == {
            t for t in STATES if jb.can_transition(state, t)}


def check_store_interleaving(tmp_path, seed, events):
    """Same invariants through the persisted JobStore + artifacts."""
    store = jb.JobStore(str(tmp_path / f"q{seed}"))
    spec = OffloadSpec(program="hetero", mode="mixed", population=4,
                       generations=2, seed=seed,
                       cache=str(store.cache_path))
    digest = jb.coalesce_key(spec)
    job = jb.Job(id=store.allocate_id(digest), state=jb.QUEUED,
                 digest=digest, seq=store.next_seq())
    art = store.create(spec, job)
    terminal_entries = 0
    for ev in events:
        target = EVENTS[ev]
        before = art.job["state"]
        if jb.can_transition(before, target):
            store.transition(art, target,
                             error="x" if target == jb.FAILED else None,
                             restarted=(target == jb.QUEUED))
            if target in jb.TERMINAL:
                terminal_entries += 1
        else:
            with pytest.raises(jb.JobError):
                store.transition(art, target)
            # the rejected transition left disk AND memory untouched
            assert art.job["state"] == before
        assert store.load(job.id).job["state"] == art.job["state"]
    assert terminal_entries <= 1
    reloaded = store.job(job.id)
    assert reloaded.state == art.job["state"]
    assert reloaded.restarts == art.job["restarts"]


def check_unknown_state_rejected(name):
    if name in STATES:
        return
    with pytest.raises(jb.JobError):
        jb.can_transition(name, jb.RUNNING)
    with pytest.raises(jb.JobError):
        jb.can_transition(jb.QUEUED, name)


# ---------------------------------------------------------------------------
# hypothesis drivers
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=300, deadline=None)
    @given(st.lists(st.sampled_from(sorted(EVENTS)), max_size=40))
    def test_interleavings_never_reach_invalid_state(events):
        check_interleaving(events)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16),
           events=st.lists(st.sampled_from(sorted(EVENTS)), max_size=8))
    def test_store_interleavings_persist_invariants(tmp_path_factory,
                                                    seed, events):
        check_store_interleaving(tmp_path_factory.mktemp("props"),
                                 seed, events)

    @settings(max_examples=100, deadline=None)
    @given(st.text(max_size=12))
    def test_unknown_states_always_rejected(name):
        check_unknown_state_rejected(name)


# ---------------------------------------------------------------------------
# seeded fallback (always runs; the only coverage without hypothesis)
# ---------------------------------------------------------------------------


def _seeded_interleavings(n, max_len):
    rng = random.Random(0xC0FFEE)
    names = sorted(EVENTS)
    return [[rng.choice(names) for _ in range(rng.randint(0, max_len))]
            for _ in range(n)]


@pytest.mark.parametrize("events", _seeded_interleavings(60, 40))
def test_interleavings_never_reach_invalid_state_seeded(events):
    check_interleaving(events)


@pytest.mark.parametrize("seed,events",
                         [(i, ev) for i, ev in
                          enumerate(_seeded_interleavings(12, 8))])
def test_store_interleavings_persist_invariants_seeded(tmp_path, seed,
                                                       events):
    check_store_interleaving(tmp_path, seed, events)


@pytest.mark.parametrize("name", ["", "queued ", "Queued", "done!",
                                  "pending", "zombie"])
def test_unknown_states_always_rejected_seeded(name):
    check_unknown_state_rejected(name)


def test_every_documented_transition_is_reachable():
    # the TRANSITIONS table itself: keys cover all states, every target
    # is a known state, terminal rows are empty
    assert set(jb.TRANSITIONS) == set(STATES)
    for state, targets in jb.TRANSITIONS.items():
        assert set(targets) <= set(STATES)
        if state in jb.TERMINAL:
            assert targets == ()
