"""Fault-injection + concurrency suite for the offload service
(src/repro/serve): the serving features land together with the tests
that prove their behavior under crashes, cancels and contention.

Covers the ISSUE 9 acceptance criteria directly:

- crash mid-search -> restart -> the job completes via resume with ZERO
  fresh measurements and the same winner as an uninterrupted run
  (simulated crash in the fast tier; a real SIGKILL subprocess variant
  runs @slow in the nightly tier);
- a forced duplicate submission reports a >=90% fitness-cache hit rate
  in its job trace; the coalescing path returns the first job's id;
- an injected evaluator exception FAILS that job while siblings finish;
- cancellation between pipeline stages stops the job with the terminal
  state recorded and no further stage executed;
- with the service unused, Offloader runs / spec digests / trace digests
  are byte-identical to PR 8 (pinned-literal regression).
"""
import json
import os
import subprocess
import sys
import threading

import pytest

import repro
from repro.offload import trace as trace_mod
from repro.offload.pipeline import Offloader, _spec_digest
from repro.offload.spec import OffloadSpec
from repro.serve import jobs as jb
from repro.serve.admission import AdmissionPolicy
from repro.serve.offload_service import (
    FaultPlan,
    OffloadService,
    ServiceCrash,
)

# the suite's canonical job: hetero mixed, analytic evaluator, tiny GA —
# a full six-stage pipeline in well under a second
_SPEC_KW = dict(program="hetero", mode="mixed", population=6, generations=4,
                ga={"stability_seeds": 2})
_LAST_GEN = _SPEC_KW["generations"] - 1  # crash here = everything cached


def _spec(**kw) -> OffloadSpec:
    return OffloadSpec(**{**_SPEC_KW, **kw})


def _svc(tmp_path, **kw) -> OffloadService:
    return OffloadService(str(tmp_path / "q"), **kw)


def _search(art):
    return art.stages["search"].payload


def _winner(art):
    return (art.best_genes, art.best_time_s,
            _search(art)["placement"],
            [h["best_time_s"] for h in _search(art)["history"]])


def _terminal_event(svc, job_id):
    tr = trace_mod.load_trace(svc.store.trace_path(job_id))
    events = [e for e in tr.events("service") if e["name"] == "job_terminal"]
    assert events, "job trace records no terminal event"
    return events[-1]["attrs"]


# ---------------------------------------------------------------------------
# submission: coalescing + admission
# ---------------------------------------------------------------------------


def test_duplicate_submission_coalesces_onto_anchor(tmp_path):
    svc = _svc(tmp_path)
    r1 = svc.submit(_spec())
    r2 = svc.submit(_spec())
    assert not r1.coalesced and r2.coalesced
    assert r2.job_id == r1.job_id  # the first job's artifact id
    # cache path + workers are result-neutral: they coalesce too
    r3 = svc.submit(_spec(workers=4, cache="/elsewhere/f.jsonl"))
    assert r3.coalesced and r3.job_id == r1.job_id
    # a genuinely different spec gets its own job
    r4 = svc.submit(_spec(seed=1))
    assert not r4.coalesced and r4.job_id != r1.job_id
    assert svc.store.coalesced_count(r1.job_id) == 2
    assert [j.state for j in svc.jobs()] == [jb.QUEUED, jb.QUEUED]


def test_coalescing_still_applies_after_done_and_skips_failed(tmp_path):
    svc = _svc(tmp_path)
    r1 = svc.submit(_spec())
    svc.run()
    assert svc.status(r1.job_id).state == jb.DONE
    # DONE anchors absorb repeats: the search is never paid twice
    r2 = svc.submit(_spec())
    assert r2.coalesced and r2.job_id == r1.job_id
    # FAILED/CANCELLED anchors do NOT absorb: resubmit = retry
    svc.cancel(r1.job_id)  # terminal job ignores it; make a failed one
    bad = _svc(tmp_path, fault=FaultPlan.parse("raise-in-search:0@-r2"))
    rf = bad.submit(_spec(), force=True)
    bad.run()
    assert bad.status(rf.job_id).state == jb.FAILED
    r3 = bad.submit(_spec(seed=0), force=False)
    assert r3.coalesced and r3.job_id == r1.job_id  # DONE anchor wins


def test_admission_clamps_are_applied_and_recorded(tmp_path):
    svc = _svc(tmp_path, policy=AdmissionPolicy(
        max_in_flight=1, max_generations=2, max_population=4,
        max_stability_seeds=1))
    r = svc.submit(_spec())
    assert r.clamped == {"generations": [4, 2], "population": [6, 4],
                         "stability_seeds": [2, 1]}
    job = svc.status(r.job_id)
    assert job.clamped == r.clamped
    art = svc.result(r.job_id)
    assert art.spec.generations == 2 and art.spec.population == 4
    assert art.spec.ga.stability_seeds == 1
    svc.run()
    assert len(_search(svc.result(r.job_id))["history"]) == 2


def test_concurrent_identical_submissions_yield_one_job(tmp_path):
    svc = _svc(tmp_path)
    receipts = []
    lock = threading.Lock()

    def submit():
        r = svc.submit(_spec())
        with lock:
            receipts.append(r)

    threads = [threading.Thread(target=submit) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len({r.job_id for r in receipts}) == 1
    assert sum(not r.coalesced for r in receipts) == 1
    assert len(svc.jobs()) == 1


# ---------------------------------------------------------------------------
# fault injection: crash-resume, evaluator exception, cancellation
# ---------------------------------------------------------------------------


def test_crash_mid_search_restart_resumes_with_zero_measurements(tmp_path):
    # reference: the same spec, uninterrupted, in its own directory
    ref_svc = OffloadService(str(tmp_path / "ref"))
    ref = ref_svc.submit(_spec())
    ref_svc.run()
    ref_art = ref_svc.result(ref.job_id)

    # crash AFTER the last generation's measurements hit the shared
    # cache, BEFORE the search stage records: the worst-case kill point
    svc = _svc(tmp_path,
               fault=FaultPlan.parse(f"crash-in-search:{_LAST_GEN}"))
    r = svc.submit(_spec())
    with pytest.raises(ServiceCrash):
        svc.run()
    assert svc.status(r.job_id).state == jb.RUNNING  # the crash state

    # restart = a fresh service over the same directory, no fault
    svc2 = _svc(tmp_path)
    svc2.run()
    job = svc2.status(r.job_id)
    assert job.state == jb.DONE and job.restarts == 1
    art = svc2.result(r.job_id)
    p = _search(art)
    assert p["evaluations"] == 0, "resume must re-measure nothing"
    assert p["cache_resumed"] > 0
    assert _winner(art) == _winner(ref_art)
    # the trace survives the crash: validates whole, digest matches the
    # artifact's embedded one, and records the requeue + terminal events
    tr = trace_mod.load_trace(svc2.store.trace_path(r.job_id))
    assert art.trace["digest"] == tr.digest
    names = [e["name"] for e in tr.events("service")]
    assert "job_requeued" in names and names[-1] == "job_terminal"
    term = _terminal_event(svc2, r.job_id)
    assert term["restarts"] == 1 and term["evaluations"] == 0


def test_evaluator_exception_fails_job_while_sibling_completes(tmp_path):
    svc = _svc(tmp_path, policy=AdmissionPolicy(max_in_flight=2),
               fault=FaultPlan.parse("raise-in-search:1@-r2"))
    ra = svc.submit(_spec())
    rb = svc.submit(_spec(), force=True)  # gets id ...-r2 -> the fault
    jobs = {j.id: j for j in svc.run()}
    assert jobs[ra.job_id].state == jb.DONE
    assert jobs[rb.job_id].state == jb.FAILED
    assert "fault injected" in jobs[rb.job_id].error
    term = _terminal_event(svc, rb.job_id)
    assert term["state"] == jb.FAILED and "error" in term
    # a failed job's artifact still validates against its trace
    art = svc.result(rb.job_id)
    tr = trace_mod.load_trace(svc.store.trace_path(rb.job_id))
    assert art.trace["digest"] == tr.digest


def test_cancel_queued_job_runs_no_stage(tmp_path):
    svc = _svc(tmp_path)
    r = svc.submit(_spec())
    svc.cancel(r.job_id)
    svc.run()
    job = svc.status(r.job_id)
    assert job.state == jb.CANCELLED
    assert svc.result(r.job_id).stages == {}
    assert _terminal_event(svc, r.job_id)["state"] == jb.CANCELLED


def test_cancel_running_job_stops_between_stages(tmp_path, monkeypatch):
    svc = _svc(tmp_path)
    r = svc.submit(_spec())
    orig = Offloader.run_stage

    def run_stage_then_cancel(self, name):
        orig(self, name)
        if name == "seed":  # job is RUNNING; cancel lands mid-pipeline
            svc.cancel(r.job_id)

    monkeypatch.setattr(Offloader, "run_stage", run_stage_then_cancel)
    svc.run()
    job = svc.status(r.job_id)
    assert job.state == jb.CANCELLED
    assert "before stage 'search'" in job.error
    art = svc.result(r.job_id)
    assert art.completed("seed")
    assert "search" not in art.stages, "no stage may run past a cancel"
    assert _terminal_event(svc, r.job_id)["state"] == jb.CANCELLED


def test_recover_repairs_torn_trace_tail(tmp_path):
    svc = _svc(tmp_path,
               fault=FaultPlan.parse(f"crash-in-search:{_LAST_GEN}"))
    r = svc.submit(_spec())
    with pytest.raises(ServiceCrash):
        svc.run()
    # a SIGKILL mid-write leaves half a JSON line; recovery drops it
    trace_path = svc.store.trace_path(r.job_id)
    with open(trace_path, "a", encoding="utf-8") as fh:
        fh.write('{"seq": 99, "kind": "ev')
    with pytest.raises(trace_mod.TraceError):
        trace_mod.load_trace(trace_path)
    svc2 = _svc(tmp_path)
    svc2.run()
    assert svc2.status(r.job_id).state == jb.DONE
    trace_mod.load_trace(trace_path)  # validates whole again


# ---------------------------------------------------------------------------
# shared cache: forced duplicates are nearly free
# ---------------------------------------------------------------------------


def test_forced_duplicate_reports_cache_hit_rate(tmp_path):
    svc = _svc(tmp_path)
    r1 = svc.submit(_spec())
    svc.run()
    r2 = svc.submit(_spec(), force=True)
    assert not r2.coalesced and r2.job_id != r1.job_id
    svc.run()
    art1, art2 = svc.result(r1.job_id), svc.result(r2.job_id)
    assert _winner(art2) == _winner(art1)
    assert _search(art2)["evaluations"] == 0  # pure cache replay
    term = _terminal_event(svc, r2.job_id)
    assert term["hit_rate"] >= 0.9  # the acceptance bar; actual: 1.0
    assert term["evaluations"] == 0


def test_cross_subset_submissions_share_the_store(tmp_path):
    # different destination subsets share the subset-independent mixed
    # fingerprint: the cpu+gpu job re-uses cpu+gpu+fpga measurements
    svc = _svc(tmp_path)
    r1 = svc.submit(_spec())
    svc.run()
    r2 = svc.submit(_spec(destinations=("cpu", "gpu")))
    svc.run()
    p = _search(svc.result(r2.job_id))
    assert p["cache_hits"] > 0


# ---------------------------------------------------------------------------
# concurrency stress: coalescing + bound + serial parity
# ---------------------------------------------------------------------------


def test_concurrency_stress_matches_serial_runs(tmp_path):
    distinct = [
        _spec(),
        _spec(destinations=("cpu", "gpu")),
        _spec(destinations=("cpu", "fpga")),
        _spec(seed=1),
    ]
    svc = _svc(tmp_path, policy=AdmissionPolicy(max_in_flight=2))
    receipts = []
    lock = threading.Lock()

    def submit(spec):
        r = svc.submit(spec)
        with lock:
            receipts.append(r)

    # 8 threads, every distinct spec submitted twice: the duplicates
    # must coalesce, the distinct ones must all run
    threads = [threading.Thread(target=submit, args=(s,))
               for s in distinct * 2]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(svc.jobs()) == len(distinct)
    assert sum(r.coalesced for r in receipts) == len(distinct)

    jobs = svc.run()
    assert all(j.state == jb.DONE for j in jobs)
    assert svc.max_in_flight_seen <= 2, "admission bound exceeded"

    # serial reference: each spec through a plain Offloader, alone
    by_digest = {jb.coalesce_key(svc.normalize(s)): s for s in distinct}
    for j in jobs:
        spec = by_digest[j.digest]
        ref_dir = tmp_path / f"serial-{j.digest}"
        ref = Offloader(
            OffloadSpec(**{**_SPEC_KW,
                           **{k: getattr(spec, k)
                              for k in ("destinations", "seed")}}),
            artifact_path=str(ref_dir / "ref.offload.json"),
        ).run()
        assert _winner(svc.result(j.id)) == _winner(ref), j.id


# ---------------------------------------------------------------------------
# state machine guard rails (the persisted store side)
# ---------------------------------------------------------------------------


def test_illegal_transition_raises_and_leaves_record_untouched(tmp_path):
    svc = _svc(tmp_path)
    r = svc.submit(_spec())
    art = svc.store.load(r.job_id)
    with pytest.raises(jb.JobError):
        svc.store.transition(art, jb.DONE)  # QUEUED -> DONE is illegal
    assert svc.status(r.job_id).state == jb.QUEUED
    svc.run()
    art = svc.store.load(r.job_id)
    for target in (jb.RUNNING, jb.QUEUED, jb.FAILED, jb.CANCELLED):
        with pytest.raises(jb.JobError):
            svc.store.transition(art, target)  # DONE is terminal
    assert svc.status(r.job_id).state == jb.DONE


def test_unknown_job_and_duplicate_create_raise(tmp_path):
    svc = _svc(tmp_path)
    with pytest.raises(jb.JobError):
        svc.status("jb-0000000000")
    r = svc.submit(_spec())
    with pytest.raises(jb.JobError):
        svc.store.create(svc.normalize(_spec()),
                         jb.Job(id=r.job_id, state=jb.QUEUED,
                                digest=r.digest, seq=99))


# ---------------------------------------------------------------------------
# CLI: the filesystem queue is fully drivable without sockets
# ---------------------------------------------------------------------------


def test_cli_serve_roundtrip(tmp_path, capsys):
    from repro.offload.__main__ import main

    q = str(tmp_path / "q")
    spec_args = ["--program", "hetero", "--mode", "mixed",
                 "--population", "6", "--generations", "4",
                 "--stability-seeds", "2"]
    assert main(["serve", "submit", "--dir", q, *spec_args,
                 "--quiet"]) == 0
    job_id = capsys.readouterr().out.strip()
    assert job_id.startswith("jb-")
    assert main(["serve", "submit", "--dir", q, *spec_args]) == 0
    assert f"coalesced onto existing job {job_id}" in capsys.readouterr().out
    assert main(["serve", "run", "--dir", q]) == 0
    out = capsys.readouterr().out
    assert job_id in out and "done" in out and "(+1 coalesced)" in out
    assert main(["serve", "status", "--dir", q, "--job", job_id]) == 0
    assert "done" in capsys.readouterr().out
    assert main(["serve", "result", "--dir", q, "--job", job_id]) == 0
    out = capsys.readouterr().out
    assert "OffloadResult[hetero/mixed" in out and "artifact:" in out
    # the job's trace renders + digest-checks through the trace verb
    art_path = os.path.join(q, "jobs", f"{job_id}.offload.json")
    assert main(["trace", "--artifact", art_path]) == 0
    assert "service::job_terminal" in capsys.readouterr().out
    # unknown job ids exit 1 on every query verb
    assert main(["serve", "status", "--dir", q, "--job", "jb-nope"]) == 1
    assert main(["serve", "result", "--dir", q, "--job", "jb-nope"]) == 1
    assert main(["serve", "cancel", "--dir", q, "--job", "jb-nope"]) == 1
    capsys.readouterr()


def test_cli_serve_run_reports_failed_jobs(tmp_path, capsys):
    from repro.offload.__main__ import main

    q = str(tmp_path / "q")
    spec_args = ["--program", "hetero", "--mode", "mixed",
                 "--population", "6", "--generations", "4",
                 "--stability-seeds", "2"]
    assert main(["serve", "submit", "--dir", q, *spec_args,
                 "--quiet"]) == 0
    capsys.readouterr()
    assert main(["serve", "run", "--dir", q, "--fault",
                 "raise-in-stage:search"]) == 1
    assert "failed" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# byte parity: the service layer is invisible when unused
# ---------------------------------------------------------------------------

# produced by the PR-8 pipeline (verified identical on the pre-serving
# tree); any drift here means plain Offloader behavior changed
_PINNED_SPEC_DIGESTS = {
    ("hetero", "mixed"): "5ce1087a37b01cae",
    ("himeno", "binary"): "3bcd40234cda7d50",
}
_PINNED_6X4_DIGEST = "24f343abc31d8a46"
_PINNED_TRACE_DIGEST = (
    "efef4bcd23f270e9026f93b8078d55671abd83a9c0582485428277d30f4f4858"
)
_PINNED_WINNER = (0, 1, 2, 1, 1, 2, 2, 2, 1, 2, 2, 1)
_PINNED_BEST_S = 2.4199330573728335


def test_unused_service_keeps_offloader_byte_identical(tmp_path):
    # spec digests: serialized spec bytes are untouched by the serving PR
    assert _spec_digest(OffloadSpec(program="hetero",
                                    mode="mixed")) == \
        _PINNED_SPEC_DIGESTS[("hetero", "mixed")]
    assert _spec_digest(OffloadSpec(program="himeno")) == \
        _PINNED_SPEC_DIGESTS[("himeno", "binary")]
    assert _spec_digest(OffloadSpec(program="hetero", mode="mixed",
                                    population=6, generations=4)) == \
        _PINNED_6X4_DIGEST
    # a full pipeline run under a pinned clock: identical winner and
    # identical (timing-stripped) trace digest to PR 8
    import itertools

    clock = itertools.count(0.0, 0.25)
    art = Offloader(
        _spec(),
        artifact_path=str(tmp_path / "parity.offload.json"),
        trace_clock=lambda c=clock: next(c),
    ).run()
    assert art.best_genes == _PINNED_WINNER
    assert art.best_time_s == _PINNED_BEST_S
    assert art.trace["digest"] == _PINNED_TRACE_DIGEST
    # and the artifact JSON carries no serving-layer field at all
    saved = json.loads((tmp_path / "parity.offload.json").read_text())
    assert "job" not in saved


# ---------------------------------------------------------------------------
# the real thing: SIGKILL the service process, restart, resume (@slow)
# ---------------------------------------------------------------------------


def _serve_cli(args, **kw):
    env = dict(os.environ)
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.offload", "serve", *args],
        env=env, capture_output=True, text=True, timeout=600, **kw)


@pytest.mark.slow
def test_sigkill_service_process_restart_resumes(tmp_path):
    q = str(tmp_path / "q")
    spec_args = ["--program", "hetero", "--mode", "mixed",
                 "--population", "6", "--generations", "4",
                 "--stability-seeds", "2"]
    sub = _serve_cli(["submit", "--dir", q, *spec_args, "--quiet"])
    assert sub.returncode == 0, sub.stderr
    job_id = sub.stdout.strip()

    # the service process SIGKILLs ITSELF at the last generation: no
    # cleanup, no atexit — the artifact says RUNNING, the cache is warm
    killed = _serve_cli(["run", "--dir", q, "--fault",
                         f"kill-in-search:{_LAST_GEN}"])
    assert killed.returncode == -9, (killed.returncode, killed.stderr)
    svc = OffloadService(q)
    assert svc.status(job_id).state == jb.RUNNING

    restarted = _serve_cli(["run", "--dir", q])
    assert restarted.returncode == 0, restarted.stderr
    job = svc.status(job_id)
    assert job.state == jb.DONE and job.restarts == 1
    art = svc.result(job_id)
    assert _search(art)["evaluations"] == 0
    # same winner as an uninterrupted run of the same spec
    ref_svc = OffloadService(str(tmp_path / "ref"))
    ref = ref_svc.submit(_spec())
    ref_svc.run()
    assert _winner(art) == _winner(ref_svc.result(ref.job_id))
