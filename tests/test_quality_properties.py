"""Property tests for the pure quality math in repro/offload/quality.py:
rank correlations (Spearman, Kendall tau-b) and allele entropy, at the
edges the report stage actually hits — ties, constant populations,
single-element inputs.

Runs under hypothesis when available; the container image may not ship
it, so a deterministic seeded-case fallback drives the same property
checkers either way (no new dependencies — the ISSUE's constraint).
"""
import math
import random

import pytest

from repro.offload import quality as qual

try:  # hypothesis is optional; the fallback below covers its absence
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# property checkers (shared by the hypothesis and fallback drivers)
# ---------------------------------------------------------------------------


def check_rank_properties(xs, ys):
    """Every property that must hold for ANY equal-length float pair."""
    n = len(xs)
    # ranks: a permutation-average of 1..n — bounded, fixed sum
    r = qual.ranks(xs)
    assert len(r) == n
    if n:
        assert min(r) >= 1.0 and max(r) <= n
        assert math.isclose(sum(r), n * (n + 1) / 2.0)
    for fn in (qual.spearman, qual.kendall):
        c = fn(xs, ys)
        if n < 2:
            assert c is None
            continue
        if c is not None:
            assert -1.0 - 1e-9 <= c <= 1.0 + 1e-9, (fn.__name__, c)
        # symmetry: correlation(x, y) == correlation(y, x)
        c2 = fn(ys, xs)
        if c is None:
            assert c2 is None
        else:
            assert math.isclose(c, c2, abs_tol=1e-12)
    # constant sides are never rankable
    if n >= 2:
        assert qual.spearman(xs, [1.0] * n) is None
        assert qual.kendall([0.0] * n, ys) is None


def check_monotone_properties(xs):
    """Strictly increasing distinct values: perfect agreement with
    themselves, perfect disagreement with their negation."""
    if len(xs) < 2:
        return
    neg = [-x for x in xs]
    for fn in (qual.spearman, qual.kendall):
        assert math.isclose(fn(xs, list(xs)), 1.0, abs_tol=1e-12)
        assert math.isclose(fn(xs, neg), -1.0, abs_tol=1e-12)


def check_entropy_properties(population, alleles):
    e = qual.allele_entropy(population, alleles)
    assert 0.0 <= e <= 1.0 + 1e-9, e
    if population:
        # a converged population (one genome repeated) has zero entropy
        converged = [tuple(population[0])] * len(population)
        assert qual.allele_entropy(converged, alleles) == 0.0
    # permutation invariance: entropy is a population-level statistic
    if len(population) > 1:
        rev = list(reversed(population))
        assert math.isclose(qual.allele_entropy(rev, alleles), e,
                            abs_tol=1e-12)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def _fallback_float_pairs(n_cases=200):
    rng = random.Random(0xC0FFEE)
    cases = [([], []), ([1.0], [2.0]), ([1.0, 1.0], [2.0, 3.0])]
    for _ in range(n_cases):
        n = rng.randrange(0, 12)
        # coarse grid -> plenty of ties
        xs = [rng.choice([-2.0, -1.0, 0.0, 0.5, 1.0, 3.0]) for _ in range(n)]
        ys = [rng.choice([-2.0, -1.0, 0.0, 0.5, 1.0, 3.0]) for _ in range(n)]
        cases.append((xs, ys))
    return cases


def _fallback_populations(n_cases=200):
    rng = random.Random(0xBEEF)
    cases = [([], 2), ([()], 2), ([(0,)], 1), ([(0, 1), (1, 0)], 2)]
    for _ in range(n_cases):
        alleles = rng.randrange(1, 5)
        genes = rng.randrange(0, 6)
        m = rng.randrange(1, 8)
        pop = [tuple(rng.randrange(alleles) for _ in range(genes))
               for _ in range(m)]
        cases.append((pop, alleles))
    return cases


if HAVE_HYPOTHESIS:
    floats = st.floats(allow_nan=False, allow_infinity=False,
                       min_value=-1e6, max_value=1e6)

    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 12).flatmap(
        lambda n: st.tuples(
            st.lists(floats, min_size=n, max_size=n),
            st.lists(floats, min_size=n, max_size=n),
        )
    ))
    def test_rank_properties(pair):
        check_rank_properties(*pair)

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.integers(-1000, 1000), min_size=2, max_size=10,
                    unique=True))
    def test_monotone_extremes(values):
        check_monotone_properties(sorted(float(v) for v in values))

    @settings(max_examples=200, deadline=None)
    @given(
        st.integers(1, 4).flatmap(
            lambda k: st.tuples(
                st.integers(0, 5).flatmap(
                    lambda g: st.lists(
                        st.lists(st.integers(0, k - 1),
                                 min_size=g, max_size=g).map(tuple),
                        min_size=0, max_size=8,
                    )
                ),
                st.just(k),
            )
        )
    )
    def test_entropy_properties(case):
        check_entropy_properties(*case)

else:

    @pytest.mark.parametrize("xs,ys", _fallback_float_pairs())
    def test_rank_properties(xs, ys):
        check_rank_properties(xs, ys)

    @pytest.mark.parametrize("xs", [
        [0.0, 1.0], [-3.0, -1.0, 2.0, 7.0], [1.0, 2.0, 3.0, 4.0, 5.0],
        [float(v) for v in range(-5, 6)],
    ])
    def test_monotone_extremes(xs):
        check_monotone_properties(xs)

    @pytest.mark.parametrize("pop,alleles", _fallback_populations())
    def test_entropy_properties(pop, alleles):
        check_entropy_properties(pop, alleles)


# ---------------------------------------------------------------------------
# pinned edge cases (identical under either driver)
# ---------------------------------------------------------------------------


def test_length_mismatch_raises():
    with pytest.raises(ValueError):
        qual.spearman([1.0], [1.0, 2.0])
    with pytest.raises(ValueError):
        qual.kendall([1.0, 2.0], [1.0])


def test_single_element_and_empty_are_undefined():
    for fn in (qual.spearman, qual.kendall):
        assert fn([], []) is None
        assert fn([3.0], [4.0]) is None


def test_ties_tau_b_known_value():
    # x has one tied pair; tau-b corrects the denominator for it:
    # pairs = 6, concordant = 5, discordant = 0, ties_x = 1
    # tau-b = 5 / sqrt((6-1) * 6) ~ 0.9129
    xs = [1.0, 2.0, 2.0, 3.0]
    ys = [1.0, 2.0, 3.0, 4.0]
    assert math.isclose(qual.kendall(xs, ys),
                        5.0 / math.sqrt(30.0), abs_tol=1e-12)


def test_entropy_extremes():
    # uniform over both alleles at every gene -> exactly 1
    assert qual.allele_entropy([(0, 0), (1, 1)], 2) == pytest.approx(1.0)
    # converged -> exactly 0; degenerate alphabets/populations -> 0
    assert qual.allele_entropy([(1, 1), (1, 1)], 2) == 0.0
    assert qual.allele_entropy([], 2) == 0.0
    assert qual.allele_entropy([(0,), (0,)], 1) == 0.0
    assert qual.allele_entropy([()], 2) == 0.0
    # a single individual has nothing to vary
    assert qual.allele_entropy([(0, 1, 0)], 2) == 0.0


def test_median():
    assert qual.median([3.0]) == 3.0
    assert qual.median([4.0, 1.0, 3.0]) == 3.0
    assert qual.median([1.0, 2.0, 3.0, 4.0]) == 2.5
    with pytest.raises(ValueError):
        qual.median([])


def test_stability_metrics_window_edges():
    winners = [
        {"seed": 0, "best_time_s": 1.0, "best_genes": [0, 1]},
        {"seed": 1, "best_time_s": 1.02, "best_genes": [0, 1]},
        {"seed": 2, "best_time_s": 1.5, "best_genes": [1, 1]},
    ]
    m = qual.stability_metrics(winners, window=0.02)
    # exactly at the window edge still passes (<=)
    assert m["pass_at_k"] == pytest.approx(2 / 3)
    assert m["k"] == 3
    assert m["best_time_s"] == 1.0
    assert m["worst_time_s"] == 1.5
    assert m["rel_spread"] == pytest.approx(0.5)
    assert m["distinct_winners"] == 2
    with pytest.raises(ValueError):
        qual.stability_metrics([], window=0.02)
    with pytest.raises(ValueError):
        qual.stability_metrics(winners, window=-0.1)


def test_rank_section_notes_degenerate_sides():
    sec = qual.rank_section([1.0, 1.0], [2.0, 3.0])
    assert sec["spearman"] is None and "note" in sec
    assert sec["distinct_modeled"] == 1
    sec = qual.rank_section([1.0, 2.0, 3.0], [10.0, 20.0, 30.0],
                            scale="small", reference="model:hw")
    assert sec["spearman"] == pytest.approx(1.0)
    assert sec["kendall"] == pytest.approx(1.0)
    assert sec["scale"] == "small" and sec["reference"] == "model:hw"
