"""Model-zoo sweep driver + BENCH trajectory artifact
(src/repro/offload/sweep.py, `python -m repro.offload sweep`).

Covers the ISSUE-6 acceptance surface: schema round-trip, append-only
merge onto a pre-existing trajectory, regression-flagger tolerance
edges, resume-mid-sweep (completed cells skipped with zero fresh
measurements), and the CLI end to end — two smoke invocations append
two points, the leaderboard renders deltas, and an injected regression
exits nonzero.
"""
import json
import math

import pytest

from repro.offload import sweep as sw
from repro.offload.__main__ import EXIT_CODES, main
from repro.offload.spec import MIXED_SMOKE_BUDGET

# ---------------------------------------------------------------------------
# fabricated points (unit tests never run searches)
# ---------------------------------------------------------------------------


def _cell(cid, best, status="ok", fresh=5):
    prog, hw, mode = cid.rsplit(":", 2)
    return {
        "id": cid, "program": prog, "hw": hw, "mode": mode,
        "status": status, "resumed": False, "fresh_measurements": fresh,
        "wall_s": 0.1, "error": None if status == "ok" else "boom",
        "best_time_s": best, "baseline_s": (best or 1.0) * 10.0,
        "speedup": 10.0 if best else None,
        "search": {"evaluations": fresh, "cache_hits": 3,
                   "hit_rate": 0.375, "wall_s": 0.05,
                   "generations": 4, "population": 6} if best else None,
        "residency": None,
    }


def _point(cells, git="abcdef123456", ts="2026-01-01T00:00:00Z",
           label=None, smoke=True):
    recs = [_cell(cid, best) if not isinstance(best, dict) else best
            for cid, best in cells.items()]
    ok = [c for c in recs if c["status"] == "ok"]
    speedups = [c["speedup"] for c in ok if c["speedup"]]
    return {
        "git": git, "timestamp": ts, "label": label, "smoke": smoke,
        "matrix": {"cells": list(cells), "skipped": []},
        "cells": recs,
        "totals": {
            "n_cells": len(recs), "n_ok": len(ok),
            "n_failed": len(recs) - len(ok), "n_resumed": 0,
            "fresh_measurements": sum(c["fresh_measurements"]
                                      for c in recs),
            "cache_hits": 0, "hit_rate": 0.0,
            "geomean_speedup": math.exp(
                sum(math.log(s) for s in speedups) / len(speedups)
            ) if speedups else None,
            "wall_s": 1.0,
        },
    }


CID_A = "himeno:quadro-p4000:binary"
CID_B = "hetero:quadro-p4000:mixed"


# ---------------------------------------------------------------------------
# trajectory schema + persistence
# ---------------------------------------------------------------------------


def test_point_schema_roundtrip(tmp_path):
    path = str(tmp_path / "BENCH_sweep.json")
    p = _point({CID_A: 1.0, CID_B: 2.0})
    sw.validate_point(p)  # writer-side gate accepts it
    sw.append_point(path, p)
    loaded = sw.Trajectory.load(path)
    assert loaded.points == [p]  # byte-faithful through JSON
    d = json.loads((tmp_path / "BENCH_sweep.json").read_text())
    assert d["schema"] == sw.SWEEP_SCHEMA
    assert d["v"] == sw.SWEEP_SCHEMA_VERSION


def test_append_only_merge_preserves_existing_points(tmp_path):
    path = str(tmp_path / "BENCH_sweep.json")
    p1 = _point({CID_A: 1.0}, ts="2026-01-01T00:00:00Z")
    p2 = _point({CID_A: 0.9}, ts="2026-01-02T00:00:00Z")
    sw.append_point(path, p1)
    traj = sw.append_point(path, p2)
    assert [pt["timestamp"] for pt in traj.points] == [
        "2026-01-01T00:00:00Z", "2026-01-02T00:00:00Z"
    ]
    assert traj.points[0] == p1  # the old point is never rewritten
    assert traj.previous == p1 and traj.last == p2


def test_load_missing_file_is_empty_trajectory(tmp_path):
    traj = sw.Trajectory.load(str(tmp_path / "nope.json"))
    assert traj.points == [] and traj.last is None


def test_load_rejects_foreign_schema(tmp_path):
    bad = tmp_path / "BENCH_sweep.json"
    bad.write_text(json.dumps({"schema": "something-else", "v": 1,
                               "points": []}))
    with pytest.raises(ValueError, match="not a repro.offload.sweep"):
        sw.Trajectory.load(str(bad))
    bad.write_text(json.dumps({"schema": sw.SWEEP_SCHEMA, "v": 999,
                               "points": []}))
    with pytest.raises(ValueError, match="v=999"):
        sw.Trajectory.load(str(bad))


def test_validate_point_names_every_missing_field():
    p = _point({CID_A: 1.0})
    del p["git"]
    del p["cells"][0]["speedup"]
    p["cells"][0]["status"] = "weird"
    with pytest.raises(ValueError) as ei:
        sw.validate_point(p)
    msg = str(ei.value)
    assert "'git'" in msg and "'speedup'" in msg and "weird" in msg


def test_append_rejects_invalid_point(tmp_path):
    path = str(tmp_path / "BENCH_sweep.json")
    p = _point({CID_A: 1.0})
    del p["totals"]
    with pytest.raises(ValueError):
        sw.append_point(path, p)
    assert not (tmp_path / "BENCH_sweep.json").exists()  # nothing written


def test_v2_point_appends_after_v1_points(tmp_path):
    """ISSUE 7: the point schema grew a "v" marker and per-cell search
    quality; v2 points must append cleanly after pre-existing v1 points,
    and each version validates by its own rules."""
    path = str(tmp_path / "BENCH_sweep.json")
    v1 = _point({CID_A: 1.0}, ts="2026-01-01T00:00:00Z")
    assert "v" not in v1  # fabricated exactly like the committed history
    sw.append_point(path, v1)

    v2 = _point({CID_A: 0.9}, ts="2026-01-02T00:00:00Z")
    v2["v"] = 2
    for c in v2["cells"]:
        c["quality"] = {"stability": {"k": 3, "pass_at_k": 1.0,
                                      "rel_spread": 0.009,
                                      "distinct_winners": 2},
                        "rank": {"skipped": "rank_probe disabled"}}
    traj = sw.append_point(path, v2)

    # ISSUE 8: v3 points add a per-cell block-substitution summary and
    # append cleanly after the v1/v2 history
    v3 = _point({CID_A: 0.85}, ts="2026-01-03T00:00:00Z")
    v3["v"] = 3
    for c in v3["cells"]:
        c["quality"] = {"stability": {"skipped": "zero generations"},
                        "rank": {"skipped": "rank_probe disabled"}}
        c["blocks"] = None  # binary cell: feature not applicable
    traj = sw.append_point(path, v3)

    # ISSUE 10: v4 points add per-cell search throughput (genomes/sec)
    # and append cleanly after the v1/v2/v3 history
    v4 = _point({CID_A: 0.80}, ts="2026-01-04T00:00:00Z")
    v4["v"] = sw.SWEEP_POINT_VERSION
    for c in v4["cells"]:
        c["quality"] = {"stability": {"skipped": "zero generations"},
                        "rank": {"skipped": "rank_probe disabled"}}
        c["blocks"] = None
        if isinstance(c.get("search"), dict):
            c["search"]["throughput"] = 4200.0
    traj = sw.append_point(path, v4)
    assert traj.points == [v1, v2, v3, v4]
    # the file-level schema version did not move — old readers still load
    d = json.loads((tmp_path / "BENCH_sweep.json").read_text())
    assert d["v"] == sw.SWEEP_SCHEMA_VERSION == 1

    # a v2 point without per-cell quality is invalid...
    bad = _point({CID_A: 0.8})
    bad["v"] = 2
    with pytest.raises(ValueError, match="quality"):
        sw.validate_point(bad)
    # ...a v3 point without per-cell blocks is invalid...
    bad = _point({CID_A: 0.8})
    bad["v"] = 3
    for c in bad["cells"]:
        c["quality"] = {"stability": {"skipped": "x"}, "rank": {}}
    with pytest.raises(ValueError, match="blocks"):
        sw.validate_point(bad)
    # ...but the same shape as an (implicit) v1 point stays valid
    sw.validate_point(_point({CID_A: 0.8}))


def test_run_sweep_emits_v4_points_with_quality_blocks_throughput(tmp_path):
    cell = sw.SweepCell("himeno", "quadro-p4000", "binary")
    p = sw.run_sweep([cell], out_dir=str(tmp_path / "sweep"), smoke=True)
    assert p["v"] == sw.SWEEP_POINT_VERSION == 4
    q = p["cells"][0]["quality"]
    assert q is not None
    assert q["stability"]["k"] >= 2 and 0.0 <= q["stability"]["pass_at_k"] <= 1.0
    # binary cells never run the block matcher: summary present but None
    assert p["cells"][0]["blocks"] is None
    # v4: modeled-search throughput lands in every ok cell's search
    # summary (the fast-search knobs' headline number)
    s = p["cells"][0]["search"]
    assert s["throughput"] is None or s["throughput"] > 0
    sw.validate_point(p)


# ---------------------------------------------------------------------------
# regression flagging
# ---------------------------------------------------------------------------


def test_regression_tolerance_edges():
    prev = _point({CID_A: 1.0})
    tol = 0.05
    # exactly AT the boundary: not a regression (strictly-beyond flags)
    at_edge = _point({CID_A: 1.0 * (1 + tol)})
    assert sw.flag_regressions(prev, at_edge, tol) == []
    # one ulp beyond: flagged
    beyond = _point({CID_A: math.nextafter(1.0 * (1 + tol), 2.0)})
    flags = sw.flag_regressions(prev, beyond, tol)
    assert [f["id"] for f in flags] == [CID_A]
    assert flags[0]["prev_best_s"] == 1.0
    assert flags[0]["ratio"] > 1.05
    # improvements never flag, whatever their size
    assert sw.flag_regressions(prev, _point({CID_A: 0.01}), tol) == []


def test_regression_skips_failed_and_new_cells():
    prev = _point({CID_A: 1.0,
                   CID_B: _cell(CID_B, None, status="failed")})
    # CID_B failed before: its (now-ok) time has no baseline to regress
    # from; a brand-new cell id likewise
    new = _point({CID_A: 1.0, CID_B: 99.0,
                  "nasft:quadro-p4000:binary": 123.0})
    assert sw.flag_regressions(prev, new, 0.05) == []
    # a cell that FAILED in the new point is a failure, not a regression
    new2 = _point({CID_A: _cell(CID_A, None, status="failed")})
    assert sw.flag_regressions(prev, new2, 0.05) == []


def test_regression_no_previous_point_and_bad_tolerance():
    assert sw.flag_regressions(None, _point({CID_A: 9.0})) == []
    with pytest.raises(ValueError, match="rel_tolerance"):
        sw.flag_regressions(_point({CID_A: 1.0}), _point({CID_A: 1.0}),
                            rel_tolerance=-0.1)


# ---------------------------------------------------------------------------
# matrix enumeration + cell specs
# ---------------------------------------------------------------------------


def test_matrix_covers_the_whole_cross_product():
    programs = sw.default_programs()
    machines = sw.default_machines()
    cells, skipped = sw.enumerate_matrix(programs, machines)
    assert len(cells) + len(skipped) == len(programs) * len(machines) * 2
    ids = {c.id for c in cells} | {s["id"] for s in skipped}
    assert len(ids) == len(cells) + len(skipped)  # no dup, no overlap
    # every skip carries a reason; arch programs never appear mixed
    assert all(s["reason"] for s in skipped)
    assert not any(c.program.startswith("arch:") and c.mode == "mixed"
                   for c in cells)


def test_matrix_validates_inputs():
    with pytest.raises(ValueError, match="unknown programs"):
        sw.enumerate_matrix(["nope"], None)
    with pytest.raises(ValueError, match="unknown machines"):
        sw.enumerate_matrix(None, ["nope"])
    with pytest.raises(ValueError, match="unknown mode"):
        sw.enumerate_matrix(None, None, ("ternary",))


def test_cell_spec_budgets_and_destinations():
    mixed = sw.cell_spec(sw.SweepCell("hetero", "tpu-v5e-host", "mixed"),
                         smoke=True, cache="/tmp/c.jsonl")
    # the machine's full destination set, host first
    assert mixed.destinations == ("cpu", "tpu0", "tpu1")
    assert (mixed.population, mixed.generations) == MIXED_SMOKE_BUDGET
    assert mixed.warm_start and mixed.cache == "/tmp/c.jsonl"
    # mixed cells search with the block-substitution dimension on
    # (docs/blocks.md); v3 points record what it bought per cell
    assert mixed.blocks
    full = sw.cell_spec(sw.SweepCell("hetero", "quadro-p4000", "mixed"))
    assert full.population is None  # spec default = full MIXED_BUDGET
    assert full.blocks
    binary = sw.cell_spec(sw.SweepCell("himeno", "quadro-p4000", "binary"))
    assert binary.mode == "binary" and not binary.warm_start
    assert not binary.blocks  # blocks is a mixed-mode feature


# ---------------------------------------------------------------------------
# the driver: resume-mid-sweep
# ---------------------------------------------------------------------------


def _progress_sink(lines):
    return lines.append


def test_resume_mid_sweep_skips_completed_cells(tmp_path):
    out_dir = str(tmp_path / "sweep")
    a = sw.SweepCell("himeno", "quadro-p4000", "binary")
    b = sw.SweepCell("arch:stablelm-3b", "quadro-p4000", "binary")
    p1 = sw.run_sweep([a], out_dir=out_dir, smoke=True)
    assert p1["cells"][0]["status"] == "ok"
    assert p1["cells"][0]["fresh_measurements"] > 0
    # a killed sweep re-invoked over the full matrix: the completed cell
    # is skipped outright — zero fresh measurements — and only the new
    # cell pays
    p2 = sw.run_sweep([a, b], out_dir=out_dir, smoke=True)
    rec_a, rec_b = p2["cells"]
    assert rec_a["resumed"] and rec_a["fresh_measurements"] == 0
    assert rec_a["best_time_s"] == p1["cells"][0]["best_time_s"]
    assert not rec_b["resumed"] and rec_b["fresh_measurements"] > 0
    assert p2["totals"]["n_resumed"] == 1
    sw.validate_point(p2)


def test_sweep_survives_a_failing_cell(tmp_path, monkeypatch):
    # a cell whose pipeline raises must be recorded, not lose the sweep
    out_dir = str(tmp_path / "sweep")
    a = sw.SweepCell("himeno", "quadro-p4000", "binary")
    bad = sw.SweepCell("nasft", "quadro-p4000", "binary")

    def boom(self, name):
        if self.spec.program == "nasft" and name == "search":
            raise RuntimeError("injected")
        return orig(self, name)

    from repro.offload.pipeline import Offloader
    orig = Offloader.run_stage
    monkeypatch.setattr(Offloader, "run_stage", boom)
    point = sw.run_sweep([bad, a], out_dir=out_dir, smoke=True)
    rec_bad, rec_a = point["cells"]
    assert rec_bad["status"] == "failed" and "injected" in rec_bad["error"]
    assert rec_a["status"] == "ok"  # the sweep finished the matrix
    assert point["totals"]["n_failed"] == 1
    sw.validate_point(point)


# ---------------------------------------------------------------------------
# CLI end to end
# ---------------------------------------------------------------------------


def _smoke_argv(tmp_path, *extra):
    return ["sweep", "--smoke", "--quiet",
            "--dir", str(tmp_path / "cells"),
            "--out", str(tmp_path / "BENCH_sweep.json"), *extra]


def test_cli_smoke_twice_appends_and_renders_deltas(tmp_path, capsys):
    assert main(_smoke_argv(tmp_path)) == 0
    d = json.loads((tmp_path / "BENCH_sweep.json").read_text())
    assert d["schema"] == sw.SWEEP_SCHEMA and len(d["points"]) == 1
    for c in d["points"][0]["cells"]:
        assert c["status"] == "ok" and c["best_time_s"] > 0
    capsys.readouterr()

    # second invocation: all cells resume complete, a second point
    # appends, and the leaderboard shows per-cell deltas vs point 1
    assert main(_smoke_argv(tmp_path)) == 0
    out = capsys.readouterr().out
    d = json.loads((tmp_path / "BENCH_sweep.json").read_text())
    assert len(d["points"]) == 2
    p2 = d["points"][1]
    assert all(c["resumed"] and c["fresh_measurements"] == 0
               for c in p2["cells"])
    assert "BENCH leaderboard" in out
    assert "+0.0%" in out  # deterministic searches: delta exactly zero
    assert "regressions (tolerance 5%): none" in out


def test_cli_injected_regression_exits_3(tmp_path, capsys):
    assert main(_smoke_argv(tmp_path)) == 0
    # tamper the recorded point: pretend the previous sweep was 2x
    # faster, so the (identical) re-run reads as a regression
    path = tmp_path / "BENCH_sweep.json"
    d = json.loads(path.read_text())
    for c in d["points"][0]["cells"]:
        c["best_time_s"] *= 0.5
    path.write_text(json.dumps(d))
    capsys.readouterr()
    assert main(_smoke_argv(tmp_path)) == 3
    out = capsys.readouterr().out
    assert "REGRESSIONS" in out and "2.000x" in out
    # report-only re-reads the saved trajectory and agrees
    assert main(["sweep", "--report-only",
                 "--out", str(path)]) == 3
    # ...and a loose tolerance un-flags it
    assert main(["sweep", "--report-only", "--tolerance", "1.5",
                 "--out", str(path)]) == 0


def test_cli_report_only_on_empty_trajectory(tmp_path, capsys):
    assert main(["sweep", "--report-only",
                 "--out", str(tmp_path / "none.json")]) == 0
    assert "empty" in capsys.readouterr().out


def test_cli_no_append_leaves_trajectory_untouched(tmp_path):
    assert main(_smoke_argv(tmp_path)) == 0
    before = (tmp_path / "BENCH_sweep.json").read_text()
    assert main(_smoke_argv(tmp_path, "--no-append")) == 0
    assert (tmp_path / "BENCH_sweep.json").read_text() == before


def test_exit_codes_table_matches_cli_behavior():
    # the sweep verdicts asserted above are the documented ones
    codes = {c for c, _ in EXIT_CODES["sweep"]}
    assert codes == {0, 1, 2, 3}
