"""Function-block offloading (src/repro/blocks/, docs/blocks.md):
library + matcher + substitution evaluator units, the blocks-off parity
contract, the calibration hook, and the ISSUE-8 acceptance surface —
the blocks-on search strictly beating the loop-level best with the
winner's substitutions oracle-checked in verify and visible in report
and trace.
"""
import json

import pytest

from repro.blocks import (
    BlockMixedEvaluator,
    default_library,
    fused_loop,
    internal_vars,
    match_blocks,
    register_kernel_gains,
    substituted_program,
)
from repro.blocks.library import KernelLibrary
from repro.core import miniapps
from repro.destinations.mixed import MixedEvaluator
from repro.offload import Offloader, OffloadSpec
from repro.offload import trace as tr
from repro.offload.programs import MiniappMixedAdapter, resolve_adapter

HETERO = miniapps.hetero_program()
LIB = default_library()

# the two hetero chains the default library matches (asserted exactly:
# the matcher is deterministic and these names anchor docs/blocks.md)
FLASH_CHAIN = ("load_frame", "stencil_a", "stencil_b")
SSD_CHAIN = ("scan_stage1", "scan_stage2", "scan_stage3", "scan_stage4")


# ---------------------------------------------------------------------------
# library
# ---------------------------------------------------------------------------


def test_library_lookup_and_fingerprint():
    assert LIB.get("flash_attention").impl == "flash_attention"
    with pytest.raises(KeyError):
        LIB.get("nope")
    assert LIB.fingerprint().startswith("kernlib-")
    # gains are priced, so they must move the fingerprint
    register_kernel_gains("test-hw-x", {"flash_attention": 2.0})
    assert default_library(hw="test-hw-x").fingerprint() != LIB.fingerprint()
    assert default_library(hw="test-hw-x").get("flash_attention").gain == 2.0
    # unknown hw: stock gains
    assert default_library(hw="no-such-hw").fingerprint() == LIB.fingerprint()


def test_library_rejects_duplicates_and_bad_gain():
    e = LIB.get("flash_attention")
    with pytest.raises(AssertionError):
        KernelLibrary((e, e))
    import dataclasses
    with pytest.raises(AssertionError):
        dataclasses.replace(e, gain=0.0)


# ---------------------------------------------------------------------------
# matching the real miniapps
# ---------------------------------------------------------------------------


def test_match_hetero_exact():
    matches = match_blocks(HETERO, LIB)
    assert [(m.entry, m.loops) for m in matches] == [
        ("flash_attention", FLASH_CHAIN),
        ("ssd_scan", SSD_CHAIN),
    ]
    assert all(m.parent_seq == "frame_iter" for m in matches)


def test_match_other_miniapps():
    # the matcher generalizes beyond the program it was designed around:
    # himeno's stencil+copy pair and nasft's per-dimension fft chains
    # are library-shaped too
    himeno = match_blocks(miniapps.himeno_program(), LIB)
    assert [(m.entry, m.loops) for m in himeno] == [
        ("flash_attention", ("jacobi_stencil", "jacobi_copy")),
    ]
    nasft = match_blocks(miniapps.nasft_program(), LIB)
    assert len(nasft) == 4
    assert all(m.entry == "flash_attention" for m in nasft)


# ---------------------------------------------------------------------------
# substitution: fused nest + variant program
# ---------------------------------------------------------------------------


def test_internal_vars_and_fused_loop():
    flash = match_blocks(HETERO, LIB)[0]
    entry = LIB.get(flash.entry)
    by_name = {l.name: l for l in HETERO.loops}
    chain = [by_name[n] for n in flash.loops]
    internal = internal_vars(HETERO, flash)
    chain_writes = frozenset().union(*(l.writes for l in chain))
    assert internal <= chain_writes
    # internal means exactly: no loop outside the chain touches it
    outside = [l for l in HETERO.loops if l.name not in flash.loops]
    for v in internal:
        assert not any(v in l.touched() for l in outside)
    fused = fused_loop(HETERO, flash, entry)
    assert fused.name == "block:flash_attention:load_frame"
    assert not fused.sequential_carry and fused.trip == 1
    assert fused.parent_seq == chain[0].parent_seq
    assert fused.total_flops == pytest.approx(
        sum(l.total_flops for l in chain) / entry.gain
    )
    assert not (fused.reads & internal) and not (fused.writes & internal)


def test_substituted_program_collapses_chain():
    flash = match_blocks(HETERO, LIB)[0]
    sub = substituted_program(HETERO, [(flash, LIB.get(flash.entry))])
    assert len(sub.loops) == len(HETERO.loops) - len(flash.loops) + 1
    names = [l.name for l in sub.loops]
    assert "block:flash_attention:load_frame" in names
    assert not (set(flash.loops) & set(names))
    # a different program must never share fitness-cache identity
    assert sub.fingerprint() != HETERO.fingerprint()


# ---------------------------------------------------------------------------
# the evaluator: genome semantics, pricing, cache identity
# ---------------------------------------------------------------------------


def _ev() -> BlockMixedEvaluator:
    return BlockMixedEvaluator(HETERO)  # cpu, gpu, fpga


def test_genome_layout_and_eligibility_clamp():
    e = _ev()
    assert e.gene_length == HETERO.gene_length + 2
    assert e.k == 3
    loops = (0,) * HETERO.gene_length
    # flash_attention lists gpu/tpu kinds only: the fpga allele (2)
    # clamps to 0; ssd_scan lists fpga too, so it keeps its allele
    assert e.admissible(loops + (2, 2))[-2:] == (0, 2)
    assert e.admissible(loops + (1, 1))[-2:] == (1, 1)


def test_inactive_blocks_price_exactly_like_the_base_evaluator():
    e = _ev()
    base = MixedEvaluator(HETERO)
    for genes in ((0,) * 12, (1,) * 12, (1, 0, 1, 2, 1, 2, 2, 2, 2, 2, 2, 0)):
        assert e(genes + (0, 0)) == base(genes)
    assert e.fingerprint() != base.fingerprint()
    assert e.fingerprint().startswith("blocks:")
    assert e.host_only_time() == base.host_only_time()


def test_substitution_strictly_beats_loop_level_pricing():
    e = _ev()
    all_gpu = (1,) * 12
    assert e(all_gpu + (1, 1)) < e(all_gpu + (0, 0))


def test_placement_and_substitution_rows():
    e = _ev()
    genes = (1,) * 12 + (1, 1)
    place = e.placement(genes)
    for name in FLASH_CHAIN + SSD_CHAIN:
        assert place[name] == "gpu"
    rows = e.substitutions(genes)
    assert [(r["entry"], r["active"], r["destination"]) for r in rows] == [
        ("flash_attention", True, "gpu"), ("ssd_scan", True, "gpu"),
    ]
    rows0 = e.substitutions((1,) * 12 + (0, 0))
    assert all(not r["active"] and r["destination"] is None for r in rows0)


def test_cache_keys_cover_block_decisions_and_ignore_dead_genes():
    e = _ev()
    loops = (0,) * 12
    k_off = e.cache_key(loops + (0, 0))
    k_on = e.cache_key(loops + (1, 1))
    assert k_off != k_on and "|blocks=" in k_off
    # genomes differing only in a covered loop's (dead) gene share a key
    head, dead = list(loops), list(loops)
    dead[2] = 2  # load_frame: covered by the active flash block
    assert e.cache_key(tuple(head) + (1, 1)) == \
        e.cache_key(tuple(dead) + (1, 1))
    # ...but NOT when the block is inactive (the gene is live again)
    assert e.cache_key(tuple(head) + (0, 1)) != \
        e.cache_key(tuple(dead) + (0, 1))


# ---------------------------------------------------------------------------
# spec + adapter: the blocks-off parity contract
# ---------------------------------------------------------------------------


def test_spec_blocks_is_mixed_only_and_serializes_sparsely():
    with pytest.raises(ValueError, match="mixed"):
        OffloadSpec(program="himeno", mode="binary", blocks=True)
    off = OffloadSpec(program="hetero", mode="mixed")
    assert not off.blocks
    # unset => absent from the dict: pre-blocks artifacts and digests
    # round-trip byte-identically
    assert "blocks" not in off.to_dict()
    assert OffloadSpec.from_dict(off.to_dict()) == off
    on = OffloadSpec(program="hetero", mode="mixed", blocks=True)
    assert on.to_dict()["blocks"] is True
    assert OffloadSpec.from_dict(on.to_dict()) == on


def test_adapter_parity_when_blocks_off():
    spec = OffloadSpec(program="hetero", mode="mixed")
    adapter = resolve_adapter(spec)
    ev = adapter.build_evaluator()
    assert isinstance(ev, MixedEvaluator)
    assert not ev.fingerprint().startswith("blocks:")
    assert adapter.gene_length == HETERO.gene_length
    assert "blocks" not in adapter.analyze_payload()
    assert adapter.substitutions((0,) * adapter.gene_length) is None


def test_adapter_blocks_on_wires_the_evaluator():
    spec = OffloadSpec(program="hetero", mode="mixed", blocks=True)
    adapter = resolve_adapter(spec)
    assert isinstance(adapter.build_evaluator(), BlockMixedEvaluator)
    assert adapter.gene_length == HETERO.gene_length + 2
    payload = adapter.analyze_payload()
    assert [m["entry"] for m in payload["blocks"]["matches"]] == [
        "flash_attention", "ssd_scan"
    ]
    # the warm-start sub-searches carry the block genes too
    sub = adapter.sub_evaluator(("cpu", "gpu"))
    assert isinstance(sub, BlockMixedEvaluator)
    assert sub.gene_length == adapter.gene_length


def test_adapter_zero_matches_falls_back_to_plain_evaluator(monkeypatch):
    # a program without library-shaped chains must search byte-
    # identically to a blocks-off run even when the flag is set
    monkeypatch.setattr("repro.blocks.match_blocks", lambda p, lib: ())
    spec = OffloadSpec(program="hetero", mode="mixed", blocks=True)
    adapter = MiniappMixedAdapter(spec, None)
    ev = adapter.build_evaluator()
    assert isinstance(ev, MixedEvaluator)
    assert ev.fingerprint() == MixedEvaluator(HETERO).fingerprint()
    assert adapter.analyze_payload()["blocks"]["matches"] == []


# ---------------------------------------------------------------------------
# calibration: fitted per-kernel gains
# ---------------------------------------------------------------------------


def _fake_probe_measure(p, repeats):
    from repro.offload.calibrate import _probe_program, _region_quantities

    f, b, c = _region_quantities(_probe_program(p))
    return f / 1e9 + (b / 5e9 + c * 1e-4 if p.dest == "accel" else 0.0)


def test_calibration_fits_and_installs_kernel_gains():
    from repro.offload import calibrate as cal_mod

    kw = dict(base="quadro-p4000", repeats=1, name="blocks-test-cal",
              measure=_fake_probe_measure)
    plain = cal_mod.run_calibration(**kw)
    assert plain.kernel_constants == {}
    assert "kernel_constants" not in plain.to_dict()  # old files unchanged

    cal = cal_mod.run_calibration(
        **kw, kernels=True, kernel_measure=lambda entry: (3.0, 1.0)
    )
    assert cal.kernel_constants == {"flash_attention": 3.0, "ssd_scan": 3.0}
    # kernel gains are priced, so they must shift the cache identity
    assert cal.digest != plain.digest
    rt = cal_mod.CalibrationResult.from_dict(
        json.loads(json.dumps(cal.to_dict()))
    )
    assert rt.kernel_constants == cal.kernel_constants
    assert rt.digest == cal.digest

    cal_mod.install(cal)
    lib = default_library(hw=cal.name)
    assert {e.name: e.gain for e in lib.entries} == cal.kernel_constants
    assert lib.fingerprint() != LIB.fingerprint()


# ---------------------------------------------------------------------------
# acceptance: the pipeline end to end
# ---------------------------------------------------------------------------


def _smoke_spec(blocks: bool) -> OffloadSpec:
    return OffloadSpec(program="hetero", mode="mixed", blocks=blocks,
                       population=10, generations=8, warm_start=True)


def test_blocks_search_strictly_beats_loop_level_search():
    res_off = Offloader(_smoke_spec(False)).run(until="search")
    res_on = Offloader(_smoke_spec(True)).run(until="search")
    assert res_on.best_time_s < res_off.best_time_s
    subs = res_on.stage("search").payload["substitutions"]
    assert any(s["active"] for s in subs)
    # blocks-off searches must not even carry the key
    assert "substitutions" not in res_off.stage("search").payload


def test_full_pipeline_verifies_reports_and_traces_substitutions(tmp_path):
    art = str(tmp_path / "blocks.offload.json")
    res = Offloader(_smoke_spec(True), artifact_path=art).run()

    oracles = res.stage("verify").payload["block_oracles"]
    assert oracles and all(r["ok"] for r in oracles)
    assert {r["kernel"] for r in oracles} <= {"flash_attention", "ssd_scan"}
    assert all(r["max_abs_err"] <= r["tol"] for r in oracles)

    text = res.stage("report").payload["text"]
    assert "blocks substituted" in text and "[ssd_scan]" in text
    assert "block oracles:" in text and "PASS" in text

    trace = tr.load_trace(tr.default_trace_path(art))
    match_events = [e for e in trace.events("analyze")
                    if e["name"] == "block_match"]
    sub_events = [e for e in trace.events("verify")
                  if e["name"] == "block_substitution"]
    assert len(match_events) == 2
    assert sub_events and all(e["attrs"]["oracle_ok"] for e in sub_events)
    rendered = tr.render_trace(trace, res)
    assert "block [" in rendered and "oracle PASS" in rendered


def test_blocks_off_pipeline_has_no_block_artifacts(tmp_path):
    art = str(tmp_path / "plain.offload.json")
    res = Offloader(_smoke_spec(False), artifact_path=art).run()
    assert "block_oracles" not in res.stage("verify").payload
    assert "blocks" not in res.stage("analyze").payload
    assert "block" not in res.stage("report").payload["text"]
    trace = tr.load_trace(tr.default_trace_path(art))
    assert not [e for e in trace.events()
                if e["name"].startswith("block_")]
