"""moe_permute (row-gather kernel + gather-only custom vjp) correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.gather_rows import gather_rows_pallas


def _manual_gather(src, idx):
    out = np.zeros((idx.shape[0], idx.shape[1], src.shape[-1]), src.dtype)
    for g in range(idx.shape[0]):
        for i, r in enumerate(idx[g]):
            if r >= 0:
                out[g, i] = src[g, r]
    return out


@pytest.mark.parametrize("block_rows", [4, 8])
def test_gather_rows_pallas_vs_manual(rng, block_rows):
    src = jnp.asarray(rng.normal(size=(23, 32)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, 23, size=(17,)), jnp.int32)
    out = gather_rows_pallas(src, idx, block_rows=block_rows, interpret=True)
    want = _manual_gather(
        np.asarray(src)[None], np.asarray(idx)[None]
    )[0]
    np.testing.assert_array_equal(np.asarray(out), want)


def test_moe_permute_forward(rng):
    src = jnp.asarray(rng.normal(size=(2, 10, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, 10, size=(2, 6)), jnp.int32)
    inv = jnp.full((2, 10), -1, jnp.int32)  # unused in fwd
    out = ops.moe_permute(src, idx, inv, 1)
    want = _manual_gather(np.asarray(src), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(out), want)


def test_moe_permute_round_trip_gradient(rng):
    """Dispatch/combine pair: gradient of a loss through the permutation
    equals the autodiff gradient of the equivalent dense gather."""
    G, T, k, d = 1, 6, 2, 4
    E, cap = 3, 4  # capacity ample: nothing drops
    eids = np.array([[0, 1], [1, 2], [0, 0], [2, 1], [1, 0], [2, 2]])
    # build indices exactly like moe_apply.route
    flat_e = eids.reshape(-1)
    order = np.argsort(flat_e, kind="stable")
    sorted_e = flat_e[order]
    counts = np.bincount(flat_e, minlength=E)
    starts = np.cumsum(counts) - counts
    pos = np.arange(T * k) - starts[sorted_e]
    keep = pos < cap
    slot = np.where(keep, sorted_e * cap + pos, E * cap)
    src_tok = order // k
    buf_src = np.full((E * cap + 1,), -1, np.int64)
    buf_src[slot] = src_tok
    buf_src = buf_src[: E * cap]
    slot_of_flat = np.zeros((T * k,), np.int64)
    slot_of_flat[order] = slot
    tok_slots = np.where(slot_of_flat < E * cap, slot_of_flat, -1)
    flat_of_slot = np.full((E * cap + 1,), -1, np.int64)
    flat_of_slot[slot] = order  # flat id at sorted position p is order[p]
    flat_of_slot = flat_of_slot[: E * cap]

    x = jnp.asarray(rng.normal(size=(G, T, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
    bs = jnp.asarray(buf_src[None], jnp.int32)
    ts = jnp.asarray(tok_slots[None], jnp.int32)
    fs = jnp.asarray(flat_of_slot[None], jnp.int32)

    def loss_permute(x):
        buf = ops.moe_permute(x, bs, ts, k)  # dispatch
        yb = buf @ w  # "expert" compute
        y = ops.moe_permute(yb, ts, fs, 1)  # combine
        return (y**2).sum()

    def loss_dense(x):
        buf = jnp.where(
            (bs >= 0)[..., None], x[0][jnp.maximum(bs[0], 0)][None], 0.0
        )
        yb = buf @ w
        y = jnp.where(
            (ts >= 0)[..., None], yb[0][jnp.maximum(ts[0], 0)][None], 0.0
        )
        return (y**2).sum()

    v1, g1 = jax.value_and_grad(loss_permute)(x)
    v2, g2 = jax.value_and_grad(loss_dense)(x)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


def test_moe_permute_dropped_tokens_zero_grad(rng):
    """Tokens dropped by capacity get zero gradient (not NaN/garbage)."""
    x = jnp.asarray(rng.normal(size=(1, 4, 3)), jnp.float32)
    out_idx = jnp.asarray([[0, 1]], jnp.int32)  # only tokens 0,1 dispatched
    inv = jnp.asarray([[0, 1, -1, -1]], jnp.int32)  # tokens 2,3 dropped

    def loss(x):
        return ops.moe_permute(x, out_idx, inv, 1).sum()

    g = jax.grad(loss)(x)
    np.testing.assert_array_equal(np.asarray(g[0, 2:]), 0.0)
    np.testing.assert_array_equal(np.asarray(g[0, :2]), 1.0)
