"""Property tests for the block matcher (src/repro/blocks/match.py):
whatever program shape the generator produces, matching must be
deterministic, matches must be non-overlapping consecutive runs with
the entry's atom and length floor, every adjacent pair must be
dataflow-linked, and chains must be forward-maximal.

Runs under hypothesis when available; the container image may not ship
it, so a deterministic seeded-case fallback drives the same property
checkers either way (no new dependencies — the ISSUE's constraint).
"""
import random

import pytest

from repro.blocks.library import default_library, loop_atom
from repro.blocks.match import match_blocks
from repro.core.loopir import Loop, LoopClass, LoopProgram, SeqRegion, Var

try:  # hypothesis is optional; the fallback below covers its absence
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False


KLASSES = (LoopClass.TIGHT, LoopClass.NON_TIGHT, LoopClass.VECTOR_ONLY,
           LoopClass.NOT_OFFLOADABLE)
MAX_LOOPS = 10

# one blueprint row per loop: (klass index, sequential carry, in the
# "t" region vs region-free, reads the previous loop's output)
Blueprint = "list of (int, bool, bool, bool)"


def program_from_blueprint(blueprint) -> LoopProgram:
    """A synthetic LoopProgram whose chain structure is fully determined
    by the blueprint, so the generators explore every matcher branch:
    atom runs of every length, broken dataflow links, region boundaries,
    and non-offloadable interruptions."""
    loops = []
    for i, (ki, carry, in_region, linked) in enumerate(blueprint):
        reads = {"x"}
        if linked and i > 0:
            reads.add(f"v{i - 1}")
        loops.append(Loop(
            name=f"l{i}",
            klass=KLASSES[ki % len(KLASSES)],
            trip=8,
            inner_trip=4,
            flops_per_iter=2.0,
            reads=frozenset(reads),
            writes=frozenset({f"v{i}"}),
            parent_seq="t" if in_region else None,
            sequential_carry=bool(carry),
        ))
    vars_ = (Var("x", 1024),) + tuple(
        Var(f"v{i}", 1024) for i in range(len(blueprint))
    )
    return LoopProgram(
        name="synthetic", loops=tuple(loops), vars=vars_,
        seq_regions=(SeqRegion("t", 3),),
    )


# ---------------------------------------------------------------------------
# property checkers (shared by the hypothesis and fallback drivers)
# ---------------------------------------------------------------------------


def check_match_properties(blueprint):
    prog = program_from_blueprint(blueprint)
    lib = default_library()
    matches = match_blocks(prog, lib)

    # deterministic: same inputs, same matches, every time
    assert match_blocks(prog, lib) == matches

    by_name = {l.name: l for l in prog.loops}
    index = {l.name: i for i, l in enumerate(prog.loops)}
    all_covered = {n for m in matches for n in m.loops}
    seen = set()
    for m in matches:
        entry = lib.get(m.entry)
        # length floor and non-overlap
        assert len(m.loops) >= entry.signature.min_len
        assert not (set(m.loops) & seen)
        seen.update(m.loops)
        # consecutive in program order
        idxs = [index[n] for n in m.loops]
        assert idxs == list(range(idxs[0], idxs[-1] + 1))
        chain = [by_name[n] for n in m.loops]
        # every loop carries the entry's atom, is offloadable, and
        # shares the chain's sequential region
        for l in chain:
            assert loop_atom(l) == entry.signature.atom == m.atom
            assert l.offloadable
            assert l.parent_seq == m.parent_seq == chain[0].parent_seq
        # adjacent loops are dataflow-linked
        for prev, nxt in zip(chain, chain[1:]):
            assert nxt.reads & prev.writes
        # forward-maximal: the loop after the chain (if any) cannot
        # extend it — it is consumed elsewhere or breaks a condition
        j = idxs[-1] + 1
        if j < len(prog.loops):
            nxt = prog.loops[j]
            assert (
                nxt.name in all_covered
                or not nxt.offloadable
                or loop_atom(nxt) != m.atom
                or nxt.parent_seq != m.parent_seq
                or not (nxt.reads & chain[-1].writes)
            )


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def _fallback_blueprints(n_cases=200):
    rng = random.Random(0xB10C5)
    cases = [
        [],  # empty program
        [(0, False, True, True)],  # single loop: below every min_len
        # a clean flash_attention chain and a clean ssd_scan chain
        [(0, False, True, True)] * 3 + [(2, True, True, True)] * 4,
    ]
    for _ in range(n_cases):
        n = rng.randrange(0, MAX_LOOPS + 1)
        cases.append([
            (rng.randrange(4), rng.random() < 0.5,
             rng.random() < 0.7, rng.random() < 0.8)
            for _ in range(n)
        ])
    return cases


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(st.lists(
        st.tuples(st.integers(0, 3), st.booleans(), st.booleans(),
                  st.booleans()),
        min_size=0, max_size=MAX_LOOPS,
    ))
    def test_match_properties(blueprint):
        check_match_properties(blueprint)

else:

    @pytest.mark.parametrize("blueprint", _fallback_blueprints())
    def test_match_properties(blueprint):
        check_match_properties(blueprint)


# ---------------------------------------------------------------------------
# pinned edge cases (identical under either driver)
# ---------------------------------------------------------------------------


def test_zero_matches_on_library_shape_free_program():
    """Alternating atoms: every same-atom run has length 1, below every
    library entry's min_len — the matcher must propose nothing, which is
    what keeps blocks-enabled runs byte-identical on programs without
    library-shaped chains."""
    blueprint = [(0, False, True, True), (2, True, True, True)] * 3
    prog = program_from_blueprint(blueprint)
    assert match_blocks(prog, default_library()) == ()


def test_broken_dataflow_splits_a_run():
    """Six tight loops where the middle link is severed: the matcher
    must emit two 3-loop chains, not one 6-loop chain."""
    blueprint = [(0, False, True, i != 3) for i in range(6)]
    prog = program_from_blueprint(blueprint)
    matches = match_blocks(prog, default_library())
    assert [m.loops for m in matches] == [
        ("l0", "l1", "l2"), ("l3", "l4", "l5")
    ]


def test_region_boundary_splits_a_run():
    """A region change between l1 and l2 breaks the chain even though
    atoms and dataflow continue."""
    blueprint = [(0, False, True, True), (0, False, True, True),
                 (0, False, False, True), (0, False, False, True)]
    prog = program_from_blueprint(blueprint)
    matches = match_blocks(prog, default_library())
    assert [m.loops for m in matches] == [("l0", "l1"), ("l2", "l3")]


def test_non_offloadable_loop_interrupts_a_chain():
    blueprint = [(0, False, True, True), (0, False, True, True),
                 (3, False, True, True),  # NOT_OFFLOADABLE
                 (0, False, True, True), (0, False, True, True)]
    prog = program_from_blueprint(blueprint)
    matches = match_blocks(prog, default_library())
    assert [m.loops for m in matches] == [("l0", "l1"), ("l3", "l4")]
