"""repro.offload: spec/artifact lifecycle, stage semantics, CLI, and
byte-identical parity of the facade's searches with the pre-redesign
hand-wired paths (the acceptance bar of the API redesign)."""
import json

import pytest

from repro.core import evaluator as ev
from repro.core import evalpool as ep
from repro.core import ga, miniapps
from repro.core import transfer as tr
from repro.offload import (
    Offloader,
    OffloadResult,
    OffloadSpec,
    StageFailure,
)
from repro.offload.__main__ import main as cli_main


# ---------------------------------------------------------------------------
# parity with the pre-redesign wiring
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("app,method", [
    ("himeno", "proposed"),
    ("himeno", "previous"),
    ("nasft", "proposed"),
])
def test_binary_search_parity(app, method):
    """Offloader reproduces the old fig4/fig5 wiring byte-identically:
    same evaluator, same paper-rule GAParams, same RNG stream."""
    from repro.offload.spec import METHODS

    prog = miniapps.MINIAPPS[app]()
    n = prog.gene_length
    kw = METHODS[method]
    e = ev.MiniappEvaluator(
        prog, tr.TransferMode(kw["transfer"]), staged=kw["staged"],
        kernels_only=kw["kernels_only"],
    )
    params = ga.GAParams.for_gene_length(n, seed=0)
    with ep.EvalPool(e) as pool:
        old = ga.run_ga(None, n, params, pool=pool)

    res = Offloader(
        OffloadSpec(program=app, mode="binary", method=method)
    ).run(until="search")
    assert res.best_genes == old.best_genes
    assert res.best_time_s == old.best_time_s
    # and the baseline matches the old scripts' cpu reference
    assert res.baseline_time_s == pytest.approx(
        ev.predict_time(prog, (0,) * n).total_s, rel=1e-12
    )


def test_mixed_search_parity():
    """Offloader reproduces the old fig_mixed_destinations wiring."""
    from repro.destinations import MixedEvaluator

    prog = miniapps.hetero_program()
    e = MixedEvaluator(prog, ("cpu", "gpu", "fpga"))
    params = ga.GAParams(population=10, generations=8, seed=0,
                         timeout_s=1e6, alleles=e.k)
    with ep.EvalPool(e) as pool:
        old = ga.run_ga(None, prog.gene_length, params, pool=pool)

    res = Offloader(
        OffloadSpec(program="hetero", mode="mixed",
                    population=10, generations=8)
    ).run(until="search")
    assert res.best_genes == old.best_genes
    assert res.best_time_s == old.best_time_s


def test_arch_search_parity():
    """The arch adapter reproduces the old ga_arch_search analytic path
    (same evaluator math, same min(n,10) budget)."""
    from repro.offload.programs import ArchPlanEvaluator

    e = ArchPlanEvaluator("stablelm-3b")
    n = Offloader(
        OffloadSpec(program="arch:stablelm-3b")
    ).adapter.gene_length
    params = ga.GAParams(population=min(n, 10), generations=min(n, 10),
                         seed=0, timeout_s=1e6)
    old = ga.run_ga(e, n, params)

    res = Offloader(OffloadSpec(program="arch:stablelm-3b")).run(
        until="search"
    )
    assert res.best_genes == old.best_genes
    assert res.best_time_s == old.best_time_s
    # fingerprint kept from the pre-redesign closure (cache continuity)
    assert e.fingerprint() == "analytic-plan:stablelm-3b"


def test_run_ga_no_seeds_is_byte_identical():
    """seeds=[] / None must not perturb the RNG stream."""
    e = ev.MiniappEvaluator(miniapps.himeno_program())
    params = ga.GAParams.for_gene_length(13, seed=3)
    a = ga.run_ga(e, 13, params)
    b = ga.run_ga(e, 13, params, seeds=[])
    assert a.best_genes == b.best_genes and a.best_time_s == b.best_time_s


def test_run_ga_seed_validation():
    e = ev.MiniappEvaluator(miniapps.himeno_program())
    params = ga.GAParams.for_gene_length(13, seed=0)
    with pytest.raises(ValueError, match="length"):
        ga.run_ga(e, 13, params, seeds=[(1, 0)])
    with pytest.raises(ValueError, match="alleles"):
        ga.run_ga(e, 13, params, seeds=[(7,) * 13])


# ---------------------------------------------------------------------------
# spec validation + serialization
# ---------------------------------------------------------------------------


def test_spec_json_roundtrip():
    spec = OffloadSpec(program="hetero", mode="mixed",
                       destinations=("cpu", "fpga"), population=5,
                       warm_start=True, cache="/tmp/x.jsonl", rel_tol=1e-4)
    assert OffloadSpec.from_json(spec.to_json()) == spec


@pytest.mark.parametrize("kw,msg", [
    (dict(program="himeno", mode="hybrid"), "mode"),
    (dict(program="himeno", method="bogus"), "method"),
    (dict(program="himeno", mode="mixed", destinations=("cpu",)),
     "destinations"),
    (dict(program="arch:stablelm-3b", mode="mixed"), "arch"),
    (dict(program="himeno", warm_start=True), "warm_start"),
    (dict(program="himeno", executor="fork"), "executor"),
])
def test_spec_validation(kw, msg):
    with pytest.raises(ValueError, match=msg):
        OffloadSpec(**kw)


def test_spec_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown"):
        OffloadSpec.from_dict({"program": "himeno", "wat": 1})


def test_unknown_program_fails_at_analyze():
    off = Offloader(OffloadSpec(program="nope"))
    with pytest.raises(ValueError, match="unknown miniapp"):
        off.run(until="analyze")
    assert off.result.stages["analyze"].status == "failed"


# ---------------------------------------------------------------------------
# artifact lifecycle: save -> reload -> resume
# ---------------------------------------------------------------------------


def _mixed_spec(tmp_path, **kw):
    kw.setdefault("population", 10)
    kw.setdefault("generations", 8)
    kw.setdefault("cache", str(tmp_path / "fitness.jsonl"))
    return OffloadSpec(program="hetero", mode="mixed", **kw)


def test_artifact_roundtrip(tmp_path):
    path = str(tmp_path / "art.json")
    spec = _mixed_spec(tmp_path)
    res = Offloader(spec, artifact_path=path).run(until="search")
    loaded = OffloadResult.load(path)
    assert loaded.spec == spec
    assert loaded.completed("analyze") and loaded.completed("search")
    assert loaded.best_genes == res.best_genes
    assert loaded.best_time_s == res.best_time_s
    assert loaded.stage("search").payload == res.stage("search").payload


def test_resume_skips_completed_stages(tmp_path):
    path = str(tmp_path / "art.json")
    spec = _mixed_spec(tmp_path)
    Offloader(spec, artifact_path=path).run(until="seed")

    # plant a sentinel: if resume re-ran analyze, it would be lost
    art = json.load(open(path))
    for st in art["stages"]:
        if st["name"] == "analyze":
            st["payload"]["sentinel"] = "untouched"
    json.dump(art, open(path, "w"))

    res = Offloader.resume(path).run()
    assert res.stage("analyze").payload["sentinel"] == "untouched"
    for stage in ("analyze", "seed", "search", "verify", "report"):
        assert res.completed(stage)


def test_killed_search_resumes_from_fitness_cache(tmp_path):
    """The acceptance criterion: a killed run resumed via the artifact
    reaches the same winner WITHOUT re-measuring cached individuals."""
    spec = _mixed_spec(tmp_path)
    first = Offloader(spec, artifact_path=str(tmp_path / "a.json")).run(
        until="search"
    )
    # simulate the kill: a fresh artifact for the same spec (the search
    # stage record was lost) but the fitness cache survived on disk
    second = Offloader(spec, artifact_path=str(tmp_path / "b.json")).run(
        until="search"
    )
    p = second.stage("search").payload
    assert second.best_genes == first.best_genes
    assert second.best_time_s == first.best_time_s
    assert p["evaluations"] == 0  # everything answered from the cache
    assert p["cache_resumed"] > 0


def test_artifact_version_guard(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"v": 99, "spec": {}, "stages": []}))
    with pytest.raises(ValueError, match="version"):
        OffloadResult.load(str(path))


# ---------------------------------------------------------------------------
# warm-start seeding (genome-aware, mixed mode)
# ---------------------------------------------------------------------------


def test_warm_start_seeds_recorded_and_win_gen0(tmp_path):
    spec = _mixed_spec(tmp_path, warm_start=True)
    res = Offloader(spec).run(until="search")
    seed_p = res.stage("seed").payload
    assert seed_p["warm_start"] and len(seed_p["seeds"]) == 2
    assert [i["device"] for i in seed_p["seed_info"]] == ["gpu", "fpga"]
    best_single = min(i["best_time_s"] for i in seed_p["seed_info"])
    history = res.stage("search").payload["history"]
    # the re-expressed seeds are IN generation 0, so its best is at
    # least the best single-destination placement
    assert history[0]["best_time_s"] <= best_single * (1 + 1e-9)
    assert res.best_time_s <= best_single * (1 + 1e-9)
    # re-expression really lands in the k-ary alphabet
    assert any(g == 2 for s in seed_p["seeds"] for g in s)


def test_warm_start_gen0_beats_cold_gen0(tmp_path):
    cold = Offloader(_mixed_spec(tmp_path)).run(until="search")
    warm = Offloader(_mixed_spec(tmp_path, warm_start=True)).run(
        until="search"
    )
    c0 = cold.stage("search").payload["history"][0]["best_time_s"]
    w0 = warm.stage("search").payload["history"][0]["best_time_s"]
    assert w0 < c0


# ---------------------------------------------------------------------------
# verify stage + CLI exit codes
# ---------------------------------------------------------------------------


def test_cli_run_report_binary(tmp_path):
    """Full pipeline (incl. the PCAST check on the runnable Himeno
    implementation) through the CLI: exit 0 and a complete artifact."""
    path = str(tmp_path / "himeno.json")
    rc = cli_main(["run", "--program", "himeno", "--mode", "binary",
                   "--smoke", "--quiet", "--artifact", path])
    assert rc == 0
    art = OffloadResult.load(path)
    for stage in ("analyze", "seed", "search", "verify", "report"):
        assert art.completed(stage)
    assert art.stage("verify").payload["pcast"]["ok"]
    assert "PCAST PASS" in art.stage("report").payload["text"]
    assert cli_main(["report", "--artifact", path]) == 0


def test_cli_pcast_failure_exits_nonzero(tmp_path):
    """A PCAST result-difference failure (zero tolerance makes the f32
    jit-vs-numpy difference fatal) surfaces as a non-zero CLI exit with
    the failure recorded in the artifact."""
    path = str(tmp_path / "fail.json")
    rc = cli_main(["run", "--program", "himeno", "--mode", "binary",
                   "--smoke", "--quiet", "--artifact", path,
                   "--rel-tol", "0", "--abs-tol", "0"])
    assert rc == 1
    art = OffloadResult.load(path)
    assert art.stages["verify"].status == "failed"
    assert "PCAST" in art.stages["verify"].error
    assert not art.stages["verify"].payload["pcast"]["ok"]
    assert not art.completed("report")

    # resuming with the failure recorded re-runs verify and fails again
    rc2 = cli_main(["resume", "--artifact", path, "--quiet"])
    assert rc2 == 1


def test_verify_reports_pcast_skipped_for_hetero(tmp_path):
    res = Offloader(_mixed_spec(tmp_path)).run()
    assert "skipped" in res.stage("verify").payload["pcast"]
    assert res.completed("report")


def test_verify_rejects_evaluator_mismatch(tmp_path):
    """An artifact searched with an injected evaluator must not verify
    against a different one (e.g. compiled-arch artifact resumed without
    re-injection): clear failure, not a spurious 'drifted' one."""
    spec = OffloadSpec(program="arch:stablelm-3b", population=4,
                       generations=3)
    injected = lambda genes: 1.0 + 0.001 * sum(genes)  # noqa: E731
    injected.fingerprint = lambda: "injected:toy"
    path = str(tmp_path / "arch.json")
    Offloader(spec, artifact_path=path, evaluator=injected).run(
        until="search"
    )
    with pytest.raises(StageFailure, match="evaluator .* differs"):
        Offloader.resume(path).run(until="verify")
    art = OffloadResult.load(path)
    assert art.stages["verify"].status == "failed"
    # the failed record renders (re_measured_s is None on this path)
    from repro.offload import render_report

    assert "FAILED" in render_report(art)
    # re-injecting the evaluator verifies cleanly, without redundantly
    # re-running the (potentially expensive) injected measurement
    Offloader.resume(path, evaluator=injected).run(until="verify")
    art2 = OffloadResult.load(path)
    assert art2.completed("verify")
    assert art2.stage("verify").payload["re_measured_s"] is None
    assert "skipped" in art2.stage("verify").payload["note"]


def test_stage_failure_recorded_before_raise(tmp_path):
    """A corrupted search record makes verify's re-measurement drift:
    the failure must be recorded AND saved before the raise."""
    path = str(tmp_path / "drift.json")
    off = Offloader(_mixed_spec(tmp_path), artifact_path=path)
    off.run(until="search")
    off.result.stage("search").payload["best_time_s"] /= 2  # corrupt
    with pytest.raises(StageFailure, match="drifted"):
        off.run_stage("verify")
    art = OffloadResult.load(path)
    assert art.stages["verify"].status == "failed"
    assert "drifted" in art.stages["verify"].error
