"""Fidelity subsystem: spec validation, measured evaluator identity,
calibration fit + registry plumbing, calibrated/measured pipelines, and
the modeled-path byte-identity regression (PR-4 parity)."""
import json

import numpy as np
import pytest

from repro.core import evalpool as ep
from repro.core import evaluator as ev
from repro.core import miniapps
from repro.core import transfer as tr
from repro.offload import Offloader, OffloadResult, OffloadSpec, calibrate
from repro.offload import programs as op
from repro.offload.__main__ import main as cli_main


# ---------------------------------------------------------------------------
# spec validation: bad fidelity combinations fail AT SPEC TIME
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw,msg", [
    (dict(program="himeno", fidelity="bogus"), "fidelity"),
    (dict(program="himeno", fidelity="measured", executor="process",
          repeats=0), "repeats"),
    # measured: non-runnable programs have nothing to wall-clock
    (dict(program="hetero", fidelity="measured", executor="process"),
     "runnable"),
    (dict(program="arch:stablelm-3b", fidelity="measured",
          executor="process"), "runnable"),
    # measured: subprocess isolation is mandatory
    (dict(program="himeno", fidelity="measured"), "process"),
    (dict(program="himeno", fidelity="measured", executor="thread"),
     "process"),
    # measured is a binary-mode feature
    (dict(program="himeno", fidelity="measured", executor="process",
          mode="mixed"), "binary"),
    # calibrated: the base registry must exist
    (dict(program="himeno", fidelity="calibrated", hw="no-such-machine"),
     "base registry"),
    (dict(program="arch:stablelm-3b", fidelity="calibrated"), "machine"),
])
def test_fidelity_spec_validation(kw, msg):
    with pytest.raises(ValueError, match=msg):
        OffloadSpec(**kw)


def test_fidelity_spec_roundtrip():
    spec = OffloadSpec(program="himeno", fidelity="measured",
                       executor="process", repeats=3, workers=2)
    assert OffloadSpec.from_json(spec.to_json()) == spec


def test_pr4_era_spec_dict_still_loads():
    """Artifacts written before the fidelity knob existed deserialize to
    fidelity='modeled' (the behavior they were produced under)."""
    d = OffloadSpec(program="himeno").to_dict()
    del d["fidelity"], d["repeats"]
    spec = OffloadSpec.from_dict(d)
    assert spec.fidelity == "modeled"


# ---------------------------------------------------------------------------
# cache-fingerprint invariants (docs/fidelity.md): modeled fingerprints
# are byte-stable, measured ones carry the measurement identity
# ---------------------------------------------------------------------------


def test_modeled_fingerprints_unchanged_by_fidelity_subsystem():
    prog = miniapps.himeno_program()
    e = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)
    assert e.fingerprint() == \
        f"miniapp:{prog.fingerprint()}:bulk:staged:quadro-p4000"
    from repro.destinations import MixedEvaluator

    m = MixedEvaluator(prog, ("cpu", "gpu", "fpga"))
    assert m.fingerprint() == \
        f"mixed:{prog.fingerprint()}:{m.registry.fingerprint()}"


def test_measured_fingerprint_carries_host_and_repeats():
    fn = miniapps.HimenoRunFn()
    a = ev.MeasuredEvaluator(fn, repeats=1, tag=fn.tag, host="hostA")
    b = ev.MeasuredEvaluator(fn, repeats=2, tag=fn.tag, host="hostA")
    c = ev.MeasuredEvaluator(fn, repeats=1, tag=fn.tag, host="hostB")
    assert a.fingerprint().startswith("measured:")
    assert len({a.fingerprint(), b.fingerprint(), c.fingerprint()}) == 3
    # default host comes from this machine
    d = ev.MeasuredEvaluator(fn, tag=fn.tag)
    assert d.host and f"@{d.host}" in d.fingerprint()


def test_run_fn_cache_key_collapses_to_hot_gene():
    fn = miniapps.HimenoRunFn()
    e = ev.MeasuredEvaluator(fn, tag=fn.tag)
    n = miniapps.himeno_program().gene_length
    hot = op.hot_gene_index("himeno")
    on = [0] * n
    on[hot] = 1
    other = [1] * n
    other[hot] = 0
    assert e.cache_key(on) == "hot=1"
    assert e.cache_key([0] * n) == e.cache_key(other) == "hot=0"
    # MeasuredEvaluator without a canonicalizing run_fn keeps digits
    plain = ev.MeasuredEvaluator(lambda g: None)
    assert plain.cache_key((1, 0, 1)) == "101"


def test_pool_dedups_on_measured_canonical_key():
    calls = []

    class Fn:
        def __call__(self, genes):
            calls.append(tuple(genes))

        def cache_key(self, genes):
            return f"hot={int(bool(genes[0]))}"

    e = ev.MeasuredEvaluator(Fn(), tag="t")
    with ep.EvalPool(e) as pool:
        times, tel = pool.evaluate_generation(
            [(0, 0), (0, 1), (1, 0), (1, 1)], 180.0, 1000.0
        )
    assert tel.evaluated == 2 and tel.cache_hits == 2  # 2 canonical keys
    assert times[0] == times[1] and times[2] == times[3]


def test_process_pool_uses_executor_even_at_one_worker(monkeypatch):
    """executor='process' must never fall back to inline in-driver
    measurement: the subprocess isolation is the semantics."""
    seen = {}

    def fake(kind, workers, evaluate, genes_list, timeout_s):
        seen["kind"] = kind
        return [(1.0, False)] * len(genes_list)

    monkeypatch.setattr(ep, "_run_with_executor", fake)
    with ep.EvalPool(lambda g: 99.0, workers=1, executor="process") as pool:
        times, _ = pool.evaluate_generation([(0,)], 180.0, 1000.0)
    assert seen["kind"] == "process" and times == [1.0]
    # thread executor at workers=1 stays inline (pre-pool parity)
    seen.clear()
    with ep.EvalPool(lambda g: 2.0, workers=1, executor="thread") as pool:
        times, _ = pool.evaluate_generation([(0,)], 180.0, 1000.0)
    assert "kind" not in seen and times == [2.0]


# ---------------------------------------------------------------------------
# measured adapter
# ---------------------------------------------------------------------------


def _measured_spec(**kw):
    kw.setdefault("executor", "process")
    return OffloadSpec(program="himeno", fidelity="measured", **kw)


def test_measured_adapter_resolution_and_shape():
    ad = op.resolve_adapter(_measured_spec(repeats=2))
    assert isinstance(ad, op.MiniappMeasuredAdapter)
    assert not ad.deterministic
    assert ad.gene_length == 13 and ad.alleles == 2
    e = ad.build_evaluator()
    assert isinstance(e, ev.MeasuredEvaluator) and e.repeats == 2
    model = ad.model_evaluator()
    # the model prediction lives at the MEASURED scale, not paper scale
    assert model.prog.gene_length == ad.gene_length
    assert model.prog.description != ad.prog.description
    pay = ad.analyze_payload()
    assert pay["fidelity"] == "measured" and pay["host"] == e.host
    genes = [0] * 13
    genes[op.hot_gene_index("himeno")] = 1
    assert ad.placement(genes)["jacobi_stencil"] == "gpu"


def test_measured_adapter_baseline_is_a_real_clock():
    t = op.resolve_adapter(_measured_spec()).baseline_time()
    assert 0.0 < t < 60.0  # a wall clock, not an analytic prediction


# ---------------------------------------------------------------------------
# calibration: fit, artifact, registry plumbing
# ---------------------------------------------------------------------------


def _synthetic_measure(rates=(2.0e9, 1.0e11, 5.0e9, 5e-5)):
    cpu, acc, link, launch = rates

    def measure(p, repeats):
        f, b, c = calibrate._region_quantities(calibrate._probe_program(p))
        if p.dest == "host":
            return f / cpu + c * 1e-4
        return f / acc + b / link + c * launch

    return measure


def test_calibration_fit_recovers_synthetic_constants():
    cal = calibrate.run_calibration(name="syn-a",
                                    measure=_synthetic_measure())
    assert cal.constants["cpu_flops"] == pytest.approx(2.0e9, rel=1e-6)
    assert cal.constants["accel_flops_kernels"] == \
        pytest.approx(1.0e11, rel=1e-6)
    assert cal.constants["link_bw"] == pytest.approx(5.0e9, rel=1e-6)
    assert cal.constants["launch_latency"] == pytest.approx(5e-5, rel=1e-6)
    r = cal.residuals()
    assert r["n"] == len(calibrate.DEFAULT_PROBES)
    assert r["max_abs_rel"] < 1e-9  # exact model -> exact fit
    # balance-preserving constants are recorded as pinned, never silent
    assert "cpu_membw" in cal.pinned and "accel_membw" in cal.pinned
    # ratio preservation vs the base machine
    base = ev.QUADRO_P4000
    assert cal.constants["accel_flops_parallel"] / \
        cal.constants["accel_flops_kernels"] == pytest.approx(
            base.accel_flops_parallel / base.accel_flops_kernels)


def test_calibration_digest_tracks_constants():
    a = calibrate.run_calibration(name="syn-b",
                                  measure=_synthetic_measure())
    b = calibrate.run_calibration(name="syn-b",
                                  measure=_synthetic_measure())
    c = calibrate.run_calibration(
        name="syn-b", measure=_synthetic_measure((3.0e9, 1e11, 5e9, 5e-5)))
    assert a.hw_name == b.hw_name  # deterministic
    assert a.hw_name != c.hw_name  # recalibration moves the fingerprint


def test_calibration_save_load_install(tmp_path):
    cal = calibrate.run_calibration(name="syn-install",
                                    measure=_synthetic_measure())
    path = str(tmp_path / "m.calib.json")
    cal.save(path)
    loaded = calibrate.CalibrationResult.load(path)
    assert loaded.to_dict() == cal.to_dict()
    calibrate.install(loaded)
    # binary-mode selection
    hw = op.resolve_hw(OffloadSpec(program="himeno", hw="syn-install"))
    assert hw.name == cal.hw_name
    # mixed-mode selection (validates destinations against the registry)
    spec = OffloadSpec(program="hetero", mode="mixed", hw="syn-install")
    ad = op.resolve_adapter(spec)
    assert ad.machine == "syn-install"
    # installing again without replace fails; with replace succeeds
    with pytest.raises(ValueError, match="already registered"):
        calibrate.install(loaded, replace=False)
    calibrate.install(loaded, replace=True)


def test_builtin_machines_cannot_be_shadowed():
    from repro.destinations import default_registry, register_registry

    with pytest.raises(ValueError, match="built-in"):
        register_registry("quadro-p4000", default_registry, replace=True)
    with pytest.raises(ValueError, match="built-in"):
        op.register_hw_model(ev.QUADRO_P4000, replace=True)


def test_calibrated_registry_preserves_capacities_and_fpga():
    from repro.destinations import calibrated_registry, get_registry

    base = get_registry("p4000-constrained")
    hw = ev.HardwareModel(name="cal-x", cpu_flops=2e9, cpu_membw=4e9,
                          accel_flops_kernels=1e11,
                          accel_flops_parallel=8e10,
                          accel_flops_vector=1e10, accel_membw=5e10,
                          link_bw=5e9, link_latency=1e-5,
                          launch_latency=2e-5)
    reg = calibrated_registry(base, hw, "p4000-constrained-cal")
    gpu = reg.get("gpu")
    from repro.core.loopir import LoopClass

    assert dict(gpu.rates)[LoopClass.TIGHT] == 1e11  # calibrated rate
    assert gpu.memory_bytes == base.get("gpu").memory_bytes  # capacity kept
    assert reg.get("fpga") == base.get("fpga")  # unobservable: untouched
    assert reg.link("cpu", "gpu").bw == 5e9  # calibrated link
    assert reg.link("cpu", "fpga") == base.link("cpu", "fpga")
    assert reg.fingerprint() != base.fingerprint()


def test_probe_set_must_cover_both_destinations():
    with pytest.raises(ValueError, match="host and accel"):
        calibrate.run_calibration(
            probes=[p for p in calibrate.DEFAULT_PROBES
                    if p.dest == "host"],
            measure=_synthetic_measure(),
        )


# ---------------------------------------------------------------------------
# pipelines end to end
# ---------------------------------------------------------------------------


def test_modeled_search_identical_with_fidelity_knob_present():
    """The PR-4 byte-identity regression: an explicit fidelity='modeled'
    spec (and the default) reproduce the pre-fidelity search exactly."""
    a = Offloader(OffloadSpec(program="himeno")).run(until="search")
    b = Offloader(
        OffloadSpec(program="himeno", fidelity="modeled")
    ).run(until="search")
    assert a.best_genes == b.best_genes
    assert a.best_time_s == b.best_time_s
    assert not a.stage("calibrate").payload["applicable"]


def test_calibrated_pipeline_end_to_end(tmp_path, monkeypatch):
    # a trimmed toy-grid probe set keeps the fast tier fast; the default
    # (bigger) set runs in the CLI verb test below
    small = tuple(
        calibrate.Probe(app, grid, steps, dest)
        for app, grid, steps in [
            ("himeno", (9, 9, 17), 2), ("himeno", (9, 9, 17), 4),
            ("nasft", (8, 8, 8), 2), ("nasft", (8, 8, 8), 4),
        ]
        for dest in ("host", "accel")
    )
    monkeypatch.setattr(calibrate, "DEFAULT_PROBES", small)
    path = str(tmp_path / "cal.offload.json")
    spec = OffloadSpec(program="himeno", fidelity="calibrated",
                       population=4, generations=3)
    res = Offloader(spec, artifact_path=path).run()
    c = res.stage("calibrate").payload
    assert c["applicable"] and c["entry"] == "quadro-p4000-calibrated"
    assert c["residuals"]["n"] == 8
    assert c["calibration"]["constants"]["cpu_flops"] > 0
    # the search priced candidates under the calibrated machine: its
    # fingerprint carries the constants digest, never the modeled name
    fp = res.stage("search").payload["evaluator"]
    assert c["hw_name"].split("-")[-1] in fp
    assert fp != Offloader(OffloadSpec(program="himeno")).run(
        until="search").stage("search").payload["evaluator"]
    # predicted-vs-measured section, one row per destination involved
    fid = res.stage("verify").payload["fidelity"]
    assert fid["level"] == "calibrated"
    assert [r["placement"] for r in fid["rows"]] == \
        ["all-host", "winner:hot-loop"]
    assert all(r["ratio"] > 0 for r in fid["rows"])
    assert "fidelity[calibrated" in res.stage("report").payload["text"]

    # resume in a "new process": the calibration is rebuilt from the
    # artifact payload (same digest), not re-measured
    off2 = Offloader.resume(path)
    assert off2.adapter.hw.name == c["hw_name"]
    assert OffloadResult.load(path).calibration is not None


def test_injected_calibration_skips_the_probe_sweep(monkeypatch):
    """Offloader(calibration=...) records the provided fit instead of
    re-measuring (calibrate once, search many apps)."""
    cal = calibrate.run_calibration(measure=_synthetic_measure())

    def boom(*a, **kw):
        raise AssertionError("probe sweep must not run")

    monkeypatch.setattr(calibrate, "run_calibration", boom)
    spec = OffloadSpec(program="himeno", fidelity="calibrated",
                       population=4, generations=2)
    res = Offloader(spec, calibration=cal).run(until="search")
    c = res.stage("calibrate").payload
    assert c["provided"] and c["hw_name"] == cal.hw_name
    assert cal.digest in res.stage("search").payload["evaluator"]
    # a calibration fitted for another base is rejected up front
    with pytest.raises(ValueError, match="base"):
        Offloader(OffloadSpec(program="himeno", fidelity="calibrated",
                              hw="tpu-v5e-host"), calibration=cal)


def test_calibrated_mixed_spec_resolves_after_install():
    cal = calibrate.run_calibration(name="syn-mixed",
                                    measure=_synthetic_measure())
    calibrate.install(cal)
    res = Offloader(
        OffloadSpec(program="hetero", mode="mixed", hw="syn-mixed",
                    population=6, generations=3)
    ).run(until="search")
    assert res.best_time_s > 0
    assert "syn-mixed" in res.stage("search").payload["evaluator"]


def test_measured_verify_refuses_foreign_host_artifact(tmp_path):
    """A measured artifact resumed on a different host must not bless
    the winner: the measurement fingerprint (host-bound) mismatches."""
    spec = _measured_spec(population=2, generations=1,
                          cache=str(tmp_path / "f.jsonl"))
    off = Offloader(spec, artifact_path=str(tmp_path / "a.json"))
    # fake the search record of a run measured elsewhere
    off.run(until="seed")
    e = off.adapter.build_evaluator()
    foreign = e.fingerprint().replace(f"@{e.host}", "@elsewhere")
    off.result.record("search", {
        "best_genes": [0] * 13, "best_time_s": 0.5, "evaluator": foreign,
    }, 0.0)
    from repro.offload import StageFailure

    with pytest.raises(StageFailure, match="differs"):
        off.run_stage("verify")


@pytest.mark.slow
def test_measured_fidelity_smoke_through_subprocesses(tmp_path):
    """Nightly smoke (ISSUE 5 satellite): the whole measured-fidelity
    pipeline — himeno, tiny budget, spawn-context process pool — prices
    the winner with real subprocess measurements."""
    spec = _measured_spec(workers=2, repeats=2, population=4,
                          generations=2,
                          cache=str(tmp_path / "fitness.jsonl"))
    res = Offloader(spec,
                    artifact_path=str(tmp_path / "m.offload.json")).run()
    p = res.stage("search").payload
    assert p["evaluator"].startswith("measured:")
    assert p["evaluations"] >= 1  # >=1 real subprocess measurement
    assert p["best_time_s"] > 0
    assert res.stage("analyze").payload["baseline_s"] > 0
    fid = res.stage("verify").payload["fidelity"]
    assert fid["level"] == "measured" and len(fid["rows"]) == 2
    assert "fidelity[measured" in res.stage("report").payload["text"]
    # the persistent cache is shared with the report stage's modeled
    # stability re-runs, but fingerprints keep the levels isolated:
    # every measurement sits under the measured fingerprint, and no
    # modeled entry can ever masquerade as one
    recs = [json.loads(l) for l in
            open(tmp_path / "fitness.jsonl", encoding="utf-8")]
    measured = [r for r in recs if r["fp"].startswith("measured:")]
    assert measured and len(measured) == p["evaluations"]
    assert all(r["genes"].startswith("hot=") for r in measured)
    assert all("measured" not in r["fp"]
               for r in recs if r not in measured)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_calibrate_verb_and_calibrated_run(tmp_path, capsys):
    out = str(tmp_path / "p4000.calib.json")
    rc = cli_main(["calibrate", "--base", "quadro-p4000",
                   "--name", "cli-cal", "--repeats", "2", "--out", out])
    assert rc == 0
    printed = capsys.readouterr().out
    assert "residuals" in printed and "cli-cal" in printed
    cal = calibrate.CalibrationResult.load(out)
    assert cal.name == "cli-cal" and cal.residuals()["n"] == 16

    # a later invocation installs the file and selects the entry by name
    art = str(tmp_path / "cli.offload.json")
    rc = cli_main(["run", "--program", "himeno", "--hw", "cli-cal",
                   "--calibration", out, "--population", "4",
                   "--generations", "2", "--quiet", "--until", "search",
                   "--artifact", art])
    assert rc == 0
    assert cal.hw_name.split("-")[-1] in \
        OffloadResult.load(art).stage("search").payload["evaluator"]
