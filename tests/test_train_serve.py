"""End-to-end integration: trainer learns, restarts, serves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import TRAIN_4K
from repro.core import analysis
from repro.data.pipeline import DataConfig
from repro.models.model import Model
from repro.serve.engine import Engine, Request, ServeConfig
from repro.train.trainer import TrainConfig, Trainer


def _shape(seq=32, batch=8):
    return dataclasses.replace(TRAIN_4K, seq_len=seq, global_batch=batch)


@pytest.fixture(scope="module")
def trained():
    cfg = get_arch("stablelm-3b").reduced()
    plan = analysis.build_plan(cfg, None, n_groups=2)
    tcfg = TrainConfig(steps=60, log_every=1000, peak_lr=3e-3, warmup=5)
    tr = Trainer(cfg, _shape(), plan, tcfg=tcfg)
    tr.initialize()
    losses = []
    it = iter(tr.pipeline)
    import itertools

    class Tap:
        def __iter__(self):
            return self

        def __next__(self):
            return next(it)

    tr.run(Tap())
    return cfg, plan, tr


def test_training_loss_decreases(trained):
    cfg, plan, tr = trained
    recs = list(tr.monitor.records)
    first = np.mean([r.loss for r in recs[:10]])
    last = np.mean([r.loss for r in recs[-10:]])
    assert last < first - 0.3, (first, last)  # planted bigram is learnable


def test_trainer_checkpoint_restart(tmp_path):
    cfg = get_arch("stablelm-3b").reduced()
    plan = analysis.build_plan(cfg, None, n_groups=2)
    tcfg = TrainConfig(steps=10, log_every=1000, ckpt_dir=str(tmp_path),
                       save_every=5)
    tr = Trainer(cfg, _shape(), plan, tcfg=tcfg)
    tr.run()
    assert tr.step == 10
    # new trainer resumes from step 10 checkpoint and only runs 5 more
    tcfg2 = TrainConfig(steps=15, log_every=1000, ckpt_dir=str(tmp_path),
                        save_every=5)
    tr2 = Trainer(cfg, _shape(), plan, tcfg=tcfg2)
    tr2.initialize()
    assert tr2.step == 10
    tr2.run()
    assert tr2.step == 15


def test_trainer_gradient_compression_path():
    cfg = get_arch("stablelm-3b").reduced()
    plan = analysis.build_plan(cfg, None, n_groups=2)
    tcfg = TrainConfig(steps=4, log_every=1000, compress_grads=True)
    tr = Trainer(cfg, _shape(), plan, tcfg=tcfg)
    summary = tr.run()
    assert np.isfinite(summary["loss_ewma"])


def test_microbatched_step_equals_fullbatch_loss():
    """Gradient accumulation over microbatches reports the same loss."""
    from repro.optim.adamw import adamw
    from repro.train import train_step as ts

    cfg = get_arch("stablelm-3b").reduced()
    plan1 = analysis.build_plan(cfg, None, n_groups=2, microbatches=1)
    plan4 = analysis.build_plan(cfg, None, n_groups=2, microbatches=4)
    m1 = Model(cfg, plan1)
    m4 = Model(cfg, plan4)
    params = jax.jit(m1.init)(jax.random.key(0))
    opt = adamw(0.0)  # lr 0: isolate the gradient computation
    s1 = jax.jit(ts.make_train_step(m1, opt))
    s4 = jax.jit(ts.make_train_step(m4, opt))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
    }
    state = opt.init(params)
    _, _, met1 = s1(params, state, batch)
    _, _, met4 = s4(params, state, batch)
    assert float(met1["loss"]) == pytest.approx(float(met4["loss"]), rel=2e-2)


def test_serving_engine_batched_requests():
    cfg = get_arch("stablelm-3b").reduced()
    plan = analysis.build_plan(cfg, None, n_groups=2)
    model = Model(cfg, plan)
    params = jax.jit(model.init)(jax.random.key(0))
    eng = Engine(cfg, plan, params, ServeConfig(slots=2, ctx_len=64))
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(i, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                           max_new_tokens=6))
    done = eng.run_until_done()
    assert len(done) == 4
    assert all(len(r.output) == 6 for r in done)


def test_serving_greedy_matches_manual_decode():
    """Engine slot decode == hand-rolled prefill+decode for one request."""
    cfg = get_arch("stablelm-3b").reduced()
    plan = analysis.build_plan(cfg, None, n_groups=2)
    model = Model(cfg, plan)
    params = jax.jit(model.init)(jax.random.key(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)

    eng = Engine(cfg, plan, params, ServeConfig(slots=2, ctx_len=64))
    eng.submit(Request(0, prompt, max_new_tokens=5))
    out_engine = eng.run_until_done()[0].output

    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, ctx_len=64
    )
    tok = int(jnp.argmax(logits[0, : cfg.vocab]))
    out_manual = [tok]
    pos = len(prompt)
    for _ in range(4):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[tok]]), jnp.asarray([[pos]])
        )
        tok = int(jnp.argmax(lg[0, : cfg.vocab]))
        out_manual.append(tok)
        pos += 1
    assert out_engine == out_manual
